"""Content-addressed run cache: RunSpec fingerprint -> RunResult.

Runs are deterministic per spec (seeded ``World``, virtual clock, stable
seed derivation), so a completed :class:`RunResult` can be replayed for
free.  The cache key is a digest over the spec identity PLUS the resolved
:class:`PatternConfig`, :class:`DeploymentCapabilities` AND
:class:`ServingCapabilities` fingerprints — re-registering a pattern,
deployment or LLM serving backend with different knobs invalidates every
cached run that used it, with no explicit flush.

    from repro.apps.cache import RunCache
    from repro.apps.session import RunSpec, Session

    session = Session(cache=RunCache())
    session.execute(spec)   # miss: executes
    session.execute(spec)   # hit: returns the stored RunResult

``run_sweep`` re-runs and figure regeneration become near-free once the
cache is warm.  Specs carrying a ``backend_factory`` are not cacheable
(arbitrary callables have no stable fingerprint) and always execute.

Disk persistence (ROADMAP item): pass ``RunCache(cache_dir=...)`` and
every completed run is also written as one wire-serialized JSON file
(trace derived from the run's event stream; ``extras`` dropped except
the events themselves). A fresh ``RunCache`` on the same directory —
e.g. a ``Session`` constructed in a new process — loads them back, so
cold ``run_sweep`` restarts are free too.  In-memory entries keep the
full ``extras`` (World, policy) so ``score_run`` works on warm hits;
disk-replayed hits carry only the event stream.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Dict, Optional

from ..core.events import derive_trace, events_from_wire, events_to_wire
from ..core.metrics import RunResult
from ..core.persist import atomic_write_json, load_json_dir


def spec_fingerprint(spec) -> Optional[str]:
    """Deterministic content address of one run, or ``None`` if the spec
    is not cacheable (custom ``backend_factory``).

    ``spec.priority`` is deliberately NOT part of the address: serving
    priority steers admission order and preemption — latency, never
    tokens (preempted requests resume bit-identically) — so runs that
    differ only in priority share a cache entry.

    ``spec.tenant`` IS part of the address (when non-empty): a cached
    result carries its billing attribution (tenant-stamped events), so
    two tenants issuing the identical request must never share an entry
    — one tenant's spend would be served under the other's name.  The
    default tenant ``""`` is omitted from the payload entirely, keeping
    pre-tenancy fingerprints — and any disk caches written under them —
    byte-identical."""
    if spec.backend_factory is not None:
        return None
    from ..core.runtime import resolve_pattern
    from ..faas.deployments import resolve_deployment
    from ..serving.api import resolve_llm_backend
    tenant = getattr(spec, "tenant", "")
    payload = json.dumps({
        **({"tenant": tenant} if tenant else {}),
        "app": spec.app,
        "instance": spec.instance,
        "pattern": spec.pattern,
        "deployment": spec.deployment,
        "llm": spec.llm,
        "seed": spec.seed,
        "pattern_config": resolve_pattern(spec.pattern).config.fingerprint(),
        "deployment_caps":
            resolve_deployment(spec.deployment).capabilities.fingerprint(),
        "serving_caps":
            resolve_llm_backend(spec.llm).capabilities.fingerprint(),
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()


def result_to_wire(result: RunResult) -> Dict:
    """JSON-safe dict for one completed run: scalar fields + the run's
    wire-serialized event stream (``extras`` beyond the events — World,
    policy, outcome — are dropped; they don't serialize).  The spec seed
    is kept so ``score_run`` can rebuild the deterministic world/policy
    for a replayed result."""
    spec = result.extras.get("spec")
    return {
        "app": result.app, "instance": result.instance,
        "pattern": result.pattern, "deployment": result.deployment,
        "seed": getattr(spec, "seed", result.extras.get("seed")),
        "success": result.success, "total_latency": result.total_latency,
        "artifact_path": result.artifact_path, "artifact": result.artifact,
        "faas_cost": result.faas_cost,
        "failure_reason": result.failure_reason,
        "events": events_to_wire(result.extras.get("events", [])),
    }


def result_from_wire(d: Dict) -> RunResult:
    """Inverse of :func:`result_to_wire`: the accounting ``Trace`` is
    rebuilt from the event stream (``derive_trace``)."""
    events = events_from_wire(d.get("events", []))
    return RunResult(
        app=d["app"], instance=d["instance"], pattern=d["pattern"],
        deployment=d["deployment"], success=d["success"],
        total_latency=d["total_latency"], trace=derive_trace(events),
        artifact_path=d.get("artifact_path"), artifact=d.get("artifact"),
        faas_cost=d.get("faas_cost", 0.0),
        failure_reason=d.get("failure_reason", ""),
        extras={"events": events, "seed": d.get("seed")})


class RunCache:
    """Thread-safe RunResult store addressed by :func:`spec_fingerprint`,
    optionally persisted under ``cache_dir`` (one JSON file per entry).
    Safe under ``Session.execute_many`` worker threads."""

    def __init__(self, cache_dir: Optional[str] = None):
        self._lock = threading.Lock()
        self._store: Dict[str, RunResult] = {}
        self.hits = 0
        self.misses = 0
        self.cache_dir = cache_dir
        if cache_dir:
            os.makedirs(cache_dir, exist_ok=True)
            # corrupt, foreign, or schema-drifted files are misses
            # (CORRUPT_ENTRY_ERRORS skip inside load_json_dir)
            self._store.update(load_json_dir(
                cache_dir,
                lambda stem, payload: (stem, result_from_wire(payload))))

    def get(self, key: Optional[str]) -> Optional[RunResult]:
        if key is None:
            return None
        with self._lock:
            result = self._store.get(key)
            if result is None:
                self.misses += 1
            else:
                self.hits += 1
            return result

    def put(self, key: Optional[str], result: RunResult) -> None:
        if key is None:
            return
        with self._lock:
            self._store[key] = result
        if self.cache_dir:
            # serialize + write OUTSIDE the lock: execute_many workers
            # must not queue behind each other's JSON encoding/disk I/O.
            # Per-key last-writer-wins via atomic rename; same key means
            # same deterministic result anyway.  Persistence is an
            # optimization — a full disk must not fail a completed run
            # (best_effort).
            atomic_write_json(os.path.join(self.cache_dir, f"{key}.json"),
                              result_to_wire(result), best_effort=True)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def clear(self) -> None:
        """Release in-memory entries and counters (disk files are kept —
        a fresh RunCache on the same dir reloads them)."""
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses}
