"""Content-addressed run cache: RunSpec fingerprint -> RunResult.

Runs are deterministic per spec (seeded ``World``, virtual clock, stable
seed derivation), so a completed :class:`RunResult` can be replayed for
free.  The cache key is a digest over the spec identity PLUS the resolved
:class:`PatternConfig` fingerprint and :class:`DeploymentCapabilities`
fingerprint — re-registering a pattern or deployment with different knobs
invalidates every cached run that used it, with no explicit flush.

    from repro.apps.cache import RunCache
    from repro.apps.session import RunSpec, Session

    session = Session(cache=RunCache())
    session.execute(spec)   # miss: executes
    session.execute(spec)   # hit: returns the stored RunResult

``run_sweep`` re-runs and figure regeneration become near-free once the
cache is warm.  Specs carrying a ``backend_factory`` are not cacheable
(arbitrary callables have no stable fingerprint) and always execute.

Entries keep the full ``RunResult`` including ``extras`` (World, policy,
events) so ``score_run`` works on replayed hits — a warm full-sweep cache
therefore pins one World per combo.  ``clear()`` releases them; a disk
layer with slimmed results is future work (see ROADMAP).
"""
from __future__ import annotations

import hashlib
import json
import threading
from typing import Dict, Optional

from ..core.metrics import RunResult


def spec_fingerprint(spec) -> Optional[str]:
    """Deterministic content address of one run, or ``None`` if the spec
    is not cacheable (custom ``backend_factory``)."""
    if spec.backend_factory is not None:
        return None
    from ..core.runtime import resolve_pattern
    from ..faas.deployments import resolve_deployment
    payload = json.dumps({
        "app": spec.app,
        "instance": spec.instance,
        "pattern": spec.pattern,
        "deployment": spec.deployment,
        "seed": spec.seed,
        "pattern_config": resolve_pattern(spec.pattern).config.fingerprint(),
        "deployment_caps":
            resolve_deployment(spec.deployment).capabilities.fingerprint(),
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()


class RunCache:
    """Thread-safe in-memory RunResult store addressed by
    :func:`spec_fingerprint`. Safe under ``Session.execute_many`` worker
    threads."""

    def __init__(self):
        self._lock = threading.Lock()
        self._store: Dict[str, RunResult] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: Optional[str]) -> Optional[RunResult]:
        if key is None:
            return None
        with self._lock:
            result = self._store.get(key)
            if result is None:
                self.misses += 1
            else:
                self.hits += 1
            return result

    def put(self, key: Optional[str], result: RunResult) -> None:
        if key is None:
            return
        with self._lock:
            self._store[key] = result

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses}
