"""Back-compat shim over the Session / RunSpec API.

The end-to-end runner lives in :mod:`repro.apps.session`; pattern lookup
lives in the registry (:mod:`repro.core.runtime`). This module keeps the
historical entry points — ``run_app``, ``run_until_n_successes``,
``score_run`` and the ``PATTERNS`` mapping — as thin delegating wrappers.
"""
from __future__ import annotations

import functools
from typing import Iterator, Mapping

from ..core.metrics import RunResult
from ..core.runtime import pattern_names, resolve_pattern
from .session import RunSpec, Session, score_run  # noqa: F401 (re-export)


class _PatternView(Mapping):
    """Read-only mapping view over the pattern registry, shaped like the
    old ``PATTERNS`` dict of runner factories."""

    def __getitem__(self, name: str):
        rp = resolve_pattern(name)
        return functools.partial(rp.runner_cls, config=rp.config)

    def __iter__(self) -> Iterator[str]:
        return iter(pattern_names())

    def __len__(self) -> int:
        return len(pattern_names())


PATTERNS = _PatternView()


def run_app(app_name: str, instance: str, pattern: str,
            deployment: str = "local", seed: int = 0,
            backend_factory=None, llm: str = "oracle") -> RunResult:
    """Execute one (app, instance, pattern, deployment, llm) run.

    Equivalent to ``Session().execute(RunSpec(...))``.
    """
    return Session().execute(RunSpec(app_name, instance, pattern, deployment,
                                     seed, backend_factory, llm))


def run_until_n_successes(app_name: str, instance: str, pattern: str,
                          deployment: str, n: int = 5, max_runs: int = 40,
                          seed0: int = 0):
    """Paper success-rate protocol (§5.4.2); see
    ``Session.run_until_n_successes``."""
    return Session().run_until_n_successes(
        RunSpec(app_name, instance, pattern, deployment, seed0),
        n=n, max_runs=max_runs)
