"""End-to-end application runner: deployment + pattern + app instance ->
RunResult (+ judge score). The experiment harness in ``benchmarks/``
aggregates these into the paper's figures.
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

from ..core.agentx import AgentXRunner
from ..core.llm import OracleLLMBackend
from ..core.magentic import MagenticOneRunner
from ..core.metrics import RunResult, Trace
from ..core.policies import POLICIES
from ..core.react import ReActRunner
from ..env.world import World
from ..eval.judge import Score, judge_stock, judge_summary
from ..faas.deployments import (deploy_distributed, deploy_local,
                                deploy_monolithic)
from ..faas.platform import FaaSPlatform
from .apps import APPS

import functools

PATTERNS = {
    "agentx": AgentXRunner,
    "agentx-cot": functools.partial(AgentXRunner, cot=True),
    "agentx-parallel": functools.partial(AgentXRunner, parallel_stages=True),
    "agentx-cot-parallel": functools.partial(AgentXRunner, cot=True,
                                             parallel_stages=True),
    "react": ReActRunner,
    "magentic": MagenticOneRunner,
}


def _artifact(policy, workspace, s3) -> Tuple[Optional[str], Optional[str]]:
    """Locate the expected output artifact in whichever store it landed."""
    name = policy.artifact
    candidates = [policy.out_target(name), name,
                  f"s3://dummy-bucket/agent/{name}"]
    for store in (s3, workspace):
        if store is None:
            continue
        for path in candidates:
            if store.exists(path):
                return path, store.read(path)
        # fuzzy: suffix match (agents sometimes pick their own path)
        for path in store.list():
            if path.endswith(name.split("/")[-1]):
                return path, store.read(path)
    return None, None


def run_app(app_name: str, instance: str, pattern: str,
            deployment: str = "local", seed: int = 0,
            backend_factory=None) -> RunResult:
    """Execute one (app, instance, pattern, deployment) run.

    deployment: "local" (Fig. 2a) | "faas" (distributed, Fig. 2c) |
    "faas-mono" (monolithic, Fig. 2b — beyond-paper benchmark).
    """
    app = APPS[app_name]
    world = World(seed=seed * 9176 + hash((app_name, instance, pattern,
                                           deployment)) % 10_000)
    faas = deployment != "local"
    task = app.prompt(instance, faas)

    platform = None
    workspace = None
    if deployment == "local":
        clients, workspace = deploy_local(world, app.servers)
        s3 = None
    else:
        platform = FaaSPlatform(world)
        if deployment == "faas-mono":
            clients = deploy_monolithic(world, platform, app.servers)
        else:
            clients = deploy_distributed(world, platform, app.servers)
        s3 = platform.s3
        platform.reset_accounting()   # deployment cold-starts not billed to run
        world.clock.reset()

    policy = POLICIES[app_name](world, task, deployment, seed)
    trace = Trace()
    backend = (backend_factory(world, policy, trace) if backend_factory
               else OracleLLMBackend(world, policy, trace))
    runner_cls = PATTERNS[pattern]
    runner = runner_cls(backend, clients, world, trace, deployment=deployment)

    t0 = world.clock.now()
    failure = ""
    try:
        outcome = runner.run(task)
    except Exception as e:  # pattern-level crash counts as failed run
        outcome = {"completed": False}
        failure = f"{type(e).__name__}: {e}"
    total_latency = world.clock.now() - t0

    path, artifact = _artifact(policy, workspace, s3)
    success = outcome.get("completed", False) and artifact is not None
    if app_name == "stock_correlation" and artifact is not None:
        score = judge_stock(world, policy.companies, policy.filename,
                            path, artifact)
        # dummy-data plots count as failures (paper §6.4)
        if score.attributes["Data Accuracy"] < 20.0:
            success = False
            failure = failure or "plot used dummy/fabricated data"
    for client in clients.values():
        client.close()

    faas_cost = platform.total_cost() if platform else 0.0
    return RunResult(app=app_name, instance=instance, pattern=pattern,
                     deployment=deployment, success=success,
                     total_latency=total_latency, trace=trace,
                     artifact_path=path, artifact=artifact,
                     faas_cost=faas_cost, failure_reason=failure,
                     extras={"world": world, "policy": policy,
                             "outcome": outcome})


def score_run(result: RunResult) -> Score:
    world = result.extras["world"]
    policy = result.extras["policy"]
    if result.app == "stock_correlation":
        return judge_stock(world, policy.companies, policy.filename,
                           result.artifact_path, result.artifact)
    query = getattr(policy, "query", getattr(policy, "title", ""))
    return judge_summary(world, query, result.artifact, result.app)


def run_until_n_successes(app_name: str, instance: str, pattern: str,
                          deployment: str, n: int = 5, max_runs: int = 40,
                          seed0: int = 0):
    """Paper success-rate protocol (§5.4.2): run until N successes; success
    rate = N / total runs needed."""
    successes, runs = [], []
    seed = seed0
    while len(successes) < n and len(runs) < max_runs:
        r = run_app(app_name, instance, pattern, deployment, seed=seed)
        runs.append(r)
        if r.success:
            successes.append(r)
        seed += 1
    return successes, runs
