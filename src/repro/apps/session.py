"""Session / RunSpec orchestration API.

``RunSpec`` names one end-to-end run (app, instance, pattern, deployment,
llm, seed).  The ``pattern``, ``deployment`` and ``llm`` fields are all
*registry names*: patterns resolve through ``@register_pattern``
(:mod:`repro.core.runtime`), deployments through ``@register_deployment``
(:mod:`repro.faas.deployments`) and LLM serving backends through
``@register_llm_backend`` (:mod:`repro.serving.api`) — ``Session``
itself never branches on any of the three names.  A run's environment
comes from the resolved :class:`DeploymentBackend`: ``provision`` builds
the MCP clients and artifact stores, the backend's
:class:`DeploymentCapabilities` shape the prompt, and
``teardown``/``cost`` close out the run.  The run's *brain* comes from
the resolved :class:`ServingBackend` (``oracle`` stand-in, per-call
``jax`` engine, or ``jax-batched`` — completions multiplexed onto the
continuous-batching scheduler, so ``execute_many`` fan-out shares one
decode batch).

    from repro.apps.session import RunSpec, Session

    session = Session()
    result = session.execute(RunSpec("web_search", "quantum", "agentx"))
    batch = session.execute_many(
        [RunSpec("web_search", "quantum", "agentx", seed=s)
         for s in range(8)], max_workers=4)

Observers subscribe to the typed run-event stream with
``Session(on_event=fn)`` — ``fn`` receives every
:class:`repro.core.events.RunEvent` live (from worker threads under
``execute_many``).

Runs are deterministic per spec: the ``World`` seed derives from a stable
CRC-32 digest of the spec identity, so identical specs produce identical
runs across processes.  Pass ``Session(cache=RunCache())`` to memoize
completed runs content-addressed by spec + config fingerprints
(:mod:`repro.apps.cache`); cache hits return the stored ``RunResult``
without re-executing (and therefore without re-emitting events).
"""
from __future__ import annotations

import dataclasses
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Iterable, List, Optional, Tuple

from ..core.metrics import RunResult, Trace
from ..core.policies import POLICIES, HedgePolicy, RetryPolicy
from ..core.runtime import RunAborted, RunOutcome, create_runner
from ..env.world import World
from ..eval.judge import Score, judge_stock, judge_summary
from ..faas.deployments import create_deployment, resolve_deployment
from .apps import APPS
from .cache import RunCache, spec_fingerprint


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One (app, instance, pattern, deployment, llm, seed) run.

    deployment: any ``@register_deployment`` name — built-ins are
    "local" (Fig. 2a), "faas" (distributed, Fig. 2c), "faas-mono"
    (monolithic, Fig. 2b) and "a2a" (remote delegation).

    llm: any ``@register_llm_backend`` name — built-ins are "oracle"
    (seeded stand-in), "jax" (real engine, per-call) and "jax-batched"
    (real engine, continuous batching).  ``backend_factory`` overrides
    the registry with an arbitrary per-run factory (not cacheable).

    priority: serving-side priority class for this run's LLM
    completions (higher = more urgent).  Against the continuous-batching
    backend, completions jump the scheduler's admission queue and may
    preempt lower-priority slots (which resume bit-identically, so
    priority affects latency, never tokens).  Like ``llm``, it does NOT
    enter the ``World`` seed: scheduling urgency must not reshuffle the
    environment.

    tenant: the principal this run is billed to (multi-tenant serving,
    :mod:`repro.tenancy`); ``""`` is the single default tenant.  Like
    ``priority``, the tenant steers scheduling (fair-share weight) and
    billing (budgets), never the run's content: it is EXCLUDED from the
    ``World`` seed and the plan-cache key, but INCLUDED in the run-cache
    fingerprint — identical requests from two tenants share a compiled
    plan graph yet never a cached result billed to the wrong principal.
    """
    app: str
    instance: str
    pattern: str
    deployment: str = "local"
    seed: int = 0
    backend_factory: Optional[Callable] = None
    llm: str = "oracle"
    priority: int = 0
    tenant: str = ""

    def with_seed(self, seed: int) -> "RunSpec":
        return dataclasses.replace(self, seed=seed)


def stable_world_seed(spec: RunSpec) -> int:
    """Process-independent ``World`` seed for a spec.

    Uses CRC-32 instead of builtin ``hash`` (randomized per process via
    PYTHONHASHSEED), so identical specs produce identical runs everywhere
    — the invariant the run cache and cross-process reproducibility rest
    on.  ``spec.llm`` is deliberately NOT part of the key: the serving
    backend is the brain's substrate, not the world — decisions come from
    the seeded policy either way, so swapping oracle/jax/jax-batched must
    not reshuffle the environment.  A deployment whose capabilities set
    ``world_alias`` (fault-injecting wrappers, :mod:`repro.traffic.faults`)
    seeds as the aliased name: injected faults perturb the run, never the
    world it runs in.
    """
    deployment = spec.deployment
    try:
        caps = resolve_deployment(deployment).capabilities
        deployment = caps.world_alias or deployment
    except KeyError:
        pass   # unregistered name (direct construction in tests)
    key = f"{spec.app}/{spec.instance}/{spec.pattern}/{deployment}"
    return spec.seed * 9176 + zlib.crc32(key.encode()) % 10_000


def _artifact(policy, workspace, s3) -> Tuple[Optional[str], Optional[str]]:
    """Locate the expected output artifact in whichever store it landed."""
    name = policy.artifact
    candidates = [policy.out_target(name), name,
                  f"s3://dummy-bucket/agent/{name}"]
    for store in (s3, workspace):
        if store is None:
            continue
        for path in candidates:
            if store.exists(path):
                return path, store.read(path)
        # fuzzy: suffix match (agents sometimes pick their own path)
        for path in store.list():
            if path.endswith(name.split("/")[-1]):
                return path, store.read(path)
    return None, None


class Session:
    """Executes RunSpecs against fresh per-run environments.

    ``retry`` / ``hedge`` (:class:`repro.core.policies.RetryPolicy` /
    :class:`repro.core.policies.HedgePolicy`) are handed to every
    runner: tool invocations that fail with retryable errors (e.g. the
    fault injection of :mod:`repro.traffic.faults`) are re-dispatched
    with virtual-time backoff, slow calls are hedged — the agent's
    history, and therefore every decision, stays identical to a
    fault-free run as long as the budget holds.  Specs run under a
    retry/hedge policy are NOT cached: resilience changes latency/cost
    accounting, and the cache key does not cover the policies.

    ``journal`` (:class:`repro.durable.journal.RunJournal`) makes runs
    durable: every emitted event is appended to a per-run JSONL segment
    keyed by the run-cache content address, and an interrupted run can
    continue from its last committed event via
    :func:`repro.durable.resume.resume_run` — see ``docs/DURABLE.md``.
    Crashed (aborted) runs are never cached: their results are partial
    by construction.

    ``tenancy`` (:class:`repro.tenancy.Tenancy`) turns on per-tenant
    budget enforcement at admission: a soft-exhausted tenant's runs are
    downgraded to a cheaper configuration (``RunDegraded`` on the
    stream), a hard-exhausted tenant's runs are rejected outright
    (``BudgetExceeded``, nothing executes), and every finished run's
    Eq. 1+2 spend is charged to its tenant's meter — see
    ``docs/TENANCY.md``.  With ``tenancy=None`` (or a registry with no
    finite budgets) the admission path is inert and runs are
    bit-identical to a tenancy-free session."""

    def __init__(self,
                 on_event: Optional[Callable] = None,
                 cache: Optional[RunCache] = None,
                 retry: Optional["RetryPolicy"] = None,
                 hedge: Optional["HedgePolicy"] = None,
                 plan_cache: Optional["PlanCache"] = None,
                 journal: Optional["RunJournal"] = None,
                 tenancy: Optional["Tenancy"] = None):
        self.on_event = on_event
        self.cache = cache
        self.retry = retry
        self.hedge = hedge
        self.plan_cache = plan_cache
        self.journal = journal
        self.tenancy = tenancy

    # ------------------------------------------------------------------
    def execute(self, spec: RunSpec,
                on_event: Optional[Callable] = None,
                attempt: int = 0) -> RunResult:
        """Execute one run end-to-end: provision the deployment backend,
        run the pattern, locate + judge the artifact, account costs.

        With a warm cache, returns the stored RunResult instead.

        ``attempt`` is the durable-execution restart counter (0 = first
        execution): it keys the deployment's injected-crash draw so a
        rerun/resume of a crashed run is a fresh sample instead of
        deterministically dying at the same event again."""
        pre_events: List = []
        if self.tenancy is not None:
            admitted = self._admit(spec, on_event)
            if isinstance(admitted, RunResult):
                return admitted                    # hard budget rejection
            spec, pre_events = admitted
        # a plan-compilable spec bypasses the run cache: compiled replays
        # differ in cost/latency accounting (no planner calls), and the
        # run-cache key does not cover the plan-cache state — the same
        # exclusion rule as retry/hedge policies.  A degraded run is not
        # cacheable either: its stream carries the RunDegraded admission
        # event, which reflects the tenant's meter state, not the spec.
        cacheable = (self.cache is not None
                     and self.retry is None and self.hedge is None
                     and not pre_events
                     and self._plan_key(spec) is None)
        key = spec_fingerprint(spec) if cacheable else None
        if cacheable:
            hit = self.cache.get(key)
            if hit is not None:
                return hit
        result = self._execute(spec, on_event, attempt=attempt)
        if pre_events:
            result.extras["events"] = (pre_events
                                       + list(result.extras.get("events",
                                                                ())))
        # an aborted (crashed) run is partial by construction: caching
        # it would serve the dead run to every later identical spec
        if cacheable and not result.extras.get("aborted"):
            self.cache.put(key, result)
        if self.tenancy is not None:
            # bill the run's Eq. 1 (LLM tokens) + Eq. 2 (FaaS) spend to
            # its tenant; cache hits return above unbilled — the tenant
            # already paid when the entry was first executed
            self.tenancy.meter.charge(
                spec.tenant,
                result.trace.input_tokens + result.trace.output_tokens,
                result.trace.llm_cost + result.faas_cost)
        return result

    def _admit(self, spec: RunSpec, on_event: Optional[Callable]):
        """Tenancy admission control for one spec.

        Returns either a rejection ``RunResult`` (hard budget
        exhaustion — nothing executes, nothing billed) or
        ``(spec', pre_events)`` where ``spec'`` is possibly degraded to
        a cheaper configuration and ``pre_events`` holds the
        ``RunDegraded`` admission event to prepend to the run's
        stream."""
        from ..core.events import BudgetExceeded, RunDegraded
        from ..tenancy.budget import HARD, SOFT
        meter = self.tenancy.meter
        state = meter.state(spec.tenant)
        if state == HARD:
            kind, used, budget = meter.exhausted_axis(spec.tenant)
            ev = BudgetExceeded(t=0.0, tenant=spec.tenant, kind=kind,
                                used=used, budget=budget)
            obs = self._combined_observer(on_event)
            if obs is not None:
                obs(ev)
            meter.record_rejected(spec.tenant)
            return RunResult(
                app=spec.app, instance=spec.instance, pattern=spec.pattern,
                deployment=spec.deployment, success=False,
                total_latency=0.0, trace=Trace(),
                failure_reason=(f"BudgetExceeded: tenant {spec.tenant!r} "
                                f"{kind} {used:.6g}/{budget:.6g}"),
                extras={"spec": spec, "events": [ev], "rejected": True})
        if state == SOFT:
            spec2, info = self.tenancy.degrade.degrade(spec,
                                                       self.plan_cache)
            if info is not None:
                ev = RunDegraded(t=0.0, tenant=spec.tenant,
                                 reason="soft budget exhaustion", **info)
                obs = self._combined_observer(on_event)
                if obs is not None:
                    obs(ev)
                meter.record_degraded(spec.tenant)
                return spec2, [ev]
        return spec, []

    def _plan_key(self, spec: RunSpec) -> Optional[str]:
        if self.plan_cache is None:
            return None
        # deferred import: the plans layer sits above core + apps.apps
        from ..plans.compile import plan_key
        return plan_key(spec)

    def _execute(self, spec: RunSpec,
                 on_event: Optional[Callable] = None,
                 resume: Any = None, attempt: int = 0) -> RunResult:
        """Dispatch one run: replay a compiled plan when the plan cache
        holds this spec's template key, falling back to a fresh fully
        planned run (which recompiles) on any :class:`PlanDeviation`.
        ``resume`` (a :class:`repro.durable.journal.Segment`) routes the
        run down the crash-resume path instead."""
        pk = self._plan_key(spec)
        if resume is not None:
            return self._execute_resume(spec, on_event, resume, pk)
        if pk is None:
            return self._execute_once(spec, on_event, attempt=attempt)
        graph = self.plan_cache.get(pk)
        if graph is None:
            return self._execute_once(spec, on_event, key=pk,
                                      attempt=attempt)
        from ..plans.execute import PlanDeviation
        try:
            return self._execute_once(spec, on_event, graph=graph, key=pk,
                                      attempt=attempt)
        except PlanDeviation as e:
            self.plan_cache.record_fallback(pk)
            return self._execute_once(spec, on_event, key=pk,
                                      fallback=(e.reason, e.stage),
                                      attempt=attempt)

    def _execute_resume(self, spec: RunSpec, on_event: Optional[Callable],
                        segment: Any, pk: Optional[str]) -> RunResult:
        """Resume an interrupted run: re-dispatch it down the same branch
        its journaled prefix took — the plan-cache decision (miss /
        fallback / compiled replay) is part of the history being
        replayed, so it must not be re-decided against today's cache
        state.  Raises :class:`ResumeDeviation` when the branch can no
        longer be taken (``resume_run`` falls back to a full rerun)."""
        from ..core.events import PlanCacheMiss, PlanFallback, RunStarted
        from ..durable.resume import ResumeDeviation
        attempt = segment.resumes + 1
        first = segment.events[0]
        if isinstance(first, PlanFallback):
            return self._execute_once(spec, on_event, key=first.key,
                                      fallback=(first.reason, first.stage),
                                      resume=segment, attempt=attempt)
        if isinstance(first, PlanCacheMiss):
            return self._execute_once(spec, on_event, key=first.key,
                                      resume=segment, attempt=attempt)
        if isinstance(first, RunStarted) and first.pattern != spec.pattern:
            # the prefix is a compiled-plan replay: resuming needs the
            # same graph back
            graph = (self.plan_cache.get(pk)
                     if self.plan_cache is not None and pk else None)
            if graph is None:
                raise ResumeDeviation("compiled graph no longer cached")
            from ..plans.execute import PlanDeviation
            try:
                return self._execute_once(spec, on_event, graph=graph,
                                          key=pk, resume=segment,
                                          attempt=attempt)
            except PlanDeviation as e:
                raise ResumeDeviation(
                    f"plan replay deviated on resume: {e.reason}") from e
        return self._execute_once(spec, on_event, resume=segment,
                                  attempt=attempt)

    def _execute_once(self, spec: RunSpec,
                      on_event: Optional[Callable] = None,
                      graph: Any = None, key: Optional[str] = None,
                      fallback: Optional[Tuple[str, int]] = None,
                      resume: Any = None, attempt: int = 0) -> RunResult:
        app = APPS[spec.app]
        world = World(seed=stable_world_seed(spec))
        backend = create_deployment(spec.deployment)
        task = app.prompt(spec.instance, backend.capabilities.remote)
        env = backend.provision(world, app.servers)

        policy = POLICIES[spec.app](world, task, spec.deployment, spec.seed)
        trace = Trace()
        # deferred import: serving.api pulls the JAX stack, which the
        # default oracle path should not pay at session import time
        from ..serving.api import get_llm_backend
        # ``tenant`` is forwarded only when set: pre-tenancy backends
        # (registered with a priority-only ``make``) keep working for
        # default-tenant runs — the tenancy-off parity contract.
        mk_kwargs: dict = {"priority": spec.priority}
        if spec.tenant:
            mk_kwargs["tenant"] = spec.tenant
        llm = (spec.backend_factory(world, policy, trace)
               if spec.backend_factory
               else get_llm_backend(spec.llm).make(world, policy, trace,
                                                   **mk_kwargs))
        pattern = spec.pattern if graph is None else "agentx-compiled"
        runner = create_runner(pattern, llm, env.clients, world, trace,
                               deployment=spec.deployment,
                               remote=backend.capabilities.remote,
                               on_event=self._combined_observer(on_event),
                               retry=self.retry, hedge=self.hedge,
                               tenant=spec.tenant)
        if graph is not None:
            from ..plans.execute import PlanDeviation
            runner.bind_graph(graph)
            deviation: Tuple = (PlanDeviation,)
        else:
            deviation = ()

        # durable-execution instrumentation — subscriber order matters:
        #   1. replay cursor: verifies each re-emitted prefix event BEFORE
        #      the journal writer sees it (a deviating event must not be
        #      appended) and snapshots the Eq. 2 FaaS cost at the resume
        #      boundary;
        #   2. journal writer: appends the (verified) event to disk;
        #   3. crash guard: an injected kill fires AFTER the event is
        #      journaled, so a crashed segment ends exactly at its last
        #      committed event.
        if resume is not None:
            from ..durable.resume import ReplayCursor, ResumeDeviation
            boundary: dict = {}
            cursor = ReplayCursor(
                resume.events,
                on_boundary=lambda: boundary.setdefault(
                    "faas_cost", backend.cost()))
            runner.subscribe(cursor.check)
            deviation = deviation + (ResumeDeviation,)
        jw = None
        if self.journal is not None:
            jkey = self.journal.key_for(spec)
            if jkey is not None:
                jw = (self.journal.resume_writer(resume)
                      if resume is not None
                      else self.journal.begin(jkey, spec))
                runner.subscribe(jw.append)
        n_committed = len(resume.events) if resume is not None else 0
        crash_at = backend.crash_point(world, attempt)
        if crash_at is not None and crash_at > n_committed:
            # crash only in live territory: a platform cannot kill work
            # that is already committed history (the replayed prefix);
            # and a kill landing on the terminal event arrived after the
            # run already completed-and-committed — no crash (same rule
            # as a draw beyond the run's natural length)
            from ..core.events import RunCompleted
            counter = {"n": 0}

            def _crash_guard(event):
                counter["n"] += 1
                if (counter["n"] == crash_at
                        and not isinstance(event, RunCompleted)):
                    backend.record_crash()
                    raise RunAborted(
                        f"injected platform crash at event {crash_at}")

            runner.subscribe(_crash_guard)

        t0 = world.clock.now()
        failure = ""
        aborted = False
        try:
            if key is not None and graph is None:
                from ..core.events import PlanCacheMiss, PlanFallback
                if fallback is not None:
                    runner.emit(PlanFallback(t=world.clock.now(), key=key,
                                             reason=fallback[0],
                                             stage=fallback[1]))
                else:
                    runner.emit(PlanCacheMiss(t=world.clock.now(), key=key))
            outcome = runner.run(task)
        except deviation:
            # compiled/journal replay diverged: drop the writer's
            # unfsynced tail, release the environment and let the caller
            # re-run the spec from scratch
            if jw is not None:
                jw.abort()
            backend.teardown()
            raise
        except RunAborted as e:
            # simulated platform death: the journal keeps only what
            # survived the last fsync barrier; the result is partial and
            # must never be cached (see Session.execute)
            if jw is not None:
                jw.abort()
            outcome = RunOutcome(completed=False)
            failure = f"aborted: {e}"
            aborted = True
        except Exception as e:  # pattern-level crash counts as failed run
            outcome = RunOutcome(completed=False)
            failure = f"{type(e).__name__}: {e}"
        total_latency = world.clock.now() - t0

        path, artifact = _artifact(policy, env.workspace, env.s3)
        success = outcome.get("completed", False) and artifact is not None
        if spec.app == "stock_correlation" and artifact is not None:
            score = judge_stock(world, policy.companies, policy.filename,
                                path, artifact)
            # dummy-data plots count as failures (paper §6.4)
            if score.attributes["Data Accuracy"] < 20.0:
                success = False
                failure = failure or "plot used dummy/fabricated data"
        if key is not None and graph is None and success:
            # fresh run under an active plan cache: lift the trace into a
            # graph so the next same-template spec replays planner-free
            from ..core.events import PlanCompiled
            from ..plans.compile import compile_trace
            g = compile_trace(runner.events, app=spec.app,
                              pattern=spec.pattern, instance=spec.instance,
                              seed=spec.seed, deployment=spec.deployment)
            if g is not None:
                self.plan_cache.put(key, g)
                runner.emit(PlanCompiled(t=world.clock.now(), key=key,
                                         template=g.template,
                                         stages=len(g.stages),
                                         nodes=len(g.nodes),
                                         dyn_nodes=g.dyn_nodes))
        if jw is not None and not jw.closed:
            jw.close()
        backend.teardown()

        extras = {"world": world, "policy": policy, "outcome": outcome,
                  "spec": spec, "events": runner.events}
        if aborted:
            extras["aborted"] = True
        if resume is not None:
            from ..durable.resume import recovered_stats
            info = recovered_stats(resume.events)
            info["attempt"] = attempt
            info["recovered_faas_cost"] = boundary.get("faas_cost", 0.0)
            extras["resume"] = info
        return RunResult(app=spec.app, instance=spec.instance,
                         pattern=spec.pattern, deployment=spec.deployment,
                         success=success, total_latency=total_latency,
                         trace=trace, artifact_path=path, artifact=artifact,
                         faas_cost=backend.cost(), failure_reason=failure,
                         extras=extras)

    def _combined_observer(self, extra: Optional[Callable]):
        subs = [fn for fn in (self.on_event, extra) if fn is not None]
        if not subs:
            return None
        if len(subs) == 1:
            return subs[0]
        return lambda ev: [fn(ev) for fn in subs]

    # ------------------------------------------------------------------
    def execute_many(self, specs: Iterable[RunSpec],
                     max_workers: int = 1) -> List[RunResult]:
        """Execute many specs, thread-pooled across ``max_workers``.

        Results preserve spec order and are bit-identical to serial
        execution: every run builds its own World/clock/clients, and MCP
        request IDs are per-client, so no state is shared across runs.
        """
        specs = list(specs)
        if max_workers <= 1 or len(specs) <= 1:
            return [self.execute(s) for s in specs]
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            return list(pool.map(self.execute, specs))

    # ------------------------------------------------------------------
    async def execute_many_async(self, specs: Iterable[RunSpec],
                                 arrivals: Optional[Iterable[float]] = None,
                                 max_concurrency: int = 0) -> List[RunResult]:
        """Asyncio fan-out: interleave many runs on ONE event loop with a
        shared virtual-clock timeline (:mod:`repro.traffic.driver`) — no
        thread per run.  Results preserve spec order and are bit-identical
        to serial :meth:`execute` (every run still builds its own
        World/clock/clients; the timeline only *interleaves* their
        recorded latencies).

        ``arrivals`` (virtual seconds, one per spec) staggers run start
        times; ``max_concurrency`` caps in-flight runs — excess arrivals
        queue in FIFO order and their wait shows up on the timeline, not
        in ``RunResult.total_latency``.  Call from an event loop::

            results = asyncio.run(session.execute_many_async(specs))
        """
        # deferred import: the traffic layer sits above the session API
        from ..traffic.driver import drive_specs
        records = await drive_specs(self, list(specs), arrivals=arrivals,
                                    max_concurrency=max_concurrency)
        return [r.result for r in records]

    # ------------------------------------------------------------------
    def run_until_n_successes(self, spec: RunSpec, n: int = 5,
                              max_runs: int = 40
                              ) -> Tuple[List[RunResult], List[RunResult]]:
        """Paper success-rate protocol (§5.4.2): run seeds ``spec.seed,
        spec.seed+1, ...`` until N successes; success rate = N / total
        runs needed."""
        successes: List[RunResult] = []
        runs: List[RunResult] = []
        seed = spec.seed
        while len(successes) < n and len(runs) < max_runs:
            r = self.execute(spec.with_seed(seed))
            runs.append(r)
            if r.success:
                successes.append(r)
            seed += 1
        return successes, runs


def score_run(result: RunResult) -> Score:
    world = result.extras.get("world")
    policy = result.extras.get("policy")
    if world is None or policy is None:
        world, policy = _rebuild_env(result)
    if result.app == "stock_correlation":
        return judge_stock(world, policy.companies, policy.filename,
                           result.artifact_path, result.artifact)
    query = getattr(policy, "query", getattr(policy, "title", ""))
    return judge_summary(world, query, result.artifact, result.app)


def _rebuild_env(result: RunResult) -> Tuple[World, Any]:
    """Reconstruct the (world, policy) pair for a disk-replayed result.

    Both are deterministic functions of the spec: the World's ground
    truth derives from the stable spec seed at construction, and
    policies draw from their own ``random.Random(seed)`` — so a rebuild
    scores identically to the original in-memory extras."""
    spec = result.extras.get("spec")
    if spec is None:
        seed = result.extras.get("seed")
        if seed is None:
            raise KeyError(
                "cannot score this result: no extras and no stored seed")
        spec = RunSpec(result.app, result.instance, result.pattern,
                       result.deployment, seed)
    world = World(seed=stable_world_seed(spec))
    remote = resolve_deployment(spec.deployment).capabilities.remote
    task = APPS[spec.app].prompt(spec.instance, remote)
    policy = POLICIES[spec.app](world, task, spec.deployment, spec.seed)
    return world, policy
