"""Session / RunSpec orchestration API.

``RunSpec`` names one end-to-end run (app, instance, pattern, deployment,
seed); ``Session`` executes specs — one at a time (``execute``) or as a
thread-pooled batch (``execute_many``). Batch fan-out is safe because each
run owns its ``World`` (virtual clock, corpora, RNGs), its MCP clients and
its trace; results are bit-identical to serial execution on the same
specs.

    from repro.apps.session import RunSpec, Session

    session = Session()
    result = session.execute(RunSpec("web_search", "quantum", "agentx"))
    batch = session.execute_many(
        [RunSpec("web_search", "quantum", "agentx", seed=s)
         for s in range(8)], max_workers=4)

Observers subscribe to the typed run-event stream with
``Session(on_event=fn)`` — ``fn`` receives every
:class:`repro.core.events.RunEvent` live (from worker threads under
``execute_many``).
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Iterable, List, Optional, Tuple

from ..core.llm import OracleLLMBackend
from ..core.metrics import RunResult, Trace
from ..core.policies import POLICIES
from ..core.runtime import RunOutcome, create_runner
from ..env.world import World
from ..eval.judge import Score, judge_stock, judge_summary
from ..faas.deployments import (deploy_distributed, deploy_local,
                                deploy_monolithic)
from ..faas.platform import FaaSPlatform
from .apps import APPS


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One (app, instance, pattern, deployment, seed) run.

    deployment: "local" (Fig. 2a) | "faas" (distributed, Fig. 2c) |
    "faas-mono" (monolithic, Fig. 2b — beyond-paper benchmark).
    """
    app: str
    instance: str
    pattern: str
    deployment: str = "local"
    seed: int = 0
    backend_factory: Optional[Callable] = None

    def with_seed(self, seed: int) -> "RunSpec":
        return dataclasses.replace(self, seed=seed)


def _artifact(policy, workspace, s3) -> Tuple[Optional[str], Optional[str]]:
    """Locate the expected output artifact in whichever store it landed."""
    name = policy.artifact
    candidates = [policy.out_target(name), name,
                  f"s3://dummy-bucket/agent/{name}"]
    for store in (s3, workspace):
        if store is None:
            continue
        for path in candidates:
            if store.exists(path):
                return path, store.read(path)
        # fuzzy: suffix match (agents sometimes pick their own path)
        for path in store.list():
            if path.endswith(name.split("/")[-1]):
                return path, store.read(path)
    return None, None


class Session:
    """Executes RunSpecs against fresh per-run environments."""

    def __init__(self,
                 on_event: Optional[Callable] = None):
        self.on_event = on_event

    # ------------------------------------------------------------------
    def execute(self, spec: RunSpec,
                on_event: Optional[Callable] = None) -> RunResult:
        """Execute one run end-to-end: deploy MCP servers, run the
        pattern, locate + judge the artifact, account costs."""
        app = APPS[spec.app]
        world = World(seed=spec.seed * 9176
                      + hash((spec.app, spec.instance, spec.pattern,
                              spec.deployment)) % 10_000)
        faas = spec.deployment != "local"
        task = app.prompt(spec.instance, faas)

        platform = None
        workspace = None
        if spec.deployment == "local":
            clients, workspace = deploy_local(world, app.servers)
            s3 = None
        else:
            platform = FaaSPlatform(world)
            if spec.deployment == "faas-mono":
                clients = deploy_monolithic(world, platform, app.servers)
            else:
                clients = deploy_distributed(world, platform, app.servers)
            s3 = platform.s3
            platform.reset_accounting()  # deployment cold-starts not billed
            world.clock.reset()

        policy = POLICIES[spec.app](world, task, spec.deployment, spec.seed)
        trace = Trace()
        backend = (spec.backend_factory(world, policy, trace)
                   if spec.backend_factory
                   else OracleLLMBackend(world, policy, trace))
        runner = create_runner(spec.pattern, backend, clients, world, trace,
                               deployment=spec.deployment,
                               on_event=self._combined_observer(on_event))

        t0 = world.clock.now()
        failure = ""
        try:
            outcome = runner.run(task)
        except Exception as e:  # pattern-level crash counts as failed run
            outcome = RunOutcome(completed=False)
            failure = f"{type(e).__name__}: {e}"
        total_latency = world.clock.now() - t0

        path, artifact = _artifact(policy, workspace, s3)
        success = outcome.get("completed", False) and artifact is not None
        if spec.app == "stock_correlation" and artifact is not None:
            score = judge_stock(world, policy.companies, policy.filename,
                                path, artifact)
            # dummy-data plots count as failures (paper §6.4)
            if score.attributes["Data Accuracy"] < 20.0:
                success = False
                failure = failure or "plot used dummy/fabricated data"
        for client in clients.values():
            client.close()

        faas_cost = platform.total_cost() if platform else 0.0
        return RunResult(app=spec.app, instance=spec.instance,
                         pattern=spec.pattern, deployment=spec.deployment,
                         success=success, total_latency=total_latency,
                         trace=trace, artifact_path=path, artifact=artifact,
                         faas_cost=faas_cost, failure_reason=failure,
                         extras={"world": world, "policy": policy,
                                 "outcome": outcome, "spec": spec,
                                 "events": runner.events})

    def _combined_observer(self, extra: Optional[Callable]):
        subs = [fn for fn in (self.on_event, extra) if fn is not None]
        if not subs:
            return None
        if len(subs) == 1:
            return subs[0]
        return lambda ev: [fn(ev) for fn in subs]

    # ------------------------------------------------------------------
    def execute_many(self, specs: Iterable[RunSpec],
                     max_workers: int = 1) -> List[RunResult]:
        """Execute many specs, thread-pooled across ``max_workers``.

        Results preserve spec order and are bit-identical to serial
        execution: every run builds its own World/clock/clients, and MCP
        request IDs are per-client, so no state is shared across runs.
        """
        specs = list(specs)
        if max_workers <= 1 or len(specs) <= 1:
            return [self.execute(s) for s in specs]
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            return list(pool.map(self.execute, specs))

    # ------------------------------------------------------------------
    def run_until_n_successes(self, spec: RunSpec, n: int = 5,
                              max_runs: int = 40
                              ) -> Tuple[List[RunResult], List[RunResult]]:
        """Paper success-rate protocol (§5.4.2): run seeds ``spec.seed,
        spec.seed+1, ...`` until N successes; success rate = N / total
        runs needed."""
        successes: List[RunResult] = []
        runs: List[RunResult] = []
        seed = spec.seed
        while len(successes) < n and len(runs) < max_runs:
            r = self.execute(spec.with_seed(seed))
            runs.append(r)
            if r.success:
                successes.append(r)
            seed += 1
        return successes, runs


def score_run(result: RunResult) -> Score:
    world = result.extras["world"]
    policy = result.extras["policy"]
    if result.app == "stock_correlation":
        return judge_stock(world, policy.companies, policy.filename,
                           result.artifact_path, result.artifact)
    query = getattr(policy, "query", getattr(policy, "title", ""))
    return judge_summary(world, query, result.artifact, result.app)
