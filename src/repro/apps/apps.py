"""The three templated applications (paper §5.3), each with three instances."""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass(frozen=True)
class AppSpec:
    name: str
    template: str
    instances: Dict[str, str]          # instance key -> template variable(s)
    servers: List[str]                 # MCP servers required (local names)

    def prompt(self, instance: str, faas: bool) -> str:
        var = self.instances[instance]
        task = self.template.format(var=var)
        if faas:
            task += (" ...you can read/write from s3 from this location: "
                     "'s3://dummy-bucket/agent/'")
        return task


WEB_SEARCH = AppSpec(
    name="web_search",
    template="Search for {var} and summarize the results in a text file",
    instances={
        "quantum": "Recent advancements in quantum computing hardware development",
        "edge": "Edge devices and their real-world use cases in 2025",
        "materials": "Latest trends in biodegradable materials for sustainable packaging",
    },
    servers=["serper", "fetch", "filesystem"],
)

STOCK_CORRELATION = AppSpec(
    name="stock_correlation",
    template="Generate a plot for the historic stock prices of {var}",
    instances={
        "apple": ("Apple, Alphabet (Google), and Microsoft, and save it as "
                  "AppleAlphabetMicrosoft.png"),
        "netflix": ("Netflix, Disney, and Amazon, and save it as "
                    "NetflixDisneyAmazon.png"),
        "cola": ("Coca-Cola, PepsiCo, and Mondelez, and save it as "
                 "CocaColaPepsiCoMondelez.png"),
    },
    servers=["yfinance", "code-execution", "filesystem"],
)

RESEARCH_REPORT = AppSpec(
    name="research_report",
    template=("Generate a report on the Core Contributions, Methodology, "
              "Experimental Results, and Limitations for the paper titled "
              "{var} and save it as a text file."),
    instances={
        "why": "'Why Do Multi-Agent LLM Systems Fail?'",
        "flow": "'Flow: Modularized Agentic Workflow Automation'",
        "magentic": ("'Magentic-One: A Generalist Multi-Agent System for "
                     "Solving Complex Tasks.'"),
    },
    servers=["arxiv", "rag", "filesystem"],
)

MULTI_TOPIC = AppSpec(
    name="multi_topic_digest",
    template="Search for {var} and write a combined digest to a text file",
    instances={
        "tech": ("'Recent advancements in quantum computing hardware "
                 "development'; 'Edge devices and their real-world use "
                 "cases in 2025'; 'Latest trends in biodegradable "
                 "materials for sustainable packaging'"),
    },
    servers=["serper", "filesystem"],
)

APPS = {a.name: a for a in (WEB_SEARCH, STOCK_CORRELATION, RESEARCH_REPORT,
                            MULTI_TOPIC)}
