"""Unified decoder LM covering all six assigned architecture families.

Entry points (all pure functions over a params pytree):
  forward(params, cfg, tokens, frontend_embeds=None)  -> logits (train path)
  loss_fn(params, cfg, batch)                          -> (loss, metrics)
  prefill(params, cfg, tokens, frontend_embeds=None)   -> (last_logits, cache)
  decode_step(params, cfg, cache, token, pos)          -> (logits, cache)
      pos: scalar OR (B,) per-sequence position vector (slot batching)
  init_cache(cfg, batch, cache_len, dtype)             -> cache pytree

Layers are lax.scan-stacked; hybrid (Zamba2) uses a two-level scan with a
weight-shared attention block closed over by the group body.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .moe import moe_ffn
from .ssm import mamba2_block
from .sharding_ctx import constrain

Params = Dict[str, Any]

# When True, lax.scan over layers is fully unrolled. Used by the roofline
# probes (repro.launch.probe): XLA cost_analysis counts while-bodies once,
# so probes compile small unrolled variants to get per-layer costs.
SCAN_UNROLL = False

# When set to a Mesh, MoE layers use the shard_map expert-parallel path
# (inference; see repro.models.moe_shardmap).
MOE_SHARDMAP_MESH = None

# Remat policy for the layer scan: None = full remat (recompute everything
# in backward), "dots" = save matmul outputs, recompute only cheap
# elementwise ops (jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
REMAT_POLICY = None


def _checkpoint(f):
    if REMAT_POLICY == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=True if SCAN_UNROLL else 1)


# ---------------------------------------------------------------------------
# Layer bodies


def _attn_block(p: Params, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array, window: int) -> Tuple[jax.Array, jax.Array]:
    h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    if cfg.attention == "mla":
        a = L.mla_attention(p["attn"], h, cfg, positions, window)
    else:
        a = L.gqa_attention(p["attn"], h, cfg, positions, window)
    x = x + a
    h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        if MOE_SHARDMAP_MESH is not None:
            from .moe_shardmap import moe_ffn_shardmap
            y, aux = moe_ffn_shardmap(p["moe"], h, cfg, MOE_SHARDMAP_MESH)
        else:
            y, aux = moe_ffn(p["moe"], h, cfg)
    else:
        y, aux = L.mlp(p["mlp"], h, cfg.mlp_type), jnp.float32(0.0)
    return x + y, aux


def _ssm_block(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    return x + mamba2_block(p["ssm"], h, cfg)


# ---------------------------------------------------------------------------
# Embedding / head


def _embed(params: Params, cfg: ModelConfig, tokens: jax.Array,
           frontend_embeds: Optional[jax.Array]) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return constrain(x, "activations")


def _lm_head(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return constrain(logits, "logits")


# ---------------------------------------------------------------------------
# Forward (training / scoring)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None,
            remat: bool = True,
            return_hidden: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S_total, V), aux_loss); with
    ``return_hidden``, returns the final-norm hidden states instead of
    logits (chunked-xent path)."""
    x = _embed(params, cfg, tokens, frontend_embeds)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    window = cfg.sliding_window

    if cfg.arch_type == "hybrid":
        x, aux = _hybrid_stack(params, cfg, x, positions, window, remat)
        if return_hidden:
            return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux
        return _lm_head(params, cfg, x), aux
    elif cfg.arch_type == "ssm":
        def body(carry, lp):
            return _ssm_block(lp, carry, cfg), None
        if remat:
            body = _checkpoint(body)
        x, _ = _scan(body, x, params["layers"])
        aux = jnp.float32(0.0)
        if return_hidden:
            return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux
        return _lm_head(params, cfg, x), aux
    else:
        def body(carry, lp):
            x, aux = carry
            x, a = _attn_block(lp, x, cfg, positions, window)
            return (x, aux + a), None
        if remat:
            body = _checkpoint(body)
        (x, aux), _ = _scan(body, (x, jnp.float32(0.0)), params["layers"])
    if return_hidden:
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux
    return _lm_head(params, cfg, x), aux


def _hybrid_stack(params, cfg, x, positions, window, remat):
    def ssm_body(carry, lp):
        return _ssm_block(lp, carry, cfg), None
    if remat:
        ssm_body = _checkpoint(ssm_body)

    shared = params["shared_attn"]

    def group_body(carry, gp):
        x, aux = carry
        x, _ = _scan(ssm_body, x, gp)
        x, a = _attn_block(shared, x, cfg, positions, window)
        return (x, aux + a), None

    (x, aux), _ = _scan(group_body, (x, jnp.float32(0.0)),
                               params["groups"])
    if "rem" in params:
        x, _ = _scan(ssm_body, x, params["rem"])
    return x, aux


# >0: cross-entropy computed in sequence chunks of this many positions —
# the (B, S, V) logits tensor never materializes (peak-memory lever for
# large-vocab training; EXPERIMENTS.md §Perf deepseek iteration 7).
XENT_CHUNK = 0


def _chunked_xent(params, cfg, hidden, targets):
    """hidden: (B, S, d) final-norm states; targets: (B, S) int32."""
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    B, S, d = hidden.shape
    C = XENT_CHUNK
    pad = (-S) % C
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nc = (S + pad) // C
    hc = hidden.reshape(B, nc, C, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, C).transpose(1, 0, 2)
    valid = (jnp.arange(S + pad) < S).reshape(nc, C)

    @jax.checkpoint
    def chunk(carry, inp):
        h, t, v = inp                              # (B,C,d),(B,C),(C,)
        logits = (h @ head).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return carry + jnp.sum((logz - gold) * v[None, :]), None

    total, _ = jax.lax.scan(chunk, jnp.float32(0.0), (hc, tc, valid))
    return total / (B * S)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: {"tokens": (B,S), optional "frontend_embeds": (B,P,d)}.
    Next-token loss over the token positions only."""
    tokens = batch["tokens"]
    fe = batch.get("frontend_embeds")
    if XENT_CHUNK:
        hidden, aux = forward(params, cfg, tokens, fe, return_hidden=True)
        P = 0 if fe is None else fe.shape[1]
        if P == 0:
            h = hidden[:, :-1]
            tgt = tokens[:, 1:]
        else:
            h = hidden[:, P - 1:-1]
            tgt = tokens
        nll = _chunked_xent(params, cfg, h, tgt)
        loss = nll + aux
        return loss, {"nll": nll, "aux": aux}
    logits, aux = forward(params, cfg, tokens, fe)
    P = 0 if fe is None else fe.shape[1]
    # logits position P+i-1 predicts tokens[:, i]
    if P == 0:
        pred = logits[:, :-1]
        tgt = tokens[:, 1:]
    else:
        pred = logits[:, P - 1:-1]
        tgt = tokens
    pred = pred.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    loss = nll + aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Caches


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.float32) -> Dict[str, Any]:
    window = cfg.sliding_window
    C = min(cache_len, window) if window else cache_len

    def gqa_cache(stack=()):
        shape = (*stack, batch, C, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    def mla_cache(stack=()):
        m = cfg.mla
        return {"ckv": jnp.zeros((*stack, batch, C, m.kv_lora_rank), dtype),
                "kpe": jnp.zeros((*stack, batch, C, m.qk_rope_dim), dtype)}

    def ssm_state(stack=()):
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        nh = s.n_heads(cfg.d_model)
        ch = di + 2 * s.d_state
        return {
            "conv": jnp.zeros((*stack, batch, s.conv_width - 1, ch), dtype),
            "ssd": jnp.zeros((*stack, batch, nh, s.head_dim, s.d_state), dtype),
        }

    if cfg.arch_type == "hybrid":
        G = cfg.n_layers // cfg.hybrid_attn_every
        per = cfg.hybrid_attn_every - 1
        R = cfg.n_layers - G * cfg.hybrid_attn_every
        cache = {"groups": ssm_state((G, per)),
                 "attn": (mla_cache((G,)) if cfg.attention == "mla"
                          else gqa_cache((G,)))}
        if R:
            cache["rem"] = ssm_state((R,))
        return cache
    if cfg.arch_type == "ssm":
        return {"layers": ssm_state((cfg.n_layers,))}
    if cfg.attention == "mla":
        return {"layers": mla_cache((cfg.n_layers,))}
    return {"layers": gqa_cache((cfg.n_layers,))}


# ---------------------------------------------------------------------------
# Paged caches (block-paged serving; see repro.serving.paging)
#
# The paged decode cache replaces each attention leaf's dense
# (stack, B, C, ...) layout with a block *pool* (stack, n_blocks,
# block_size, ...) plus per-sequence block tables (B, max_blocks) int32.
# Row r of sequence b lives at pool[..., table[b, r // bs], r % bs, ...]
# — every attention-cache leaf (k/v/ckv/kpe) has its block axes at tree
# positions 1 and 2, so gather/scatter are uniform tree_maps.
#
# Parity by construction: ``gather_cache`` materializes the exact dense
# (stack, B, C, ...) view the contiguous path holds (junk rows from
# unallocated table slots are masked by decode's validity mask exactly
# like the contiguous cache's zero rows), so the scheduler can feed the
# gathered view through the SAME jitted ``decode_step`` executable as
# the contiguous path — paged decode is bit-identical, not just close.
# The TPU kernel that avoids the materialized gather is
# ``repro.kernels.decode_attention.paged_decode_attention``.


def supports_paged_cache(cfg: ModelConfig) -> bool:
    """Paged layout covers the attention-cache archs with absolute
    positions (GQA/MLA, no sliding-window ring, no frontend offset, no
    recurrent state — SSM/hybrid states are position-free and gain
    nothing from paging)."""
    return (cfg.arch_type not in ("ssm", "hybrid")
            and not cfg.sliding_window and not cfg.frontend)


def init_paged_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                     dtype=jnp.float32) -> Dict[str, Any]:
    """Block pool pytree: ``init_cache``'s attention leaves with the
    (batch, cache_len) axes replaced by (n_blocks, block_size)."""
    if not supports_paged_cache(cfg):
        raise NotImplementedError(
            f"paged KV covers attention-cache archs; {cfg.name} "
            f"({cfg.arch_type}) keeps the contiguous layout")
    if cfg.attention == "mla":
        m = cfg.mla
        return {"layers": {
            "ckv": jnp.zeros((cfg.n_layers, n_blocks, block_size,
                              m.kv_lora_rank), dtype),
            "kpe": jnp.zeros((cfg.n_layers, n_blocks, block_size,
                              m.qk_rope_dim), dtype)}}
    shape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads,
             cfg.head_dim)
    return {"layers": {"k": jnp.zeros(shape, dtype),
                       "v": jnp.zeros(shape, dtype)}}


def gather_cache(pool: Dict[str, Any], tables: jax.Array) -> Dict[str, Any]:
    """Materialize the dense cache view of ``tables`` (B, max_blocks)
    from a block pool: leaf (L, NB, bs, ...) -> (L, B, max_blocks*bs, ...).
    Unallocated table entries point at the trash block — their junk rows
    sit beyond every sequence's valid length and are masked by decode."""
    def g(leaf):
        v = leaf[:, tables]                    # (L, B, MB, bs, ...)
        return v.reshape(v.shape[0], v.shape[1], v.shape[2] * v.shape[3],
                         *v.shape[4:])
    return jax.tree_util.tree_map(g, pool)


def scatter_cache(pool: Dict[str, Any], cache: Dict[str, Any],
                  table: jax.Array, start: jax.Array) -> Dict[str, Any]:
    """Write a batch-1 dense cache's rows into the pool blocks of one
    sequence.  ``table``: (max_blocks,) int32; rows with position <
    ``start`` are redirected to the trash block (prefix-cache hits: the
    leading blocks are SHARED and already hold identical data — they are
    never rewritten), as are rows in unallocated tail blocks (their
    table entries already point at trash).  One trace total: the write
    always covers the full cache length."""
    def s(pool_leaf, cache_leaf):
        bs = pool_leaf.shape[2]
        c = cache_leaf.shape[2]
        positions = jnp.arange(c)
        blk = table[positions // bs]
        trash = pool_leaf.shape[1] - 1
        blk = jnp.where(positions < start, trash, blk)
        return pool_leaf.at[:, blk, positions % bs].set(
            cache_leaf[:, 0].astype(pool_leaf.dtype))
    return jax.tree_util.tree_map(s, pool, cache)


def scatter_decode_rows(pool: Dict[str, Any], cache: Dict[str, Any],
                        tables: jax.Array, pos: jax.Array) -> Dict[str, Any]:
    """Write the rows ``decode_step`` just produced (one per sequence,
    at that sequence's position) from the dense view back into the pool.
    ``tables``: (B, MB) int32 — dead slots' all-trash tables land their
    writes in the trash block."""
    def s(pool_leaf, cache_leaf):
        bs = pool_leaf.shape[2]
        c = cache_leaf.shape[2]
        b = cache_leaf.shape[1]
        slot = jnp.minimum(jnp.asarray(pos, jnp.int32), c - 1)
        rows = jnp.arange(b)
        blk = tables[rows, slot // bs]
        vals = cache_leaf[:, rows, slot]       # (L, B, ...)
        return pool_leaf.at[:, blk, slot % bs].set(
            vals.astype(pool_leaf.dtype))
    return jax.tree_util.tree_map(s, pool, cache)


def copy_block(pool: Dict[str, Any], src: jax.Array,
               dst: jax.Array) -> Dict[str, Any]:
    """Copy one physical block (copy-on-write fork: the allocator moved
    a shared reference onto ``dst``; the data follows here)."""
    return jax.tree_util.tree_map(
        lambda leaf: leaf.at[:, dst].set(leaf[:, src]), pool)


def paged_decode_step(params: Params, cfg: ModelConfig,
                      pool: Dict[str, Any], tables: jax.Array,
                      token: jax.Array, pos: jax.Array
                      ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step against the block pool: gather the dense view,
    run the ordinary :func:`decode_step`, scatter the written rows back.
    Convenience composition for tests/benchmarks — the scheduler runs
    the three stages through its own jits so the middle one is the SAME
    compiled executable as the contiguous path (the parity mechanism)."""
    view = gather_cache(pool, tables)
    logits, new_view = decode_step(params, cfg, view, token, pos)
    return logits, scatter_decode_rows(pool, new_view, tables, pos)


# ---------------------------------------------------------------------------
# Prefill


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Full-sequence prefill; returns (last-position logits, cache of len S).

    Note: the serving engine copies this cache into its ring/max-len buffers;
    for dry-run purposes the cache length equals the prompt length.
    """
    x = _embed(params, cfg, tokens, frontend_embeds)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    window = cfg.sliding_window

    if cfg.arch_type in ("ssm", "hybrid"):
        return _recurrent_prefill(params, cfg, x, positions, window)

    def body(x, lp):
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        if cfg.attention == "mla":
            a, kv = L.mla_prefill(lp["attn"], h, cfg, positions, window)
        else:
            a, kv = L.gqa_prefill(lp["attn"], h, cfg, positions, window)
        x = x + a
        h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        if cfg.is_moe:
            if MOE_SHARDMAP_MESH is not None:
                from .moe_shardmap import moe_ffn_shardmap
                y, _ = moe_ffn_shardmap(lp["moe"], h, cfg,
                                        MOE_SHARDMAP_MESH)
            else:
                y, _ = moe_ffn(lp["moe"], h, cfg)
        else:
            y = L.mlp(lp["mlp"], h, cfg.mlp_type)
        return x + y, kv

    x, cache = _scan(body, x, params["layers"])
    logits = _lm_head(params, cfg, x[:, -1:])
    return logits[:, 0], {"layers": cache}


def _recurrent_prefill(params, cfg, x, positions, window):
    def ssm_body(x, lp):
        h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
        out, st = mamba2_block(lp["ssm"], h, cfg, return_state=True)
        return x + out, st

    if cfg.arch_type == "ssm":
        x, states = _scan(ssm_body, x, params["layers"])
        logits = _lm_head(params, cfg, x[:, -1:])
        return logits[:, 0], {"layers": states}

    shared = params["shared_attn"]

    def group_body(x, gp):
        x, st = _scan(ssm_body, x, gp)
        h = L.rms_norm(x, shared["attn_norm"], cfg.norm_eps)
        a, kv = L.gqa_prefill(shared["attn"], h, cfg, positions, window)
        x = x + a
        h = L.rms_norm(x, shared["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp(shared["mlp"], h, cfg.mlp_type)
        return x, {"ssm": st, "attn": kv}

    x, out = _scan(group_body, x, params["groups"])
    cache = {"groups": out["ssm"], "attn": out["attn"]}
    if "rem" in params:
        x, st = _scan(ssm_body, x, params["rem"])
        cache["rem"] = st
    logits = _lm_head(params, cfg, x[:, -1:])
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Fixed-shape prefill (serving admission: bucketed batches + chunks)


def prefill_attend(params: Params, cfg: ModelConfig, cache: Dict[str, Any],
                   tokens: jax.Array, off: jax.Array, lengths: jax.Array
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Prefill ``tokens`` (B, L) into an explicit full-length cache at
    absolute offset ``off``.

    The serving engine's fixed-shape prefill recipe: rows are
    right-padded to a shared length L (a power-of-two bucket or a chunk),
    K/V are scattered into the cache at absolute positions, and every
    query attends over the full cache width under a validity mask — so
    the attention reduction shape never depends on the prompt length.
    One jitted trace serves a whole bucket (no per-length recompiles),
    and a prompt prefilled whole, in chunks, or inside a batch produces
    bit-identical cache rows and logits.

    tokens: (B, L) int32 right-padded rows; off: scalar int32 absolute
    position of column 0 (0 for whole prompts, the running offset for
    chunk continuation); lengths: (B,) valid token counts in this call.
    Returns (logits (B, V) at each row's last valid position, new cache).
    Attention-cache archs without sliding window / frontend only —
    recurrent-state archs keep the exact-length recipe
    (:func:`prefill`).
    """
    if cfg.arch_type in ("ssm", "hybrid") or cfg.sliding_window or cfg.frontend:
        raise NotImplementedError(
            "fixed-shape prefill covers non-windowed attention caches; "
            f"{cfg.name} ({cfg.arch_type}) uses the exact-length recipe")
    x = _embed(params, cfg, tokens, None)
    b, s, _ = x.shape
    positions = off + jnp.arange(s)[None, :]

    def body(x, inp):
        lp, kv = inp
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        if cfg.attention == "mla":
            a, kv2 = L.mla_prefill_attend(lp["attn"], h, kv, cfg, positions)
        else:
            a, kv2 = L.gqa_prefill_attend(lp["attn"], h, kv, cfg, positions)
        x = x + a
        h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        if cfg.is_moe:
            if MOE_SHARDMAP_MESH is not None:
                from .moe_shardmap import moe_ffn_shardmap
                y, _ = moe_ffn_shardmap(lp["moe"], h, cfg, MOE_SHARDMAP_MESH)
            else:
                y, _ = moe_ffn(lp["moe"], h, cfg)
        else:
            y = L.mlp(lp["mlp"], h, cfg.mlp_type)
        return x + y, kv2

    x, kvs = _scan(body, x, (params["layers"], cache["layers"]))
    idx = jnp.clip(lengths - 1, 0, s - 1)[:, None, None]
    hid = jnp.take_along_axis(x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])),
                              axis=1)
    logits = _lm_head(params, cfg, hid)
    return logits[:, 0], {"layers": kvs}


def prefill_fresh(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  lengths: jax.Array, cache_len: int
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Whole-prompt fixed-shape prefill: a zero cache of ``cache_len``
    built inside the jit, then :func:`prefill_attend` at offset 0 —
    THE admission recipe for bucketed (batched) prefill."""
    cache = init_cache(cfg, tokens.shape[0], cache_len,
                       dtype=params["embed"].dtype)
    return prefill_attend(params, cfg, cache, tokens, jnp.int32(0), lengths)


# ---------------------------------------------------------------------------
# Decode


def decode_step(params: Params, cfg: ModelConfig, cache: Dict[str, Any],
                token: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """token: (B, 1) int32; pos: scalar int32 absolute position shared by
    the batch, OR a (B,) int32 vector of per-sequence positions — the
    continuous-batching serving path advances all live slots in one call,
    each at its own position.  Returns (logits (B, V), new cache)."""
    x = jnp.take(params["embed"], token, axis=0)
    x = constrain(x, "activations")
    window = cfg.sliding_window
    pos = L.decode_positions(pos, token.shape[0])

    if cfg.arch_type == "hybrid":
        x, new_cache = _hybrid_decode(params, cfg, cache, x, pos, window)
    elif cfg.arch_type == "ssm":
        def body(x, inp):
            lp, st = inp
            h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
            out, st2 = mamba2_block(lp["ssm"], h, cfg, state=st)
            return x + out, st2
        x, states = _scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": states}
    else:
        def body(x, inp):
            lp, kv = inp
            h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            if cfg.attention == "mla":
                a, kv2 = L.mla_decode(lp["attn"], h, kv, cfg, pos, window)
            else:
                a, kv2 = L.gqa_decode(lp["attn"], h, kv, cfg, pos, window)
            x = x + a
            h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            if cfg.is_moe:
                y, _ = moe_ffn(lp["moe"], h, cfg)
            else:
                y = L.mlp(lp["mlp"], h, cfg.mlp_type)
            return x + y, kv2
        x, kvs = _scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": kvs}

    logits = _lm_head(params, cfg, x)
    return logits[:, 0], new_cache


def _hybrid_decode(params, cfg, cache, x, pos, window):
    shared = params["shared_attn"]

    def ssm_body(x, inp):
        lp, st = inp
        h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
        out, st2 = mamba2_block(lp["ssm"], h, cfg, state=st)
        return x + out, st2

    def group_body(x, inp):
        gp, st, kv = inp
        x, st2 = _scan(ssm_body, x, (gp, st))
        h = L.rms_norm(x, shared["attn_norm"], cfg.norm_eps)
        a, kv2 = L.gqa_decode(shared["attn"], h, kv, cfg, pos, window)
        x = x + a
        h = L.rms_norm(x, shared["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp(shared["mlp"], h, cfg.mlp_type)
        return x, (st2, kv2)

    x, (sts, kvs) = _scan(
        group_body, x, (params["groups"], cache["groups"], cache["attn"]))
    new_cache = {"groups": sts, "attn": kvs}
    if "rem" in params:
        x, st = _scan(ssm_body, x, (params["rem"], cache["rem"]))
        new_cache["rem"] = st
    return x, new_cache
