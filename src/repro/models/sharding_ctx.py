"""Activation-sharding constraint context.

Model code calls ``constrain(x, "tokens")`` etc.; outside a mesh context this
is a no-op, inside ``repro.launch`` wrappers it applies
``with_sharding_constraint`` with the active policy's PartitionSpec. This
keeps model code mesh-agnostic while letting the launcher steer GSPMD.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def _policy() -> Optional[Dict[str, jax.sharding.PartitionSpec]]:
    return getattr(_state, "policy", None)


@contextlib.contextmanager
def activation_policy(policy: Dict[str, jax.sharding.PartitionSpec]):
    prev = _policy()
    _state.policy = policy
    try:
        yield
    finally:
        _state.policy = prev


def constrain(x, name: str):
    pol = _policy()
    if pol is None or name not in pol:
        return x
    spec = pol[name]
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
