"""Core transformer layers: RMSNorm, RoPE, MLPs, GQA and MLA attention.

Pure-function style: every layer is ``f(params: dict, x, ...) -> y``.
Parameter dictionaries are created in ``repro.models.params``.

Decode variants operate on an explicit KV cache and one new token per
sequence. Caches are plain dicts of arrays so they shard/scan cleanly.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .sharding_ctx import constrain

# ---------------------------------------------------------------------------
# Norms & MLP


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def mlp(params: dict, x: jax.Array, mlp_type: str = "swiglu") -> jax.Array:
    if mlp_type == "swiglu":
        gate = x @ params["w_gate"]
        up = x @ params["w_up"]
        h = jax.nn.silu(gate) * up
    else:  # gelu, 2-matrix
        h = jax.nn.gelu(x @ params["w_up"])
    h = constrain(h, "ffn_hidden")
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Rotary embeddings


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masks


def causal_mask(q_len: int, kv_len: int, q_offset: int = 0,
                window: int = 0) -> jax.Array:
    """Boolean (q_len, kv_len) mask. True = attend."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    mask = kv_pos <= q_pos
    if window:
        mask &= kv_pos > q_pos - window
    return mask


# ---------------------------------------------------------------------------
# GQA attention (full-sequence / prefill)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array]) -> jax.Array:
    """q: (B,S,Hq,hd) k/v: (B,T,Hkv,hd) with Hq % Hkv == 0."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    q = q.reshape(b, s, hkv, group, hd)
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out.reshape(b, s, hq * hd)


def gqa_attention(params: dict, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array, window: int = 0) -> jax.Array:
    """Full-sequence (training / prefill) GQA attention."""
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "attn_q")
    k = constrain(k, "attn_kv")
    mask = causal_mask(s, s, window=window)
    out = _sdpa(q, k, v, mask)
    return out @ params["wo"]


def gqa_prefill(params: dict, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array, window: int = 0
                ) -> Tuple[jax.Array, dict]:
    """Prefill: same as full attention but also returns the KV cache."""
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    mask = causal_mask(s, s, window=window)
    out = _sdpa(q, k, v, mask)
    cache = {"k": k, "v": v}
    return out @ params["wo"], cache


def gqa_prefill_attend(params: dict, x: jax.Array, cache: dict,
                       cfg: ModelConfig, positions: jax.Array
                       ) -> Tuple[jax.Array, dict]:
    """Fixed-shape GQA prefill against an explicit cache (serving
    admission path: bucketed and chunked prefill).

    x: (B, L, d) — a whole right-padded prompt bucket or one prompt
    chunk. cache: {"k","v"}: (B, C, Hkv, hd) holding the already-prefilled
    prefix (zeros on the first call). positions: (1, L) or (B, L) absolute
    positions of this call's tokens (``off + arange(L)``).

    This call's K/V rows are scattered into the cache at their absolute
    positions FIRST, then every query attends over the full C-column
    cache under a validity mask (col <= q_pos) — so the attention
    reduction has the exact same shape as ``gqa_decode``'s and as every
    other chunk's, which is what keeps chunked, bucketed-batch and serial
    prefill bit-identical (out-of-range scatter rows are dropped; padded
    rows beyond a prompt's true length are masked for real queries and
    later overwritten by decode before ever becoming visible).
    Non-ring caches only: sliding-window archs keep the exact-length
    prefill + ring re-roll recipe.
    """
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    rows = jnp.arange(b)[:, None]
    ck = cache["k"].at[rows, positions].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[rows, positions].set(v.astype(cache["v"].dtype))
    ck = constrain(ck, "kv_cache")
    cv = constrain(cv, "kv_cache")
    cache_len = ck.shape[1]
    valid = jnp.arange(cache_len)[None, None, :] <= positions[..., None]
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    group = hq // hkv
    qh = q.reshape(b, s, hkv, group, cfg.head_dim)
    scores = jnp.einsum("bshgd,bthd->bhgst", qh, ck).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(cfg.head_dim)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, cv)
    out = out.reshape(b, s, hq * cfg.head_dim)
    return out @ params["wo"], {"k": ck, "v": cv}


def mla_prefill_attend(params: dict, x: jax.Array, cache: dict,
                       cfg: ModelConfig, positions: jax.Array
                       ) -> Tuple[jax.Array, dict]:
    """Fixed-shape MLA prefill against an explicit compressed cache —
    the MLA counterpart of :func:`gqa_prefill_attend` (same scatter +
    validity-mask scheme over {"ckv","kpe"} rows, absorbed-form
    attention)."""
    b, s, _ = x.shape
    q_nope, q_rope, ckv_new, k_pe_new = _mla_qkv(params, x, cfg, positions)
    rows = jnp.arange(b)[:, None]
    ckv = cache["ckv"].at[rows, positions].set(
        ckv_new.astype(cache["ckv"].dtype))
    kpe = cache["kpe"].at[rows, positions].set(
        k_pe_new[:, :, 0, :].astype(cache["kpe"].dtype))
    ckv = constrain(ckv, "mla_cache")
    cache_len = ckv.shape[1]
    valid = jnp.arange(cache_len)[None, None, :] <= positions[..., None]
    mask = valid[:, None]                                 # (b,1,s,C)
    out = _mla_attend(params, q_nope, q_rope, ckv, kpe[:, :, None, :],
                      cfg, mask)
    return out, {"ckv": ckv, "kpe": kpe}


def decode_positions(pos: jax.Array, batch: int) -> jax.Array:
    """Normalize a decode position to a per-sequence ``(B,)`` vector.

    Accepts the historical scalar form (one position shared by the whole
    batch) or a ``(B,)`` vector (continuous batching: every slot sits at
    its own absolute position).
    """
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (batch,))
    return pos


def cache_slots(pos: jax.Array, cache_len: int, window: int) -> jax.Array:
    """Per-sequence cache row to write the new token into: ring slot for
    sliding-window caches, clamped absolute position otherwise."""
    return jnp.where(window > 0, pos % cache_len,
                     jnp.minimum(pos, cache_len - 1))


def gqa_decode(params: dict, x: jax.Array, cache: dict, cfg: ModelConfig,
               pos: jax.Array, window: int = 0) -> Tuple[jax.Array, dict]:
    """One-token decode against a cache.

    x: (B, 1, d). cache: {"k","v"}: (B, C, Hkv, hd) where C is either the
    full context length or the sliding window size (ring buffer).
    pos: scalar int32 or (B,) int32 vector — absolute position of each
    sequence's new token (per-slot under continuous batching).
    """
    b, s, _ = x.shape
    assert s == 1
    cache_len = cache["k"].shape[1]
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    pos = decode_positions(pos, b)
    posv = pos[:, None]
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    slot = cache_slots(pos, cache_len, window)
    rows = jnp.arange(b)
    ck = cache["k"].at[rows, slot].set(k[:, 0])
    cv = cache["v"].at[rows, slot].set(v[:, 0])
    ck = constrain(ck, "kv_cache")
    cv = constrain(cv, "kv_cache")
    # validity: cache rows written so far, per sequence
    idx = jnp.arange(cache_len)
    if window > 0:
        # ring fully valid once warm
        valid = idx[None, :] <= jnp.minimum(pos, cache_len - 1)[:, None]
    else:
        valid = idx[None, :] <= pos[:, None]
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    group = hq // hkv
    qh = q.reshape(b, hkv, group, cfg.head_dim)
    scores = jnp.einsum("bhgd,bthd->bhgt", qh, ck).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(cfg.head_dim)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bhgt,bthd->bhgd", probs, cv).reshape(b, 1, hq * cfg.head_dim)
    return out @ params["wo"], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2): low-rank KV compression; cache holds the
# compressed c_kv (kv_lora_rank) + shared rope key (qk_rope_dim) per token.


def _mla_qkv(params: dict, x: jax.Array, cfg: ModelConfig,
             positions: jax.Array):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = x @ params["w_dq"]                                  # (b,s,q_lora)
    q = (cq @ params["w_uq"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = x @ params["w_dkv"]                                # (b,s,kv_lora)
    k_pe = (x @ params["w_kpe"]).reshape(b, s, 1, m.qk_rope_dim)
    k_pe = apply_rope(k_pe, positions, cfg.rope_theta)
    return q_nope, q_rope, ckv, k_pe


def _mla_attend(params: dict, q_nope, q_rope, ckv, k_pe, cfg: ModelConfig,
                mask: Optional[jax.Array]):
    """Attention over the *compressed* cache (weight-absorbed form).

    q_nope: (b,s,h,dn)  q_rope: (b,s,h,dr)
    ckv: (b,t,r)        k_pe: (b,t,1,dr)
    mask: broadcastable to the (b,h,s,t) score tensor.
    """
    m = cfg.mla
    h = cfg.n_heads
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    # absorb W_uk into q: q_lat (b,s,h,r)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    scores = jnp.einsum("bshr,btr->bhst", q_lat, ckv)
    scores += jnp.einsum("bshd,btod->bhst", q_rope, k_pe)
    scores = scores.astype(jnp.float32) / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    o_lat = jnp.einsum("bhst,btr->bshr", probs, ckv)          # (b,s,h,r)
    out = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv)           # (b,s,h,dv)
    b, s = out.shape[:2]
    return out.reshape(b, s, h * m.v_head_dim) @ params["wo"]


def mla_attention(params: dict, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array, window: int = 0) -> jax.Array:
    """Training/prefill MLA: NON-absorbed form — decompress c_kv into
    per-head k/v once per token (cost T·r·h·(dn+dv)), then attend at
    (dn+dr)-wide scores. The absorbed form (_mla_attend) pays r-wide
    (512) scores per pair: ~2.6x more attention FLOPs at S=4k (§Perf
    iteration 4); it only wins at decode, where re-decompressing the whole
    cache per token would dominate."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope, ckv, k_pe = _mla_qkv(params, x, cfg, positions)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    k_nope = jnp.einsum("btr,rhd->bthd", ckv, w_uk)
    v = jnp.einsum("btr,rhd->bthd", ckv, w_uv)
    scores = jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
    scores += jnp.einsum("bshd,btod->bhst", q_rope, k_pe)
    scores = scores.astype(jnp.float32) / math.sqrt(
        m.qk_nope_dim + m.qk_rope_dim)
    mask = causal_mask(s, s, window=window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out.reshape(b, s, h * m.v_head_dim) @ params["wo"]


def mla_prefill(params: dict, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array, window: int = 0):
    q_nope, q_rope, ckv, k_pe = _mla_qkv(params, x, cfg, positions)
    s = x.shape[1]
    mask = causal_mask(s, s, window=window)
    out = _mla_attend(params, q_nope, q_rope, ckv, k_pe, cfg,
                      mask[None, None])
    return out, {"ckv": ckv, "kpe": k_pe[:, :, 0, :]}


def mla_decode(params: dict, x: jax.Array, cache: dict, cfg: ModelConfig,
               pos: jax.Array, window: int = 0):
    """cache: {"ckv": (B,C,r), "kpe": (B,C,dr)}.
    pos: scalar int32 or (B,) int32 vector (per-slot positions)."""
    b = x.shape[0]
    cache_len = cache["ckv"].shape[1]
    pos = decode_positions(pos, b)
    posv = pos[:, None]
    q_nope, q_rope, ckv_new, k_pe_new = _mla_qkv(params, x, cfg, posv)
    slot = cache_slots(pos, cache_len, window)
    rows = jnp.arange(b)
    ckv = cache["ckv"].at[rows, slot].set(ckv_new[:, 0])
    kpe = cache["kpe"].at[rows, slot].set(k_pe_new[:, 0, 0, :])
    ckv = constrain(ckv, "mla_cache")
    idx = jnp.arange(cache_len)
    valid = idx[None, :] <= jnp.minimum(pos, cache_len - 1)[:, None]
    mask = valid[:, None, None, :]                            # (b,1,s=1,C)
    out = _mla_attend(params, q_nope, q_rope, ckv, kpe[:, :, None, :], cfg, mask)
    return out, {"ckv": ckv, "kpe": kpe}
