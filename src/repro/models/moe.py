"""Mixture-of-Experts layer with sort-based capacity dispatch.

Design choice (recorded in DESIGN.md §5): we do NOT use the GShard one-hot
dispatch einsum ("td,tec->ecd") because its dense FLOPs pollute
``cost_analysis`` and destroy the MODEL_FLOPS/HLO_FLOPS roofline ratio.
Instead tokens are routed with an argsort over expert assignments into
fixed-capacity per-expert buffers (gather), run through batched expert
matmuls (active FLOPs only), and scatter-combined back, weighted by the
normalized top-k gates. Overflowing assignments are dropped (standard
capacity-factor semantics).

Sharding: the expert axis is annotated for the "model" mesh axis (expert
parallelism); the token gather/scatter across the data axis lowers to
all-to-all-style collectives under GSPMD.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from .sharding_ctx import constrain


def router(params: dict, x: jax.Array, moe: MoEConfig
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T, d). Returns (gate_weights (T,k), expert_idx (T,k), aux_loss)."""
    logits = (x.astype(jnp.float32) @ params["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate, idx = jax.lax.top_k(probs, moe.top_k)               # (T, k)
    gate = gate / (jnp.sum(gate, axis=-1, keepdims=True) + 1e-9)
    # load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e
    T = x.shape[0]
    me = jnp.mean(probs, axis=0)                              # (E,)
    assign = jax.nn.one_hot(idx[:, 0], moe.n_experts, dtype=jnp.float32)
    ce = jnp.mean(assign, axis=0)
    aux = moe.n_experts * jnp.sum(me * ce)
    return gate, idx, aux


def _expert_ffn(w: dict, xe: jax.Array) -> jax.Array:
    """Batched expert SwiGLU. xe: (E, C, d) -> (E, C, d)."""
    gate = jnp.einsum("ecd,edf->ecf", xe, w["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, w["w_up"])
    h = jax.nn.silu(gate) * up
    h = constrain(h, "moe_hidden")
    return jnp.einsum("ecf,efd->ecd", h, w["w_down"])


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    moe = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    T = b * s
    E, k = moe.n_experts, moe.top_k
    gate, idx, aux = router(params, xt, moe)

    # capacity floor of 4 avoids pathological drops for tiny decode batches
    capacity = max(4, int(math.ceil(T * k / E * moe.capacity_factor)))
    capacity = min(capacity, T)  # never more slots than tokens
    N = T * k
    flat_e = idx.reshape(N)                                    # expert of each assignment
    sort_ord = jnp.argsort(flat_e)                             # stable in XLA
    se = flat_e[sort_ord]                                      # sorted expert ids
    # rank of each assignment within its expert
    first_of_e = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(N) - first_of_e
    slot = jnp.where(rank < capacity, se * capacity + rank, E * capacity)
    tok_of_assign = sort_ord // k                              # source token
    # gather-based dispatch (§Perf): scattering (E*C, d) vectors makes
    # GSPMD replicate the buffer; instead scatter only the int32 inverse
    # map (2600x smaller) and GATHER the tokens, which shards cleanly.
    inv = jnp.full((E * capacity + 1,), N, jnp.int32)
    inv = inv.at[slot].set(jnp.arange(N, dtype=jnp.int32), mode="drop")
    inv = inv[: E * capacity]
    filled = inv < N
    src_tok = jnp.where(filled, tok_of_assign[jnp.minimum(inv, N - 1)], 0)
    xe = xt[src_tok] * filled[:, None].astype(x.dtype)
    xe = xe.reshape(E, capacity, d)
    xe = constrain(xe, "moe_dispatch")

    ye = _expert_ffn(params["experts"], xe)                    # (E, C, d)
    ye_flat = jnp.concatenate(
        [ye.reshape(E * capacity, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    y_assign_sorted = ye_flat[slot]                            # (N, d) sorted order
    # unsort back to assignment order
    y_assign = jnp.zeros((N, d), dtype=x.dtype).at[sort_ord].set(y_assign_sorted)
    y = jnp.sum(y_assign.reshape(T, k, d) * gate[..., None].astype(x.dtype), axis=1)

    # shared (always-on) experts as a dense SwiGLU over all tokens
    if moe.n_shared:
        sh = params["shared"]
        g = xt @ sh["w_gate"]
        u = xt @ sh["w_up"]
        y = y + (jax.nn.silu(g) * u) @ sh["w_down"]
    return y.reshape(b, s, d), aux * moe.router_aux_weight
