"""shard_map expert-parallel MoE (inference path).

GSPMD cannot shard the data-dependent dispatch gather: with tokens on
"data" and the (E·C, d) buffer on "model" it falls back to mask +
all-reduce of the full buffer (~2×10 GB f32 per deepseek layer — see
EXPERIMENTS.md §Perf iteration 6). Under shard_map the structure is
explicit and fully local:

  - activations are replicated across "model" and sharded over "data"
    (the serving layout), so every (data_i, model_j) chip routes its OWN
    tokens locally;
  - each model shard owns E/16 experts (weights P("model", None, None))
    and computes only its experts over the local tokens;
  - one bf16 psum over "model" combines expert outputs per local token.

Per-layer collective cost: T_local × d × 2 B (the psum) — for deepseek
prefill_32k that is 64 MB vs ~39 GB under GSPMD.

Inference-only by design: expert weights are E/model-sharded (4.7 GB bf16
per chip for deepseek — fine without optimizer state; training keeps the
gather-based path where FSDP covers m/v).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .moe import router


def _local_moe(w_router, w_gate, w_up, w_down, shared, x, *,
               cfg: ModelConfig, capacity: int, model_axis: str, data_axis):
    """Per-shard body. x: (B_l, S, d) local tokens (replicated over model);
    w_gate/w_up: (E_l, d, ffe); w_down: (E_l, ffe, d).

    ``capacity`` is computed by the caller from the GLOBAL token count with
    the exact formula of the gather path — deriving it from the local T
    here would shrink the per-expert buffers by the data-shard count and
    drop tokens the gather path keeps."""
    moe = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    T = xt.shape[0]
    E, k = moe.n_experts, moe.top_k
    E_l = w_gate.shape[0]
    m_idx = jax.lax.axis_index(model_axis)

    gate, idx, _ = router({"w_router": w_router}, xt, moe)

    N = T * k
    flat_e = idx.reshape(N)
    sort_ord = jnp.argsort(flat_e)
    se = flat_e[sort_ord]
    rank = jnp.arange(N) - jnp.searchsorted(se, se, side="left")
    slot = jnp.where(rank < capacity, se * capacity + rank, E * capacity)
    tok_of_assign = sort_ord // k
    inv = jnp.full((E * capacity + 1,), N, jnp.int32)
    inv = inv.at[slot].set(jnp.arange(N, dtype=jnp.int32), mode="drop")
    inv = inv[: E * capacity]
    filled = inv < N
    src_tok = jnp.where(filled, tok_of_assign[jnp.minimum(inv, N - 1)], 0)
    xe = (xt[src_tok] * filled[:, None].astype(xt.dtype)
          ).reshape(E, capacity, d)
    # only this shard's experts
    own = jax.lax.dynamic_slice_in_dim(xe, m_idx * E_l, E_l, axis=0)

    g = jnp.einsum("ecd,edf->ecf", own, w_gate)
    u = jnp.einsum("ecd,edf->ecf", own, w_up)
    h = jax.nn.silu(g) * u
    ye_own = jnp.einsum("ecf,efd->ecd", h, w_down)       # (E_l, C, d)

    # place own experts' outputs back into the full (E*C, d) frame
    ye_full = jnp.zeros((E * capacity + 1, d), xt.dtype)
    ye_full = jax.lax.dynamic_update_slice_in_dim(
        ye_full, ye_own.reshape(E_l * capacity, d),
        m_idx * E_l * capacity, axis=0)
    y_assign_sorted = ye_full[slot]
    y_assign = jnp.zeros((N, d), xt.dtype).at[sort_ord].set(y_assign_sorted)
    y = jnp.sum(y_assign.reshape(T, k, d) * gate[..., None].astype(xt.dtype),
                axis=1)
    # combine expert contributions across model shards (ONE bf16 psum)
    y = jax.lax.psum(y, model_axis)

    if moe.n_shared:
        sg = xt @ shared["w_gate"]
        su = xt @ shared["w_up"]
        y = y + (jax.nn.silu(sg) * su) @ shared["w_down"]
    return y.reshape(b, s, d)


def moe_ffn_shardmap(params: dict, x: jax.Array, cfg: ModelConfig, mesh,
                     data_axes=("data",), model_axis: str = "model"
                     ) -> Tuple[jax.Array, jax.Array]:
    """Drop-in for moe_ffn under an active mesh (inference)."""
    moe = cfg.moe
    # Capacity from the GLOBAL (pre-shard) token count, same formula as
    # moe_ffn: max(4, ceil(T*k/E*cf)) clamped to T.  Each shard then ranks
    # its local assignments against the global per-expert budget, so in the
    # no-drop regime (capacity >= demand) both dispatch paths process the
    # identical assignment set; under overflow the local ranking can only
    # over-admit relative to global ranking, never drop extra tokens.
    b, s, _ = x.shape
    T = b * s
    E, k = moe.n_experts, moe.top_k
    capacity = min(max(4, int(math.ceil(T * k / E * moe.capacity_factor))), T)
    body = functools.partial(_local_moe, cfg=cfg, capacity=capacity,
                             model_axis=model_axis, data_axis=data_axes)
    shared_spec = jax.tree_util.tree_map(lambda _: P(None, None),
                                         params.get("shared", {}))
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None),                       # router replicated
                  P(model_axis, None, None),           # w_gate
                  P(model_axis, None, None),           # w_up
                  P(model_axis, None, None),           # w_down
                  shared_spec,
                  P(data_axes, None, None)),           # x
        out_specs=P(data_axes, None, None),
        check_rep=False)
    y = fn(params["w_router"], params["experts"]["w_gate"],
           params["experts"]["w_up"], params["experts"]["w_down"],
           params.get("shared", {}), x)
    return y, jnp.float32(0.0)
