from .model import forward, loss_fn, prefill, decode_step, init_cache
from .params import init_params, abstract_params, param_count
