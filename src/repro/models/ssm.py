"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Implements:
  - ``ssd_chunked``: the chunked SSD forward used for training / prefill —
    intra-chunk quadratic (attention-like) term + inter-chunk state
    recurrence carried with ``lax.scan`` over chunks. This is the pure-jnp
    oracle path; the Pallas TPU kernel in ``repro.kernels.ssd_scan`` mirrors
    it block-for-block.
  - ``ssd_decode_step``: O(1)-per-token recurrent update used for decode.
  - ``mamba2_block``: full block (in_proj -> causal conv -> SSD -> gated
    norm -> out_proj) with prefill/decode state handling.

Single B/C group (ngroups=1), scalar A per head — the Mamba2 default.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, SSMConfig
from .layers import rms_norm
from .sharding_ctx import constrain


def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., l, s] = sum_{i=s+1..l} a[..., i] (l>=s).

    a: (..., cs). Returns (..., cs, cs) with -inf above the diagonal.
    """
    cs = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)                                  # (..., cs)
    diff = cum[..., :, None] - cum[..., None, :]                  # l, s
    mask = jnp.tril(jnp.ones((cs, cs), dtype=bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  (b, s, h, p)   inputs per head
    dt: (b, s, h)      positive step sizes (already softplus'd)
    A:  (h,)           negative per-head decay rates
    B:  (b, s, n)      input projection (shared across heads, ngroups=1)
    C:  (b, s, n)      output projection
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s_orig, h, p = x.shape
    n = B.shape[-1]
    # pad to a chunk multiple; dt=0 padding is exactly state-neutral
    # (decay exp(0)=1, input x*dt=0), so states and outputs are unaffected.
    pad = (-s_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc, cs = s // chunk, chunk

    a = dt * A[None, None, :]                                     # (b,s,h) log-decay
    xb = x * dt[..., None]                                        # discretized input
    # chunk views
    ac = a.reshape(b, nc, cs, h)
    xc = xb.reshape(b, nc, cs, h, p)
    Bc = B.reshape(b, nc, cs, n)
    Cc = C.reshape(b, nc, cs, n)

    # 1) intra-chunk (quadratic) term
    L = jnp.exp(_segsum(jnp.moveaxis(ac, -1, -2)))                # (b,nc,h,cs,cs)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)                # (b,nc,cs,cs)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp", scores, L, xc)

    # 2) per-chunk final states
    a_cum = jnp.cumsum(ac, axis=2)                                # (b,nc,cs,h)
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)           # (b,nc,cs,h)
    S = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_to_end, xc)

    # 3) inter-chunk recurrence — associative scan (parallel prefix), so a
    # sequence-sharded chunk axis costs log(n_shards) partial-state
    # permutes instead of an all-gather of every chunk state (§Perf).
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                     # (b,nc,h)

    h0 = (jnp.zeros((b, h, p, n), dtype=jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    S_t = S.astype(jnp.float32)                                   # (b,nc,h,p,n)
    dec_t = chunk_decay.astype(jnp.float32)                       # (b,nc,h)

    def combine(x, y):
        a1, s1 = x
        a2, s2 = y
        return a1 * a2, a2[..., None, None] * s1 + s2

    cum_dec, cum_S = jax.lax.associative_scan(
        combine, (dec_t, S_t), axis=1)
    # h_after_c = cum_S_c + cumprod(dec)_c * h0 ; h_prev_c = h_after_{c-1}
    h_after = cum_S + cum_dec[..., None, None] * h0[:, None]
    h_prevs = jnp.concatenate(
        [h0[:, None], h_after[:, :-1]], axis=1)                   # (b,nc,h,p,n)
    final = h_after[:, -1]

    # 4) contribution of carried state to each position
    state_decay = jnp.exp(a_cum)                                  # (b,nc,cs,h)
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp", Cc, state_decay,
                       h_prevs.astype(Cc.dtype))
    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_orig]
    return y, final.astype(x.dtype)


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    A: jax.Array, B: jax.Array, C: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence. state: (b,h,p,n); x: (b,h,p); dt: (b,h);
    B,C: (b,n). Returns (y (b,h,p), new_state)."""
    decay = jnp.exp(dt * A[None, :])                              # (b,h)
    inc = jnp.einsum("bhp,bn->bhpn", x * dt[..., None], B)
    new_state = state * decay[..., None, None] + inc
    y = jnp.einsum("bhpn,bn->bhp", new_state, C)
    return y, new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block


def _split_proj(cfg: ModelConfig, z: jax.Array):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    zx, xx, Bx, Cx, dtx = jnp.split(
        z, [di, 2 * di, 2 * di + s.d_state, 2 * di + 2 * s.d_state], axis=-1)
    return zx, xx, Bx, Cx, dtx


def _causal_conv(xBC: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv via lax.conv_general_dilated (native spatial
    partitioning: under a sequence-sharded mesh GSPMD emits a (k-1)-row halo
    exchange instead of whole-tensor permutes — see EXPERIMENTS.md §Perf).

    xBC: (b,s,c), w: (k,c). If ``state`` (b,k-1,c) is given it is the decode
    context; returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        ctx = xBC
        padding = [(k - 1, 0)]
        pad_zeros = jnp.zeros((xBC.shape[0], k - 1, xBC.shape[2]), xBC.dtype)
        full_ctx = jnp.concatenate([pad_zeros, xBC], axis=1)
        new_state = full_ctx[:, -(k - 1):, :] if k > 1 else None
    else:
        ctx = jnp.concatenate([state, xBC], axis=1)
        padding = [(0, 0)]
        new_state = ctx[:, -(k - 1):, :] if k > 1 else None
    c = xBC.shape[2]
    rhs = w[:, None, :].astype(ctx.dtype)               # (k, 1, c) WIO
    y = jax.lax.conv_general_dilated(
        ctx, rhs, window_strides=(1,), padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c)
    return jax.nn.silu(y), new_state


def mamba2_block(params: dict, x: jax.Array, cfg: ModelConfig,
                 state: dict | None = None, return_state: bool = False):
    """x: (b, s, d). ``state`` = {"conv": (b,k-1,c), "ssd": (b,h,p,n)} for
    decode; when given, s must be 1 and the recurrent path is used."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    z = x @ params["w_in"]                                        # (b,s,proj)
    zx, xx, Bx, Cx, dtx = _split_proj(cfg, z)
    xBC = jnp.concatenate([xx, Bx, Cx], axis=-1)
    dt = jax.nn.softplus(dtx + params["dt_bias"])                 # (b,s,nh)
    A = -jnp.exp(params["A_log"])                                 # (nh,)

    if state is None:
        conv_out, conv_state = _causal_conv(xBC, params["w_conv"])
        xx2, Bx2, Cx2 = jnp.split(conv_out, [di, di + s_cfg.d_state], axis=-1)
        xh = xx2.reshape(b, s, nh, s_cfg.head_dim)
        xh = constrain(xh, "ssm_x")
        y, final = ssd_chunked(xh, dt, A, Bx2, Cx2, s_cfg.chunk_size)
        y = y + xh * params["D"][None, None, :, None]
        y = y.reshape(b, s, di)
        y = rms_norm(y * jax.nn.silu(zx), params["norm"], cfg.norm_eps)
        out = y @ params["w_out"]
        if return_state:
            return out, {"conv": conv_state, "ssd": final}
        return out
    else:
        assert s == 1
        conv_out, conv_state = _causal_conv(xBC, params["w_conv"], state["conv"])
        xx2, Bx2, Cx2 = jnp.split(conv_out, [di, di + s_cfg.d_state], axis=-1)
        xh = xx2[:, 0].reshape(b, nh, s_cfg.head_dim)
        y, new_ssd = ssd_decode_step(state["ssd"].astype(jnp.float32),
                                     xh.astype(jnp.float32),
                                     dt[:, 0].astype(jnp.float32), A,
                                     Bx2[:, 0].astype(jnp.float32),
                                     Cx2[:, 0].astype(jnp.float32))
        y = y.astype(x.dtype) + xh * params["D"][None, :, None]
        y = y.reshape(b, 1, di)
        y = rms_norm(y * jax.nn.silu(zx), params["norm"], cfg.norm_eps)
        out = y @ params["w_out"]
        return out, {"conv": conv_state, "ssd": new_ssd.astype(state["ssd"].dtype)}
