"""Parameter construction for every architecture family.

Layout: a nested dict whose layer-stacked leaves carry a leading
``n_layers``-like dim so the model can ``lax.scan`` over layers (one
compiled layer body — essential for the 80-compile dry-run sweep).

Families:
  dense/moe/vlm/audio -> {"embed", "layers": {...stacked L...}, "final_norm",
                          "lm_head"?}
  ssm                 -> {"embed", "layers": {...stacked L...}, "final_norm"}
  hybrid (zamba2)     -> {"embed", "groups": {...stacked (G, per, ...)...},
                          "rem": {...stacked (R, ...)...},
                          "shared_attn": {... single copy ...},
                          "final_norm", "lm_head"}
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

Params = Dict[str, Any]


def _split(key, n):
    return list(jax.random.split(key, n))


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(cfg: ModelConfig, key, dtype, stack=()) -> Params:
    d = cfg.d_model
    ks = _split(key, 10)
    if cfg.attention == "mla":
        m = cfg.mla
        h = cfg.n_heads
        return {
            "w_dq": _dense_init(ks[0], (*stack, d, m.q_lora_rank), dtype),
            "w_uq": _dense_init(ks[1], (*stack, m.q_lora_rank,
                                        h * (m.qk_nope_dim + m.qk_rope_dim)), dtype),
            "w_dkv": _dense_init(ks[2], (*stack, d, m.kv_lora_rank), dtype),
            "w_kpe": _dense_init(ks[3], (*stack, d, m.qk_rope_dim), dtype),
            "w_uk": _dense_init(ks[4], (*stack, m.kv_lora_rank, h * m.qk_nope_dim), dtype),
            "w_uv": _dense_init(ks[5], (*stack, m.kv_lora_rank, h * m.v_head_dim), dtype),
            "wo": _dense_init(ks[6], (*stack, h * m.v_head_dim, d), dtype),
        }
    p = {
        "wq": _dense_init(ks[0], (*stack, d, cfg.q_dim), dtype),
        "wk": _dense_init(ks[1], (*stack, d, cfg.kv_dim), dtype),
        "wv": _dense_init(ks[2], (*stack, d, cfg.kv_dim), dtype),
        "wo": _dense_init(ks[3], (*stack, cfg.q_dim, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((*stack, cfg.q_dim), dtype)
        p["bk"] = jnp.zeros((*stack, cfg.kv_dim), dtype)
        p["bv"] = jnp.zeros((*stack, cfg.kv_dim), dtype)
    return p


def _mlp_params(cfg: ModelConfig, key, dtype, stack=(), d_ff=None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = _split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": _dense_init(ks[0], (*stack, d, ff), dtype),
            "w_up": _dense_init(ks[1], (*stack, d, ff), dtype),
            "w_down": _dense_init(ks[2], (*stack, ff, d), dtype),
        }
    return {
        "w_up": _dense_init(ks[1], (*stack, d, ff), dtype),
        "w_down": _dense_init(ks[2], (*stack, ff, d), dtype),
    }


def _moe_params(cfg: ModelConfig, key, dtype, stack=()) -> Params:
    moe = cfg.moe
    d, ffe, E = cfg.d_model, moe.d_ff_expert, moe.n_experts
    ks = _split(key, 5)
    p = {
        "w_router": _dense_init(ks[0], (*stack, d, E), jnp.float32),
        "experts": {
            "w_gate": _dense_init(ks[1], (*stack, E, d, ffe), dtype),
            "w_up": _dense_init(ks[2], (*stack, E, d, ffe), dtype),
            "w_down": _dense_init(ks[3], (*stack, E, ffe, d), dtype),
        },
    }
    if moe.n_shared:
        ff_sh = moe.n_shared * ffe
        sk = _split(ks[4], 3)
        p["shared"] = {
            "w_gate": _dense_init(sk[0], (*stack, d, ff_sh), dtype),
            "w_up": _dense_init(sk[1], (*stack, d, ff_sh), dtype),
            "w_down": _dense_init(sk[2], (*stack, ff_sh, d), dtype),
        }
    return p


def _ssm_params(cfg: ModelConfig, key, dtype, stack=()) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_ch = di + 2 * s.d_state
    proj = 2 * di + 2 * s.d_state + nh
    ks = _split(key, 4)
    return {
        "w_in": _dense_init(ks[0], (*stack, d, proj), dtype),
        "w_conv": _dense_init(ks[1], (*stack, s.conv_width, conv_ch), dtype, scale=0.5),
        "dt_bias": jnp.zeros((*stack, nh), dtype),
        "A_log": jnp.zeros((*stack, nh), jnp.float32),
        "D": jnp.ones((*stack, nh), dtype),
        "norm": jnp.ones((*stack, di), dtype),
        "w_out": _dense_init(ks[2], (*stack, di, d), dtype),
    }


def _attn_layer(cfg: ModelConfig, key, dtype, stack=()) -> Params:
    ks = _split(key, 3)
    d = cfg.d_model
    layer = {
        "attn_norm": jnp.ones((*stack, d), dtype),
        "mlp_norm": jnp.ones((*stack, d), dtype),
        "attn": _attn_params(cfg, ks[0], dtype, stack),
    }
    if cfg.is_moe:
        layer["moe"] = _moe_params(cfg, ks[1], dtype, stack)
    else:
        layer["mlp"] = _mlp_params(cfg, ks[1], dtype, stack)
    return layer


def _ssm_layer(cfg: ModelConfig, key, dtype, stack=()) -> Params:
    return {
        "norm": jnp.ones((*stack, cfg.d_model), dtype),
        "ssm": _ssm_params(cfg, key, dtype, stack),
    }


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=jnp.float32) -> Params:
    ks = _split(key, 8)
    d, V = cfg.d_model, cfg.vocab_size
    params: Params = {
        "embed": _dense_init(ks[0], (V, d), dtype, scale=0.02),
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(ks[1], (d, V), dtype)

    if cfg.arch_type == "hybrid":
        G = cfg.n_layers // cfg.hybrid_attn_every
        per = cfg.hybrid_attn_every - 1
        R = cfg.n_layers - G * cfg.hybrid_attn_every
        params["groups"] = _ssm_layer(cfg, ks[2], dtype, stack=(G, per))
        if R:
            params["rem"] = _ssm_layer(cfg, ks[3], dtype, stack=(R,))
        shared = _attn_layer(cfg, ks[4], dtype, stack=())
        params["shared_attn"] = shared
    elif cfg.arch_type == "ssm":
        params["layers"] = _ssm_layer(cfg, ks[2], dtype, stack=(cfg.n_layers,))
    else:
        params["layers"] = _attn_layer(cfg, ks[2], dtype, stack=(cfg.n_layers,))
    return params


def abstract_params(cfg: ModelConfig, dtype=jnp.float32) -> Params:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg, dtype=dtype),
        jax.random.key(0))


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
