"""Crash-resume: continue an interrupted run from its journal.

Recovery model — *deterministic re-execution with a verified replay
cursor* (Temporal-style).  Runs here are deterministic functions of the
spec: seeded world, seeded policies, virtual clock.  So a resume does
not need to snapshot pattern state (stage lists, reflection summaries,
plan-cache decisions); it re-enters the pattern from the top and lets
the journaled prefix re-derive itself — every policy decision, latency
draw and tool dispatch lands identically, rebuilding the simulated
server-side state (downloaded PDFs, workspace files) the suffix depends
on.  A :class:`ReplayCursor` subscribed to the runtime verifies each
re-emitted event against the journal, wire-form for wire-form; any
mismatch raises :class:`ResumeDeviation` and the caller falls back to a
full rerun (the same determinism check Temporal applies to workflow
histories).  Past the last committed event, execution simply continues
live — the runtime is re-entered at the first unfinished step — and the
journal writer appends the suffix (a second crash resumes further).

Accounting: the replayed prefix is *recovered*, not re-billed.  In a
production durable executor the journal serves the prefix's LLM/tool
results directly (no tokens, no invocations); our simulation substitutes
local re-derivation to rebuild environment state, and prices it the
same — zero.  :func:`resume_run` reconstructs the prefix's progress
through ``derive_trace`` and reports it under
``result.extras["resume"]`` (events replayed, tokens/cost recovered,
Eq. 2 FaaS cost at the resume boundary); :func:`billed_cost` is the
run's cost net of recovery — what the resume strategy actually pays.

The parity contract this module is tested against:
**interrupted + resumed == uninterrupted, bit-identical** — the full
event sequence and the artifact of a killed-and-resumed run equal the
uninterrupted run's, across patterns and deployments.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from ..core.events import RunEvent, derive_trace, to_wire
from ..core.metrics import RunResult
from .journal import JournalError, RunJournal, Segment


class ResumeDeviation(RuntimeError):
    """Replay re-derived an event that differs from the journaled one —
    the journal can no longer be trusted as this run's history (config
    changed, cache state diverged, non-determinism crept in).  Callers
    fall back to a fresh full rerun."""

    def __init__(self, reason: str, index: int = -1):
        super().__init__(f"replay deviated at event {index}: {reason}")
        self.reason = reason
        self.index = index


class ReplayCursor:
    """Verifies a resumed run's re-emitted events against the journaled
    prefix.  Subscribe :meth:`check` on the runtime *before* the journal
    writer: a deviating event must raise before it is appended.

    ``on_boundary`` fires exactly once, the moment the last committed
    event has been verified — i.e. at the resume boundary, before any
    live work — so the caller can snapshot boundary state (the Eq. 2
    FaaS cost accrued by the replayed prefix)."""

    def __init__(self, prefix: List[RunEvent],
                 on_boundary: Optional[Callable[[], None]] = None):
        self.prefix = prefix
        self.i = 0
        self._on_boundary = on_boundary
        if not prefix and on_boundary is not None:
            on_boundary()

    @property
    def live(self) -> bool:
        return self.i >= len(self.prefix)

    def check(self, event: RunEvent) -> None:
        if self.live:
            return
        expected = self.prefix[self.i]
        # wire-form comparison: journal events round-tripped through
        # JSON (tuples became lists), live events have not — to_wire
        # canonicalizes both
        if to_wire(event) != to_wire(expected):
            raise ResumeDeviation(
                f"expected {type(expected).__name__}, re-derived "
                f"{type(event).__name__}", index=self.i)
        self.i += 1
        if self.live and self._on_boundary is not None:
            self._on_boundary()


def recovered_stats(prefix: List[RunEvent]) -> Dict[str, Any]:
    """What the journaled prefix is worth: replay it through
    ``derive_trace`` and read off the recovered progress — the tokens,
    Eq. 1 LLM cost and tool invocations a rerun would pay again."""
    trace = derive_trace(prefix)
    return {
        "replayed_events": len(prefix),
        "recovered_input_tokens": trace.input_tokens,
        "recovered_output_tokens": trace.output_tokens,
        "recovered_llm_cost": trace.llm_cost,
        "recovered_tool_calls": len(trace.tool_events),
    }


def recovered_cost(result: RunResult) -> float:
    """Total recovered cost (Eq. 1 + Eq. 2) of a resumed result, 0.0
    for a fresh run."""
    info = result.extras.get("resume")
    if not info:
        return 0.0
    return (info.get("recovered_llm_cost", 0.0)
            + info.get("recovered_faas_cost", 0.0))


def recovered_tokens(result: RunResult) -> int:
    info = result.extras.get("resume")
    if not info:
        return 0
    return (info.get("recovered_input_tokens", 0)
            + info.get("recovered_output_tokens", 0))


def billed_cost(result: RunResult) -> float:
    """What this attempt actually pays: intrinsic run cost net of the
    journal-recovered prefix.  Equals ``result.total_cost`` for fresh
    runs."""
    return result.total_cost - recovered_cost(result)


def resume_run(session, spec, on_event: Optional[Callable] = None,
               attempt: Optional[int] = None) -> RunResult:
    """Resume ``spec`` from the session's journal.

    Reads the run's segment (corrupt tail truncated on open), replays
    the committed prefix through the verified re-execution path, and
    continues live from the first unfinished step.  Falls back to a
    plain ``session.execute`` — a fresh, fully billed run — when there
    is nothing to resume (no segment, empty, or complete), when the
    segment is untrustworthy (:class:`JournalError`: foreign file,
    older journal/wire schema), or when replay deviates
    (:class:`ResumeDeviation`).

    ``attempt`` is the caller's restart counter (the traffic driver's
    crash count); it keys the fallback rerun's injected-crash draw.  A
    crash before the first fsync barrier leaves an *empty* segment, so
    the fallback MUST advance the attempt or a deterministic kill would
    re-fire at the same event forever.  When not given, the segment's
    own resume count (or 0) is used.

    The returned result carries ``extras["resume"]`` telemetry on the
    resume path (absent after a fallback rerun)."""
    journal: Optional[RunJournal] = getattr(session, "journal", None)
    if journal is None:
        raise ValueError("resume_run needs a Session with a journal "
                         "(Session(journal=RunJournal(dir=...)))")
    key = journal.key_for(spec)
    segment: Optional[Segment] = None
    if key is not None:
        try:
            segment = journal.read(key)
        except JournalError:
            segment = None          # detected, not mis-parsed: rerun
    if segment is None or not segment.events or segment.complete:
        fallback_attempt = attempt if attempt is not None else (
            segment.resumes + 1 if segment is not None
            and not segment.complete else 0)
        return session.execute(spec, on_event, attempt=fallback_attempt)
    try:
        return session._execute(spec, on_event, resume=segment)
    except ResumeDeviation:
        fallback_attempt = (attempt if attempt is not None
                            else segment.resumes + 1)
        return session.execute(spec, on_event, attempt=fallback_attempt)
