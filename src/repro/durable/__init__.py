"""Durable execution: event-sourced run journal + crash-resume.

The subsystem that turns the typed ``RunEvent`` stream into durable
state (ROADMAP "Durable, resumable runs"):

  * :mod:`repro.durable.journal` — append-only, wire-serialized JSONL
    segments, one per run, keyed by the run-cache content address;
    version-gated headers, fsync-batched appends, corrupt-tail
    truncation on open;
  * :mod:`repro.durable.resume` — ``resume_run``: verified
    deterministic re-execution of the journaled prefix, live
    continuation from the first unfinished step, recovered-cost
    accounting.  Parity contract: interrupted + resumed ==
    uninterrupted, bit-identical.

See ``docs/DURABLE.md``.
"""
from .journal import (JOURNAL_FORMAT, JOURNAL_VERSION, JournalError,
                      JournalReader, JournalVersionError, JournalWriter,
                      RunJournal, Segment)
from .resume import (ReplayCursor, ResumeDeviation, billed_cost,
                     recovered_cost, recovered_stats, recovered_tokens,
                     resume_run)

__all__ = [
    "JOURNAL_FORMAT", "JOURNAL_VERSION", "JournalError", "JournalReader",
    "JournalVersionError", "JournalWriter", "ReplayCursor",
    "ResumeDeviation", "RunJournal", "Segment", "billed_cost",
    "recovered_cost", "recovered_stats", "recovered_tokens", "resume_run",
]
