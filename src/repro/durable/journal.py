"""Event-sourced run journal: one append-only JSONL segment per run.

The durable half of the subsystem (see :mod:`repro.durable.resume` for
the recovery half, ``docs/DURABLE.md`` for the full story).  A
:class:`RunJournal` is a directory of *segments*, one per run, keyed by
the run-cache content address (:func:`repro.apps.cache.spec_fingerprint`
— spec identity + pattern/deployment/serving config fingerprints).
Wired in as ``Session(journal=RunJournal(dir=...))``, every event of
every pattern x deployment x llm combination is journaled for free via
the runtime's subscriber list.

Segment layout (``run_<key>.jsonl``)::

    {"format": "repro-run-journal", "version": 1, "wire_version": 2,
     "key": "...", "spec": {...}}          <- header (version-gated)
    {"type": "RunStarted", "v": 2, ...}    <- one wire event per line
    {"type": "ToolInvoked", "v": 2, ...}
    {"resume": 1}                          <- a resume re-opened the segment
    {"type": "ToolInvoked", "v": 2, ...}   <- ... and appended the suffix

Durability model — *atomic fsync-batched appends*: the writer buffers
appends and flushes + ``fsync``\\ s every ``fsync_batch`` events (and on
close).  A simulated platform death (:class:`repro.core.runtime.
RunAborted`) calls :meth:`JournalWriter.abort`, which DROPS the
unflushed buffer — exactly the host-failure semantics of a real
append-only log: everything up to the last fsync barrier survives, the
tail is lost.  A torn write at the physical tail is handled on open:
:meth:`JournalReader.read` parses until the first corrupt line and
reports the valid prefix (corrupt-tail truncation); re-opening the
segment for a resume atomically rewrites that valid prefix first
(:mod:`repro.core.persist` conventions).

A segment whose last event is ``RunCompleted`` is *complete* (the run
finished, successfully or not — deterministic failures are not
resumable, they would fail again).  Anything else is an *interrupted*
run: :meth:`RunJournal.interrupted` lists them, and the traffic driver
resumes journaled-but-dead runs it executed itself.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import IO, Any, Dict, List, Optional

from ..core.events import (WIRE_VERSION, RunCompleted, RunEvent,
                           WireVersionError, from_wire, to_wire)
from ..core.persist import CORRUPT_ENTRY_ERRORS, atomic_write_text

JOURNAL_FORMAT = "repro-run-journal"
JOURNAL_VERSION = 1


class JournalError(ValueError):
    """A segment exists but cannot be trusted (foreign file, bad
    header).  Callers treat it as no-journal: rerun from scratch."""


class JournalVersionError(JournalError):
    """A segment's header carries an older journal-format or wire-schema
    version — detected up front, never mis-parsed event by event."""


def spec_to_wire(spec) -> Dict[str, Any]:
    """The header's human-readable spec identity (the *key* is the
    authoritative address; this is for debuggability and tooling)."""
    return {"app": spec.app, "instance": spec.instance,
            "pattern": spec.pattern, "deployment": spec.deployment,
            "llm": spec.llm, "seed": spec.seed, "priority": spec.priority}


@dataclasses.dataclass
class Segment:
    """One parsed journal segment."""
    key: str
    path: str
    header: Dict[str, Any]
    events: List[RunEvent]
    resumes: int          # resume markers seen (= restart attempts so far)
    truncated: bool       # a corrupt/torn tail was dropped on read
    valid_bytes: int      # byte offset of the end of the last intact line

    @property
    def complete(self) -> bool:
        """The run terminated (its stream ends with ``RunCompleted``) —
        nothing to resume."""
        return bool(self.events) and isinstance(self.events[-1],
                                                RunCompleted)


class JournalWriter:
    """Append-only writer for ONE run's segment.  Not thread-safe: one
    run, one writer (the traffic driver is single-threaded asyncio; for
    ``execute_many`` give concurrent identical specs distinct seeds, as
    every workload generator here does).

    ``skip`` committed events are silently dropped on append — a
    resumed run re-emits its journaled prefix during replay, and those
    events are already on disk."""

    def __init__(self, f: IO[str], path: str, skip: int = 0,
                 fsync_batch: int = 8):
        self._f = f
        self.path = path
        self._skip = skip
        self._batch = max(1, fsync_batch)
        self._buf: List[str] = []
        self.appended = 0       # live events accepted (skips excluded)
        self.closed = False

    def append(self, event: RunEvent) -> None:
        if self.closed:
            return
        if self._skip > 0:
            self._skip -= 1
            return
        self._buf.append(json.dumps(to_wire(event)))
        self.appended += 1
        if len(self._buf) >= self._batch:
            self._fsync()

    def _fsync(self) -> None:
        if self._buf:
            self._f.write("\n".join(self._buf) + "\n")
            self._f.flush()
            os.fsync(self._f.fileno())
            self._buf.clear()

    def close(self) -> None:
        """Normal end of run: flush + fsync everything."""
        if not self.closed:
            self._fsync()
            self._f.close()
            self.closed = True

    def abort(self) -> None:
        """Simulated platform death: the unfsynced buffer is LOST (the
        journal keeps only what survived the last fsync barrier), so a
        resume re-executes the tail the crash swallowed."""
        if not self.closed:
            self._buf.clear()
            self._f.close()
            self.closed = True


class JournalReader:
    """Parses segments with corrupt-tail truncation: events are read
    line by line until the first unparseable line (torn write, corrupt
    middle, foreign junk); everything from that line on is dropped and
    the segment is flagged ``truncated`` — the valid prefix is still a
    committed, resumable history."""

    def __init__(self, path: str, key: str):
        self.path = path
        self.key = key

    def read(self) -> Segment:
        with open(self.path, "rb") as f:
            raw = f.read()
        lines = raw.split(b"\n")
        if not lines or not lines[0]:
            raise JournalError(f"empty journal segment {self.path}")
        header = self._gate_header(lines[0])
        events: List[RunEvent] = []
        resumes = 0
        offset = len(lines[0]) + 1
        truncated = False
        for line in lines[1:]:
            if not line:        # blank filler (or the trailing split)
                offset += 1
                continue
            try:
                d = json.loads(line.decode("utf-8"))
                if "resume" in d and "type" not in d:
                    resumes = max(resumes, int(d["resume"]))
                else:
                    events.append(from_wire(d))
            except CORRUPT_ENTRY_ERRORS + (WireVersionError,):
                # torn tail or corrupt middle: the history after this
                # point cannot be ordered/trusted — truncate here
                truncated = True
                break
            offset += len(line) + 1
        return Segment(key=self.key, path=self.path, header=header,
                       events=events, resumes=resumes,
                       truncated=truncated,
                       valid_bytes=min(offset, len(raw)))

    def _gate_header(self, line: bytes) -> Dict[str, Any]:
        try:
            header = json.loads(line.decode("utf-8"))
        except (ValueError, UnicodeDecodeError):
            raise JournalError(
                f"unreadable journal header in {self.path}") from None
        if not isinstance(header, dict) \
                or header.get("format") != JOURNAL_FORMAT:
            raise JournalError(f"{self.path} is not a run-journal segment")
        if header.get("version") != JOURNAL_VERSION:
            raise JournalVersionError(
                f"journal segment version {header.get('version')!r} != "
                f"{JOURNAL_VERSION} in {self.path}")
        if header.get("wire_version", 0) < WIRE_VERSION:
            raise JournalVersionError(
                f"journal segment wire schema "
                f"v{header.get('wire_version')!r} predates current "
                f"v{WIRE_VERSION} in {self.path}")
        return header


class RunJournal:
    """Directory of per-run segments; the object a ``Session`` carries.

    ``fsync_batch=1`` fsyncs every event (nothing lost on crash, max
    I/O); larger batches trade a re-executed tail on resume for fewer
    fsyncs — the classic group-commit knob."""

    def __init__(self, dir: str, fsync_batch: int = 8):
        self.dir = dir
        self.fsync_batch = fsync_batch
        os.makedirs(dir, exist_ok=True)

    # -- addressing -----------------------------------------------------
    def key_for(self, spec) -> Optional[str]:
        """The run-cache content address, or None for unjournalable
        specs (custom ``backend_factory``: no stable fingerprint)."""
        from ..apps.cache import spec_fingerprint
        return spec_fingerprint(spec)

    def path_for(self, key: str) -> str:
        return os.path.join(self.dir, f"run_{key}.jsonl")

    # -- reading --------------------------------------------------------
    def read(self, key: str) -> Optional[Segment]:
        """Parse one segment (corrupt-tail truncation applied).  Returns
        None when no segment exists; raises :class:`JournalError` /
        :class:`JournalVersionError` on untrustworthy ones."""
        path = self.path_for(key)
        if not os.path.exists(path):
            return None
        return JournalReader(path, key).read()

    def keys(self) -> List[str]:
        return sorted(name[len("run_"):-len(".jsonl")]
                      for name in os.listdir(self.dir)
                      if name.startswith("run_")
                      and name.endswith(".jsonl"))

    def interrupted(self) -> List[str]:
        """Keys of journaled-but-dead runs: segments with committed
        events whose stream does not terminate in ``RunCompleted``."""
        out = []
        for key in self.keys():
            try:
                seg = self.read(key)
            except JournalError:
                continue
            if seg is not None and seg.events and not seg.complete:
                out.append(key)
        return out

    # -- writing --------------------------------------------------------
    def begin(self, key: str, spec) -> JournalWriter:
        """Open a FRESH segment for a new execution of ``spec``
        (truncates any previous segment under this key — a re-executed
        run re-journals from scratch)."""
        path = self.path_for(key)
        f = open(path, "w")
        f.write(json.dumps({"format": JOURNAL_FORMAT,
                            "version": JOURNAL_VERSION,
                            "wire_version": WIRE_VERSION,
                            "key": key,
                            "spec": spec_to_wire(spec)}) + "\n")
        f.flush()
        os.fsync(f.fileno())
        return JournalWriter(f, path, skip=0, fsync_batch=self.fsync_batch)

    def resume_writer(self, segment: Segment) -> JournalWriter:
        """Re-open an interrupted segment to continue it: repair a torn
        tail (atomic rewrite of the valid prefix), append a resume
        marker, and skip the ``len(segment.events)`` committed events
        the replay will re-emit."""
        if segment.truncated:
            # corrupt-tail truncation on open: atomically rewrite the
            # intact prefix so the appended suffix lands on a clean line
            # boundary (a plain os.truncate could die halfway too)
            with open(segment.path, "rb") as f:
                intact = f.read(segment.valid_bytes).decode("utf-8")
            atomic_write_text(segment.path, intact)
        f = open(segment.path, "a")
        f.write(json.dumps({"resume": segment.resumes + 1,
                            "committed": len(segment.events)}) + "\n")
        f.flush()
        os.fsync(f.fileno())
        return JournalWriter(f, segment.path, skip=len(segment.events),
                             fsync_batch=self.fsync_batch)

    # -- maintenance ----------------------------------------------------
    def discard(self, key: str) -> bool:
        try:
            os.remove(self.path_for(key))
            return True
        except OSError:
            return False

    def __len__(self) -> int:
        return len(self.keys())
