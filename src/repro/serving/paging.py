"""Block-paged KV storage: the allocator and the prefix cache.

vLLM-style paging for the serving engine: the decode cache stops being a
dense ``n_slots x max_len`` buffer and becomes a pool of fixed-size
*blocks* (``block_size`` token rows each).  Every live sequence owns a
*block table* — the ordered list of block ids whose concatenation is its
logical KV layout — and blocks are **refcounted** so the same physical
block can back many sequences at once.  That sharing is what makes
prefix reuse possible: the blocks holding a hot system-prompt /
tool-catalog prefix are prefilled once and referenced by every request
that starts the same way.

This module is deliberately *host-side and array-free*: it manages block
ids, refcounts, the free list and the content-keyed prefix index.  The
device-side pool arrays (and the gather/scatter of rows through block
tables) live in :mod:`repro.models.model` and
:mod:`repro.serving.scheduler`; the TPU kernel that reads K/V through a
block table without materializing the gather is
:func:`repro.kernels.decode_attention.paged_decode_attention`.

Invariants (fuzz-enforced by ``tests/test_paging.py``):

  * a block's refcount equals the number of live references to it
    (sequence block-table entries + prefix-cache entries);
  * a block is on the free list iff its refcount is zero — no
    double-free, no leaked block: ``free + in_use == n_blocks`` always;
  * :meth:`BlockAllocator.fork` (copy-on-write) never hands out a
    shared block for writing — a block with refcount > 1 is replaced by
    a fresh block (the caller copies the data), the share stays intact.

Prefix keys form a **hash chain** (the same construction as the run
cache's fingerprint chain, see docs/ARCHITECTURE.md): block *i*'s key is
``sha256(key_{i-1} || tokens_of_block_i)`` seeded by a salt that
includes the serving fingerprint — so a key commits to the *entire*
token prefix up to and including its block, and two caches serving
different models/engines can never alias.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple


class PagingError(RuntimeError):
    """Raised on allocator misuse (double-free, unknown block id)."""


class BlockAllocator:
    """Refcounted fixed-size block pool with a deterministic free list.

    Pure bookkeeping: block *ids* in ``[0, n_blocks)``, their refcounts,
    and a FIFO free list (deterministic reuse order keeps paged runs
    reproducible).  Data movement (zeroing, CoW copies) is the caller's
    job — the allocator tells it *which* physical block to touch.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks <= 0 or block_size <= 0:
            raise ValueError("n_blocks and block_size must be positive")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._ref: List[int] = [0] * n_blocks
        # FIFO free list: freed blocks recycle oldest-first
        self._free: List[int] = list(range(n_blocks))

    # -- introspection ------------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_blocks - len(self._free)

    def ref(self, bid: int) -> int:
        self._check(bid)
        return self._ref[bid]

    def _check(self, bid: int) -> None:
        if not 0 <= bid < self.n_blocks:
            raise PagingError(f"unknown block id {bid}")

    # -- lifecycle ----------------------------------------------------------
    def alloc(self) -> Optional[int]:
        """Take one block off the free list (ref := 1); ``None`` when the
        pool is exhausted (the caller evicts prefix-cache entries or
        defers admission)."""
        if not self._free:
            return None
        bid = self._free.pop(0)
        self._ref[bid] = 1
        return bid

    def incref(self, bid: int) -> None:
        self._check(bid)
        if self._ref[bid] <= 0:
            raise PagingError(f"incref on free block {bid}")
        self._ref[bid] += 1

    def decref(self, bid: int) -> bool:
        """Drop one reference; returns True when this freed the block."""
        self._check(bid)
        if self._ref[bid] <= 0:
            raise PagingError(f"double free of block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)
            return True
        return False

    def fork(self, bid: int) -> Optional[Tuple[int, bool]]:
        """Copy-on-write: make ``bid`` safely writable by its caller.

        ref == 1: the caller is the sole owner — returns ``(bid, False)``
        (write in place).  ref > 1: allocates a fresh block, moves one of
        the references onto it and returns ``(new_bid, True)`` — the
        caller must copy the block's data before writing; the shared
        original is never mutated.  ``None`` when a copy is needed but
        the pool is exhausted.
        """
        self._check(bid)
        if self._ref[bid] <= 0:
            raise PagingError(f"fork of free block {bid}")
        if self._ref[bid] == 1:
            return bid, False
        new = self.alloc()
        if new is None:
            return None
        self._ref[bid] -= 1   # shared: never drops to zero here
        return new, True


def prefix_block_keys(ids: Sequence[int], block_size: int,
                      salt: str = "") -> List[str]:
    """Chained content keys for every *full* block of ``ids``.

    ``key_i = sha256(key_{i-1} || tokens_of_block_i)`` — the same
    chain-of-custody construction as the run-cache fingerprint chain: a
    block's key commits to the whole prefix before it, so a key match
    implies the entire leading token sequence matches.  ``salt`` scopes
    the chain (serving fingerprint: model arch, block size) so caches
    never alias across engines.
    """
    keys: List[str] = []
    h = hashlib.sha256(f"prefix-chain:{salt}:{block_size}".encode())
    for i in range(len(ids) // block_size):
        block = ids[i * block_size:(i + 1) * block_size]
        h.update((",".join(str(t) for t in block) + ";").encode())
        keys.append(h.hexdigest())
    return keys


class PrefixCache:
    """Content-addressed index of prefilled prefix blocks (LRU).

    Maps chained block keys (:func:`prefix_block_keys`) to block ids in
    a :class:`BlockAllocator` pool.  The cache holds ONE reference per
    entry, so cached blocks survive the sequences that prefilled them;
    eviction (LRU) drops that reference and the allocator reclaims the
    block once no live sequence shares it.

    ``match`` walks the chain until the first miss and returns the
    shared blocks a new request can skip prefilling; the usable prefix
    is capped at ``len(ids) - 1`` rounded down to a block boundary — at
    least one prompt token is always freshly prefilled, because the
    admission path needs last-position logits to sample the first token
    (exactly vLLM's full-prompt-hit rule).
    """

    def __init__(self, allocator: BlockAllocator, salt: str = ""):
        self.allocator = allocator
        self.block_size = allocator.block_size
        self.salt = salt
        self._entries: "OrderedDict[str, int]" = OrderedDict()
        self.hits = 0        # admissions that reused >= 1 block
        self.misses = 0      # admissions that reused none
        self.tokens_reused = 0

    def __len__(self) -> int:
        return len(self._entries)

    def cached_block_ids(self) -> List[int]:
        return list(self._entries.values())

    def match(self, ids: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``ids`` in full blocks.

        Returns ``(n_tokens, block_ids)`` with ``n_tokens`` a multiple
        of the block size and ``< len(ids)``.  Does NOT take references —
        the caller pins the returned blocks (``incref``) into the
        admitted sequence's table before anything can evict them.
        """
        bs = self.block_size
        usable = max(0, (len(ids) - 1) // bs)   # never the whole prompt
        keys = prefix_block_keys(list(ids)[:usable * bs], bs, self.salt)
        bids: List[int] = []
        for key in keys:
            bid = self._entries.get(key)
            if bid is None:
                break
            self._entries.move_to_end(key)      # LRU touch
            bids.append(bid)
        if bids:
            self.hits += 1
            self.tokens_reused += len(bids) * bs
        else:
            self.misses += 1
        return len(bids) * bs, bids

    def insert(self, ids: Sequence[int], blocks: Sequence[int]) -> int:
        """Index the full prompt blocks of a freshly admitted sequence.

        ``blocks`` is the sequence's block table; every full block of
        ``ids`` not already cached gains a cache entry + one reference.
        Already-cached keys keep their existing block (first writer
        wins — the contents are identical by construction).  Returns the
        number of new entries.
        """
        bs = self.block_size
        keys = prefix_block_keys(ids, bs, self.salt)
        added = 0
        for i, key in enumerate(keys):
            if i >= len(blocks):
                break
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            self.allocator.incref(blocks[i])
            self._entries[key] = blocks[i]
            added += 1
        return added

    def evict(self, n_blocks: int = 1) -> int:
        """Drop up to ``n_blocks`` LRU entries' references; returns how
        many blocks this actually freed (shared blocks stay alive until
        their sequences finish)."""
        freed = 0
        while n_blocks > 0 and self._entries:
            _, bid = self._entries.popitem(last=False)
            if self.allocator.decref(bid):
                freed += 1
            n_blocks -= 1
        return freed

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "tokens_reused": self.tokens_reused}
