"""Serving engine: batched prefill + decode with per-arch cache handling.

The engine backs the ``@register_llm_backend`` serving backends
(:mod:`repro.serving.api`) — the agents' LLM endpoint — and the
serving-side benchmarks. Request flow mirrors production servers:
tokenize -> prefill (cache warm-up) -> sampled decode loop -> detokenize,
with a slot-based continuous-batching scheduler in ``scheduler.py`` that
multiplexes many concurrent requests onto one jitted ``decode_step``.

Sampling is keyed by ``(engine seed, request id, step)`` — never by
shared mutable RNG state — so a request samples the identical token
sequence whether it runs alone, serially after other requests, or inside
a decode batch (and the engine is thread-safe).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import events as run_events
from ..data.tokenizer import HashTokenizer
from ..models.model import (decode_step, init_cache, prefill, prefill_attend,
                            prefill_fresh)
from ..models.params import init_params


def prefill_bucket(n: int, floor: int = 8) -> int:
    """Power-of-two length bucket for an ``n``-token prompt (min
    ``floor``).  Admission pads prompts to their bucket so one jitted
    prefill trace serves every length in it — the lever that removes
    per-length recompiles from the admission path."""
    return max(floor, 1 << max(n - 1, 0).bit_length())


def cache_leaf_name(path) -> Optional[str]:
    """Name of a cache leaf ("k"/"v"/"ckv"/"kpe"/"conv"/"ssd") from its
    tree path — shared by seq-axis padding here and the slot-batch row
    writes in ``scheduler.write_slot``."""
    for p in reversed(path):
        if hasattr(p, "key"):
            return p.key
    return None


def pad_cache_to(cfg: ModelConfig, cache, target_len: int):
    """Grow a prefill cache (len S) to ``target_len`` along the seq axis.
    SSM states are length-free; sliding-window caches are re-rolled into
    ring layout."""
    window = cfg.sliding_window

    def pad(path, x):
        name = cache_leaf_name(path)
        if name not in ("k", "v", "ckv", "kpe"):
            return x
        seq_axis = x.ndim - 3 if name in ("k", "v") else x.ndim - 2
        s = x.shape[seq_axis]
        if window and s > window:
            # keep last `window` rows in ring layout: row p -> slot p%window
            idx = jnp.arange(s - window, s)
            slots = idx % window
            sl = [slice(None)] * x.ndim
            sl[seq_axis] = idx
            vals = x[tuple(sl)]
            out = jnp.zeros(x.shape[:seq_axis] + (window,) + x.shape[seq_axis + 1:],
                            x.dtype)
            order = jnp.argsort(slots)
            sl2 = [slice(None)] * x.ndim
            sl2[seq_axis] = slots[order]
            sl3 = [slice(None)] * x.ndim
            sl3[seq_axis] = order
            return out.at[tuple(sl2)].set(vals[tuple(sl3)])
        target = min(target_len, window) if window else target_len
        if s >= target:
            return x
        widths = [(0, 0)] * x.ndim
        widths[seq_axis] = (0, target - s)
        return jnp.pad(x, widths)

    return jax.tree_util.tree_map_with_path(pad, cache)


@dataclasses.dataclass
class GenerationResult:
    text: str
    prompt_tokens: int
    new_tokens: int
    token_ids: List[int]


class RunMonitor:
    """Live serving-side observer of agent runs.

    Subscribe it to the orchestration event stream
    (``Session(on_event=RunMonitor())``) and it aggregates in-flight
    demand on the serving engine — LLM calls, token volume, tool and
    framework activity — *while* runs execute, instead of post-hoc trace
    mining. Thread-safe: ``Session.execute_many`` delivers events from
    worker threads.

    Since the telemetry subsystem landed, the monitor is a *thin view*
    over a :class:`repro.telemetry.MetricsRegistry`: every event is
    folded by an :class:`repro.telemetry.EventMetricsBridge` and the
    historical counters (``runs_started``, ``engine_steps``, ...) are
    read-only properties derived from the registry's series, so the
    same numbers are available as Prometheus/OTLP exports via
    ``monitor.registry`` with zero double counting.  The public surface
    — attribute names, ``snapshot()`` keys, ``wire_observer()`` — is
    unchanged.

    ``runs_succeeded`` counts pattern-level completion
    (``RunCompleted.completed``); artifact location and judge gating
    happen after the run, so it can exceed the number of runs whose
    ``RunResult.success`` is True.

    Subscribe it to a :class:`BatchScheduler` too
    (``BatchScheduler(..., on_event=monitor)`` or
    ``scheduler.subscribe(monitor)``) and the serving-side
    ``EngineStepped`` stream keeps live engine-occupancy gauges:
    decode-batch fill, queue depth, tokens decoded.

    Per-tenant gauges (multi-tenant serving): ``RunStarted.tenant``
    opens a run's billing context — a run's events all arrive on the
    thread executing it, so the current tenant is tracked thread-locally
    between ``RunStarted`` and ``RunCompleted`` — and the admission
    events (``RunDegraded`` / ``BudgetExceeded``) carry their tenant
    explicitly.  ``tenants`` maps tenant -> {runs, completed, llm_calls,
    tokens, cost_usd, degraded, rejected}.
    """

    # tenant label values are unioned across these families so a tenant
    # seen only at admission (degraded/rejected before any run) still
    # gets a gauge row, exactly like the pre-registry monitor
    _TENANT_FAMILIES = (
        "repro_tenant_runs_total", "repro_tenant_completed_total",
        "repro_tenant_llm_calls_total", "repro_tenant_tokens_total",
        "repro_tenant_spend_usd_total", "repro_tenant_degraded_total",
        "repro_tenant_rejected_total")

    def __init__(self, registry=None, bridge=None):
        # lazy import: telemetry stays un-imported until a monitor (or a
        # bridge) is actually constructed — serving hot paths that never
        # attach one run the exact pre-telemetry import graph
        from ..telemetry.bridge import EventMetricsBridge
        if bridge is not None:
            self.bridge = bridge
            self.registry = bridge.registry
        else:
            self.bridge = EventMetricsBridge(registry)
            self.registry = self.bridge.registry

    def __call__(self, event) -> None:
        self.bridge(event)

    def wire_observer(self):
        """Observer accepting wire-serialized event dicts
        (``repro.core.events.to_wire``) — subscribe it where raw wire
        payloads arrive (e.g. an A2A task envelope) without deserializing
        at the call site."""
        def observe(wire_dict) -> None:
            self(run_events.from_wire(wire_dict))
        return observe

    # -- derived counters (registry reads) -----------------------------------
    def _total(self, name: str) -> int:
        return int(self.registry.total(name))

    def _gauge(self, name: str, **labels) -> int:
        g = self.registry.get(name)
        return int(g.value(**labels)) if g is not None else 0

    @property
    def runs_started(self) -> int:
        return self._total("repro_runs_started_total")

    @property
    def runs_completed(self) -> int:
        return self._total("repro_runs_completed_total")

    @property
    def runs_succeeded(self) -> int:
        c = self.registry.get("repro_runs_completed_total")
        return int(c.value(completed="true")) if c is not None else 0

    @property
    def in_flight(self) -> int:
        return self.runs_started - self.runs_completed

    @property
    def llm_calls(self) -> int:
        return self._total("repro_llm_calls_total")

    @property
    def input_tokens(self) -> int:
        c = self.registry.get("repro_llm_tokens_total")
        return int(c.value(direction="input")) if c is not None else 0

    @property
    def output_tokens(self) -> int:
        c = self.registry.get("repro_llm_tokens_total")
        return int(c.value(direction="output")) if c is not None else 0

    @property
    def tool_calls(self) -> int:
        return self._total("repro_tool_calls_total")

    @property
    def tool_errors(self) -> int:
        series = self.registry.series_values("repro_tool_calls_total")
        return int(sum(v for k, v in series.items()
                       if dict(k).get("ok") == "false"))

    @property
    def framework_events(self) -> int:
        return self._total("repro_framework_overhead_total")

    @property
    def calls_per_agent(self) -> Dict[str, int]:
        series = self.registry.series_values("repro_llm_calls_total")
        out: Dict[str, int] = {}
        for key, v in series.items():
            agent = dict(key).get("agent", "")
            out[agent] = out.get(agent, 0) + int(v)
        return out

    # serving-side gauges (EngineStepped stream)
    @property
    def engine_steps(self) -> int:
        return self._total("repro_engine_steps_total")

    @property
    def engine_live(self) -> int:
        return self._gauge("repro_engine_live")

    @property
    def engine_queued(self) -> int:
        return self._gauge("repro_engine_queue_depth")

    @property
    def engine_peak_live(self) -> int:
        return self._gauge("repro_engine_peak_live")

    @property
    def engine_tokens(self) -> int:
        return self._total("repro_engine_decode_tokens_total")

    @property
    def engine_prefill_tokens(self) -> int:
        return self._total("repro_engine_prefill_tokens_total")

    @property
    def engine_preemptions(self) -> int:
        return self._total("repro_engine_preemptions_total")

    @property
    def engine_blocks_in_use(self) -> int:
        return self._gauge("repro_engine_blocks_in_use")

    @property
    def engine_prefix_hits(self) -> int:
        return self._total("repro_engine_prefix_hits_total")

    # per-tenant gauges (multi-tenant serving)
    @property
    def tenants(self) -> Dict[str, Dict[str, Any]]:
        r = self.registry
        names = set()
        for fam in self._TENANT_FAMILIES:
            names.update(r.label_values(fam, "tenant"))
        rejected: Dict[str, int] = {}
        for key, v in r.series_values(
                "repro_tenant_rejected_total").items():
            t = dict(key).get("tenant", "")
            rejected[t] = rejected.get(t, 0) + int(v)
        spend = r.get("repro_tenant_spend_usd_total")

        def val(fam: str, tenant: str) -> int:
            m = r.get(fam)
            return int(m.value(tenant=tenant)) if m is not None else 0

        return {
            t: {
                "runs": val("repro_tenant_runs_total", t),
                "completed": val("repro_tenant_completed_total", t),
                "llm_calls": val("repro_tenant_llm_calls_total", t),
                "tokens": val("repro_tenant_tokens_total", t),
                "cost_usd": (spend.value(tenant=t, eq="1")
                             if spend is not None else 0.0),
                "degraded": val("repro_tenant_degraded_total", t),
                "rejected": rejected.get(t, 0),
            }
            for t in sorted(names)
        }

    def snapshot(self) -> Dict[str, Any]:
        # the registry RLock makes the cross-family read atomic, like
        # the single monitor lock did pre-refactor
        with self.registry._lock:
            return {
                "runs_started": self.runs_started,
                "runs_completed": self.runs_completed,
                "runs_succeeded": self.runs_succeeded,
                "in_flight": self.in_flight,
                "llm_calls": self.llm_calls,
                "input_tokens": self.input_tokens,
                "output_tokens": self.output_tokens,
                "tool_calls": self.tool_calls,
                "tool_errors": self.tool_errors,
                "framework_events": self.framework_events,
                "calls_per_agent": self.calls_per_agent,
                "engine_steps": self.engine_steps,
                "engine_live": self.engine_live,
                "engine_queued": self.engine_queued,
                "engine_peak_live": self.engine_peak_live,
                "engine_tokens": self.engine_tokens,
                "engine_prefill_tokens": self.engine_prefill_tokens,
                "engine_preemptions": self.engine_preemptions,
                "engine_blocks_in_use": self.engine_blocks_in_use,
                "engine_prefix_hits": self.engine_prefix_hits,
                "tenants": self.tenants,
            }


def _sample_row(logits: jax.Array, key: jax.Array, temperature: float,
                top_p: float) -> jax.Array:
    """Sample one token from a single (V,) logits row.

    The batched scheduler vmaps this over slot rows and the serial path
    calls it on a 1-row batch, so a request's sampled tokens are identical
    either way (given the same per-request key).
    """
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1)


class Engine:
    """The serving model runner: prefill + decode + keyed sampling.

    ``prefill_chunk`` > 0 enables chunked prefill as part of the
    CANONICAL prefill recipe: prompts longer than the chunk budget are
    prefilled in fixed-shape chunks (``prefill_job``) by *both* the
    serial ``generate_ids`` path and the batch scheduler's admission —
    sharing the recipe is what keeps chunked admission bit-identical to
    serial generation.
    """

    def __init__(self, cfg: ModelConfig, params=None, seed: int = 0,
                 dtype=jnp.float32, temperature: float = 1.0,
                 top_p: float = 1.0, prefill_chunk: int = 0):
        self.cfg = cfg
        self.tokenizer = HashTokenizer(cfg.vocab_size)
        key = jax.random.key(seed)
        self.params = params if params is not None else init_params(
            cfg, key, dtype=dtype)
        self.temperature = temperature
        self.top_p = top_p
        self.prefill_chunk = int(prefill_chunk)
        self._prefill = jax.jit(functools.partial(prefill, cfg=cfg))
        # fixed-shape prefill pair (bucketed whole prompts / chunks):
        # one trace per (batch, bucket, cache_len) — lengths and offset
        # are traced values, so every prompt length in a bucket shares it
        self._prefill_fixed = jax.jit(
            functools.partial(prefill_fresh, cfg=cfg),
            static_argnames=("cache_len",))
        self._prefill_extend = jax.jit(
            functools.partial(prefill_attend, cfg=cfg),
            donate_argnames=("cache",))
        # cache is donated: the decode loop threads it linearly, and the
        # in-place update keeps the per-step cost flat in cache size
        # (without donation XLA copies the whole slot batch every step)
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg),
                               donate_argnames=("cache",))
        self._base_key = jax.random.key(seed + 1)
        self._sampler = None
        self._sampler_knobs = None

    @property
    def supports_fixed_shape_prefill(self) -> bool:
        """True when the arch can use the bucketed/chunked admission
        recipe (:func:`repro.models.model.prefill_attend`): attention
        caches written at absolute positions.

        Excluded (they keep the exact-length recipe): recurrent-state
        archs (SSM/hybrid — padded tokens would pollute conv/SSD
        states), sliding-window ring caches (the ring re-roll would
        rotate padded rows in), frontend archs, and MoE — the
        capacity-factor dispatch routes over every token in the call, so
        padded rows change which real tokens get dropped and padding
        invariance cannot hold bitwise."""
        cfg = self.cfg
        return (cfg.arch_type not in ("ssm", "hybrid")
                and not cfg.sliding_window and not cfg.frontend
                and not cfg.is_moe)

    def _get_sampler(self):
        """Jitted sampler for the CURRENT (temperature, top_p) — the
        knobs steer python-level branches, so they are baked into the
        trace; mutating them rebuilds the sampler (one retrace)."""
        knobs = (self.temperature, self.top_p)
        if knobs != self._sampler_knobs:
            base_key, (temperature, top_p) = self._base_key, knobs

            def sampler(logits, rids, steps):
                keys = jax.vmap(lambda r, s: jax.random.fold_in(
                    jax.random.fold_in(base_key, r), s))(rids, steps)
                row = functools.partial(_sample_row, temperature=temperature,
                                        top_p=top_p)
                return jax.vmap(row)(logits, keys)

            self._sampler = jax.jit(sampler)
            self._sampler_knobs = knobs
        return self._sampler

    def sample(self, logits: jax.Array, rids, steps) -> jax.Array:
        """Per-row sampling keyed by (engine seed, request id, step).

        logits: (B, V); rids/steps: length-B int sequences. Stateless —
        results are independent of request interleaving and of whether
        rows share a batch.
        """
        return self._get_sampler()(logits, jnp.asarray(rids, jnp.int32),
                                   jnp.asarray(steps, jnp.int32))

    def generate(self, prompt: str, max_new_tokens: int = 32,
                 rid: int = 0, priority: int = 0,
                 tenant: str = "") -> GenerationResult:
        """``priority`` and ``tenant`` are accepted (and ignored) so
        ``Engine`` and ``EngineClient`` stay interchangeable endpoints
        for ``JaxLLMBackend``; only the scheduler-backed client uses
        them."""
        ids = self.tokenizer.encode(prompt)
        return self.generate_ids(ids, max_new_tokens, rid=rid)

    def prefill_ids(self, ids: List[int], cache_len: int):
        """Prefill one request (batch 1) into a ``cache_len``-length
        cache (+ frontend offset). Returns (last logits (1, V), cache).

        THE canonical prefill recipe — the serial ``generate_ids`` loop,
        the batch scheduler's admission and preemption-resume replay all
        call it (or its batched row-stable equivalent), which is what
        keeps batched/chunked decode bit-identical to serial generation.
        On archs supporting fixed-shape prefill the prompt is padded to
        its power-of-two bucket (one compile per bucket instead of one
        per length) and, when ``prefill_chunk`` is set and the prompt
        exceeds it, prefilled chunk-by-chunk via :meth:`prefill_job`."""
        if not self.supports_fixed_shape_prefill:
            return self.prefill_ids_exact(ids, cache_len)
        if self.prefill_chunk and len(ids) > self.prefill_chunk:
            job = self.prefill_job(ids, cache_len)
            while not job.done:
                job.step()
            return job.logits, job.cache
        bucket = prefill_bucket(len(ids))
        tokens = jnp.asarray([list(ids) + [0] * (bucket - len(ids))],
                             jnp.int32)
        lengths = jnp.asarray([len(ids)], jnp.int32)
        return self._prefill_fixed(self.params, tokens=tokens,
                                   lengths=lengths,
                                   cache_len=int(cache_len))

    def prefill_ids_exact(self, ids: List[int], cache_len: int):
        """The historical exact-length prefill: one trace per prompt
        length, cache padded (or ring re-rolled) to ``cache_len``
        afterwards. Canonical for SSM/hybrid/sliding-window/frontend
        archs; kept callable everywhere as the pre-bucketing baseline
        (``benchmarks/serving.py`` measures admission latency against
        it)."""
        cfg = self.cfg
        prompt = jnp.asarray([ids], jnp.int32)
        fe = None
        if cfg.frontend:
            fe = jnp.zeros((1, cfg.frontend_positions, cfg.d_model),
                           self.params["embed"].dtype)
        logits, cache = self._prefill(self.params, tokens=prompt,
                                      frontend_embeds=fe)
        cache = pad_cache_to(cfg, cache, cache_len +
                             (cfg.frontend_positions if cfg.frontend else 0))
        return logits, cache

    def prefill_batch_ids(self, ids_list: List[List[int]], cache_len: int,
                          width: Optional[int] = None):
        """Bucketed BATCHED prefill: stack several prompts (padded to the
        shared power-of-two bucket of the longest, batch padded to
        ``width`` rows) and prefill them in ONE jitted call.

        Row results are bit-identical to batch-1 :meth:`prefill_ids` of
        each prompt (batch stacking at a fixed padded length is
        row-stable), so the scheduler can admit a burst of requests
        together without breaking serial parity. Returns
        (logits (width, V), cache with a ``width`` batch axis); callers
        read the first ``len(ids_list)`` rows.
        """
        width = width if width is not None else len(ids_list)
        bucket = prefill_bucket(max(len(i) for i in ids_list))
        rows = [list(i) for i in ids_list] + [[0]] * (width - len(ids_list))
        tokens = jnp.asarray([r + [0] * (bucket - len(r)) for r in rows],
                             jnp.int32)
        lengths = jnp.asarray([len(r) for r in rows], jnp.int32)
        return self._prefill_fixed(self.params, tokens=tokens,
                                   lengths=lengths,
                                   cache_len=int(cache_len))

    def prefill_job(self, ids: List[int], cache_len: int) -> "PrefillJob":
        """Incremental chunked prefill: a :class:`PrefillJob` whose
        ``step()`` prefills ONE ``prefill_chunk``-sized chunk — the
        scheduler interleaves these steps with live decode so a long
        prompt bounds (instead of monopolizing) the stall it causes."""
        return PrefillJob(self, ids, cache_len)

    def prefill_continue(self, ids: List[int], start: int, cache):
        """Prefill only ``ids[start:]`` against a cache whose rows
        ``0..start-1`` already hold the prompt's prefix K/V — the
        prefix-reuse admission recipe (paged serving): a prefix-cache hit
        hands the scheduler the shared blocks, and only the divergent
        suffix runs through the model.

        Bit-identical to whole-prompt :meth:`prefill_ids` by the chunked
        ==-whole argument: :func:`repro.models.model.prefill_attend`
        continuation is split-agnostic (every query attends over the
        full cache width under the ``col <= q_pos`` validity mask, and
        padded suffix rows sit beyond every valid query's mask), so
        resuming at ``start`` over reused rows reproduces the exact
        logits the full prefill would have produced.  The suffix is
        padded to its power-of-two bucket — same trace economy as
        admission.  Returns (last logits (1, V), cache); ``cache`` is
        donated."""
        suffix = list(ids)[start:]
        bucket = prefill_bucket(len(suffix))
        tokens = jnp.asarray([suffix + [0] * (bucket - len(suffix))],
                             jnp.int32)
        return self._prefill_extend(self.params, cache=cache, tokens=tokens,
                                    off=jnp.int32(start),
                                    lengths=jnp.asarray([len(suffix)],
                                                        jnp.int32))

    def replay_ids(self, ids: List[int], kept: List[int], cache_len: int):
        """Rebuild the exact decode state of a request that already
        generated ``kept`` tokens (preemption resume): canonical prefill
        of the prompt, then per-token decode replay of ``kept[:-1]``.

        Replay — not re-prefill of prompt+kept — because prefill and
        decode group their float reductions differently: a prefilled row
        is not bitwise the row decode would have written.  Replaying the
        identical jitted decode calls in the identical order *is* bitwise
        (already-sampled tokens are never resampled), so a preempted
        request resumes onto exactly the uninterrupted token stream.
        Returns (cache, next_pos, next_token) ready for ``write_slot``.
        """
        _, cache = self.prefill_ids(ids, cache_len)
        offset = self.cfg.frontend_positions if self.cfg.frontend else 0
        base = offset + len(ids)
        for i, tok in enumerate(kept[:-1]):
            _, cache = self._decode(self.params, cache=cache,
                                    token=jnp.asarray([[tok]], jnp.int32),
                                    pos=jnp.int32(base + i))
        return cache, base + len(kept) - 1, kept[-1]

    def generate_ids(self, ids: List[int], max_new_tokens: int,
                     rid: int = 0, cache_len: Optional[int] = None
                     ) -> GenerationResult:
        """Serial per-request generation.

        ``rid`` keys the sampling RNG; ``cache_len`` fixes the decode
        cache length (defaults to exactly prompt+new tokens — pass the
        scheduler's ``max_len`` to compare against batched decode under
        identical shapes).
        """
        cfg = self.cfg
        total = cache_len if cache_len is not None else (
            len(ids) + max_new_tokens)
        logits, cache = self.prefill_ids(ids, total)
        new_ids: List[int] = []
        tok = self.sample(logits, [rid], [0])
        offset = cfg.frontend_positions if cfg.frontend else 0
        for i in range(max_new_tokens):
            new_ids.append(int(tok[0]))
            if int(tok[0]) == self.tokenizer.eos:
                break
            pos = jnp.int32(offset + len(ids) + i)
            logits, cache = self._decode(self.params, cache=cache,
                                         token=tok[:, None], pos=pos)
            tok = self.sample(logits, [rid], [i + 1])
        return GenerationResult(self.tokenizer.decode(new_ids), len(ids),
                                len(new_ids), new_ids)

    def score(self, text: str) -> float:
        """Mean NLL of text under the model (used by eval harnesses)."""
        from ..models.model import loss_fn
        ids = self.tokenizer.encode(text)[:512]
        batch = {"tokens": jnp.asarray([ids], jnp.int32)}
        loss, _ = loss_fn(self.params, self.cfg, batch)
        return float(loss)


class PrefillJob:
    """Chunk-at-a-time prefill of one prompt (batch 1).

    Every ``step()`` runs one fixed-shape ``prefill_chunk``-token chunk
    through :func:`repro.models.model.prefill_attend` against the
    accumulating cache (the final partial chunk is right-padded to the
    same shape, so ONE jitted trace serves every chunk of every prompt).
    ``done`` flips once the whole prompt is in; ``logits`` then holds the
    last-position logits to sample the first token from, and ``cache``
    the full prefilled cache ready for ``write_slot``.

    Both ``Engine.prefill_ids`` (synchronous drain: the serial recipe)
    and ``BatchScheduler`` (one chunk per scheduler step, interleaved
    with live decode) drive the same job, so chunked admission stays
    bit-identical to serial generation.

    ``start`` > 0 resumes the job at that offset against a caller-built
    ``cache`` already holding rows ``0..start-1`` (prefix-reuse
    admission: shared blocks skip their chunks entirely) — the chunk
    trace is the same either way, only the traced offset differs.
    """

    def __init__(self, engine: Engine, ids: List[int], cache_len: int,
                 cache=None, start: int = 0):
        if not engine.supports_fixed_shape_prefill:
            raise NotImplementedError(
                f"chunked prefill needs fixed-shape prefill support; "
                f"{engine.cfg.name} uses the exact-length recipe")
        self.engine = engine
        self.ids = list(ids)
        self.cache_len = int(cache_len)
        self.chunk = max(1, engine.prefill_chunk or len(self.ids))
        self.start = int(start)
        self.off = self.start
        self.logits = None
        self.cache = cache if cache is not None else init_cache(
            engine.cfg, 1, self.cache_len,
            dtype=engine.params["embed"].dtype)

    @property
    def done(self) -> bool:
        return self.off >= len(self.ids)

    def step(self) -> int:
        """Prefill the next chunk; returns how many prompt tokens it
        consumed (the scheduler's ``prefilled`` gauge).

        No-op once ``done``: a prefix-reuse job whose suffix fits one
        chunk completes at creation, and the scheduler's next-step
        drive must not run a zero-length chunk over the finished
        logits."""
        if self.done:
            return 0
        chunk = self.ids[self.off:self.off + self.chunk]
        valid = len(chunk)
        tokens = jnp.asarray([chunk + [0] * (self.chunk - valid)], jnp.int32)
        self.logits, self.cache = self.engine._prefill_extend(
            self.engine.params, cache=self.cache, tokens=tokens,
            off=jnp.int32(self.off),
            lengths=jnp.asarray([valid], jnp.int32))
        self.off += valid
        return valid
