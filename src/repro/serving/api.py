"""Typed serving API: the ``@register_llm_backend`` registry.

Completes the registry trilogy — patterns (``@register_pattern``,
:mod:`repro.core.runtime`), deployments (``@register_deployment``,
:mod:`repro.faas.deployments`) and now LLM serving backends:
``RunSpec.llm`` names a registered :class:`ServingBackend` and
``Session.execute`` resolves it here with zero backend-name branches.

A :class:`ServingBackend` is a *factory for per-run LLM backends* plus
the engine lifecycle behind them: ``make(world, policy, trace)`` returns
the :class:`repro.core.llm.LLMBackend` a run talks to, while expensive
serving state (the JAX engine, the continuous-batching scheduler) is
built lazily once and shared across runs.  Its
:class:`ServingCapabilities` descriptor (real model? batched? which
arch? token budget?) feeds the run cache's content address
(:mod:`repro.apps.cache`) — retuning a backend invalidates cached runs
with no explicit flush — and tells ``Session`` nothing: prompt shaping
is the deployment's job, the brain's substrate is transparent to it.

Built-ins:

  - ``oracle`` — the deterministic seeded stand-in (paper protocol);
    decisions from the application policy, token/cost accounting from
    real prompt text. No model runs.
  - ``jax`` — the real JAX engine, one *unbatched* generate per agent
    call (kept as the simple reference path).
  - ``jax-batched`` — the same engine behind ``EngineClient``: every
    agent completion is submitted to the continuous-batching scheduler,
    so concurrent runs share one slot-batched decode.

    @register_llm_backend("jax-tuned", arch="qwen1.5-4b", n_slots=8)
    class TunedServing(JaxBatchedServing):
        ...

``reset_llm_backends()`` drops the lazily-built singleton instances
(tests; also frees engine memory).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional

from ..configs import get_config
from ..core.llm import JaxLLMBackend, LLMBackend, OracleLLMBackend
from ..core.runtime import stable_fingerprint

# NOTE: .engine/.scheduler (the JAX stack) are imported lazily inside the
# jax-backed backends — resolving "oracle" must stay jax-free.


@dataclasses.dataclass(frozen=True)
class ServingCapabilities:
    """What a serving backend runs — consumed by the run cache for
    fingerprinting and by observers/examples for display."""
    name: str = ""
    real_model: bool = False    # actual JAX forward passes per completion
    batched: bool = False       # multiplexed onto the slot-batched engine
    arch: str = ""              # ModelConfig zoo name (real backends)
    reduced: bool = True        # serve the smoke-test reduced variant
    max_gen: int = 0            # per-completion new-token budget (0 = backend default)
    n_slots: int = 0            # decode batch width (batched backends)
    max_len: int = 256          # slot context length
    temperature: float = 0.0    # greedy by default: deterministic serving
    prefill_chunk: int = 0      # chunked-prefill budget (0 = whole-prompt)
    paged: bool = False         # block-paged KV cache + prefix reuse
    block_size: int = 0         # KV block rows (paged backends; 0 = n/a)
    tags: tuple = ()
    rank: int = 50              # listing order

    def fingerprint(self) -> str:
        # The paged knobs joined the dataclass after runs were already
        # cached under the pre-paging digest; for non-paged backends they
        # are dropped from the payload so every existing run-cache
        # address stays valid.  Turning paging on (or retuning its block
        # size) changes the digest — paged serving is bit-identical to
        # contiguous BY TEST, not by assumption, so cached runs do not
        # silently cross that boundary.
        if not self.paged and not self.block_size:
            return stable_fingerprint(self, exclude=("paged", "block_size"))
        return stable_fingerprint(self)


class ServingBackend:
    """Base class: a named factory for per-run LLM backends + shared
    engine lifecycle, described by a :class:`ServingCapabilities`."""

    name = "base"
    default_capabilities = ServingCapabilities()

    def __init__(self, capabilities: Optional[ServingCapabilities] = None):
        self.capabilities = (capabilities if capabilities is not None
                             else type(self).default_capabilities)

    def make(self, world, policy, trace, priority: int = 0,
             tenant: str = "") -> LLMBackend:
        """Build the LLMBackend one run talks to.

        ``priority`` comes from ``RunSpec.priority``: scheduler-backed
        backends hand it to the serving engine's priority queue
        (admission order + slot preemption); others ignore it.
        ``tenant`` comes from ``RunSpec.tenant``: scheduler-backed
        backends stamp it on every submitted request so fair-share
        admission (:mod:`repro.tenancy.fair_share`) can queue per
        tenant; others ignore it."""
        raise NotImplementedError

    def subscribe(self, fn: Callable) -> None:
        """Subscribe to serving-side run events (``EngineStepped``).
        No-op for backends without an engine."""


@dataclasses.dataclass(frozen=True)
class RegisteredServing:
    name: str
    backend_cls: type
    capabilities: ServingCapabilities


_SERVING: Dict[str, RegisteredServing] = {}
_INSTANCES: Dict[str, ServingBackend] = {}
_SERVING_LOCK = threading.Lock()


def register_llm_backend(name: str, *, tags: tuple = (), **overrides):
    """Class decorator registering a serving backend class under ``name``
    with :class:`ServingCapabilities` overrides. Stack for variants."""
    def deco(cls):
        caps = dataclasses.replace(cls.default_capabilities, name=name,
                                   tags=tuple(tags), **overrides)
        with _SERVING_LOCK:
            _SERVING[name] = RegisteredServing(name, cls, caps)
            _INSTANCES.pop(name, None)
        return cls
    return deco


def resolve_llm_backend(name: str) -> RegisteredServing:
    try:
        return _SERVING[name]
    except KeyError:
        raise KeyError(f"unknown llm backend {name!r}; registered: "
                       f"{sorted(_SERVING)}") from None


def llm_backend_names(tag: Optional[str] = None) -> List[str]:
    named = [(rs.capabilities.rank, n) for n, rs in _SERVING.items()
             if tag is None or tag in rs.capabilities.tags]
    return [n for _, n in sorted(named)]


def get_llm_backend(name: str) -> ServingBackend:
    """Resolve ``name`` to its shared backend instance (lazily built:
    engines are expensive and serve many runs)."""
    rs = resolve_llm_backend(name)
    with _SERVING_LOCK:
        inst = _INSTANCES.get(name)
        if inst is None:
            inst = rs.backend_cls(capabilities=rs.capabilities)
            _INSTANCES[name] = inst
        return inst


def reset_llm_backends() -> None:
    """Drop all shared backend instances (their engines/schedulers)."""
    with _SERVING_LOCK:
        _INSTANCES.clear()


# ---------------------------------------------------------------------------
# built-in backends


@register_llm_backend("oracle", tags=("paper",), rank=10)
class OracleServing(ServingBackend):
    """Deterministic seeded stand-in for the paper's gpt-4o-mini brain."""

    name = "oracle"

    def make(self, world, policy, trace, priority: int = 0,
             tenant: str = "") -> LLMBackend:
        return OracleLLMBackend(world, policy, trace)


class _JaxServingBase(ServingBackend):
    """Shared lazy-engine lifecycle for the real-model backends."""

    default_capabilities = ServingCapabilities(
        real_model=True, arch="tinyllama-1.1b", max_gen=2)

    def __init__(self, capabilities: Optional[ServingCapabilities] = None):
        super().__init__(capabilities)
        self._lock = threading.Lock()
        self._engine = None

    def engine(self) -> "Engine":
        from .engine import Engine
        with self._lock:
            if self._engine is None:
                cfg = get_config(self.capabilities.arch)
                if self.capabilities.reduced:
                    cfg = cfg.reduced()
                self._engine = Engine(
                    cfg, temperature=self.capabilities.temperature,
                    prefill_chunk=self.capabilities.prefill_chunk)
            return self._engine

    def endpoint(self):
        """What ``JaxLLMBackend`` generates against."""
        return self.engine()

    def make(self, world, policy, trace, priority: int = 0,
             tenant: str = "") -> LLMBackend:
        return JaxLLMBackend(world, policy, self.endpoint(), trace,
                             max_gen=self.capabilities.max_gen or 16,
                             priority=priority, tenant=tenant)


@register_llm_backend("jax", rank=20)
class JaxServing(_JaxServingBase):
    """Real JAX engine, one unbatched generate per agent completion."""

    name = "jax"


@register_llm_backend("jax-batched", rank=30)
class JaxBatchedServing(_JaxServingBase):
    """Real JAX engine behind the continuous-batching scheduler: agent
    completions from concurrent runs multiplex onto one slot-batched
    decode via a blocking :class:`EngineClient`."""

    name = "jax-batched"
    # batched-ness lives on the CLASS, not the decorator: subclasses
    # registered as variants inherit truthful capability metadata
    default_capabilities = dataclasses.replace(
        _JaxServingBase.default_capabilities, batched=True, n_slots=4)
    # fair-share weight source handed to the scheduler (TenantRegistry /
    # dict / True for equal weights); None = the single global priority
    # queue.  Subclass-register a variant (or set the attribute before
    # the first completion builds the client) to serve tenants under
    # DRR admission.
    fair_share = None

    def __init__(self, capabilities: Optional[ServingCapabilities] = None):
        super().__init__(capabilities)
        self._client = None
        self._pending_subs: List[Callable] = []

    def client(self) -> "EngineClient":
        from .scheduler import BatchScheduler, EngineClient
        engine = self.engine()
        with self._lock:
            if self._client is None:
                caps = self.capabilities
                paged: dict = {}
                if caps.paged:
                    # the prefix-key chain is salted by the capability
                    # fingerprint: retuning the backend can never alias
                    # cached prefix blocks across engine configurations
                    paged = dict(paged_kv=True,
                                 block_size=caps.block_size or 32,
                                 prefix_salt=caps.fingerprint())
                sched = BatchScheduler(engine,
                                       n_slots=caps.n_slots or 4,
                                       max_len=caps.max_len,
                                       fair_share=self.fair_share,
                                       **paged)
                for fn in self._pending_subs:
                    sched.subscribe(fn)
                self._pending_subs.clear()
                self._client = EngineClient(sched)
            return self._client

    def subscribe(self, fn: Callable) -> None:
        with self._lock:
            if self._client is not None:
                self._client.scheduler.subscribe(fn)
            else:
                self._pending_subs.append(fn)

    def endpoint(self):
        return self.client()


@register_llm_backend("jax-batched-paged", rank=35, paged=True,
                      block_size=32)
class JaxPagedServing(JaxBatchedServing):
    """``jax-batched`` over the block-paged KV cache with prefix reuse:
    same scheduler, same bit-identical token streams (enforced by the
    property suite), but hot shared prefixes prefill once and admissions
    that match them skip straight to the divergent suffix.  The paged
    knobs join the capability fingerprint, so switching a deployment
    between this backend and ``jax-batched`` re-addresses its cached
    runs instead of mixing them."""

    name = "jax-batched-paged"
