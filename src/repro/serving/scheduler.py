"""Continuous-batching scheduler: one jitted decode advances ALL slots.

Slot-based, vLLM-style, TPU-friendly fixed shapes (no paged indirection,
which doesn't map well onto dense XLA buffers):

  * the decode cache carries an ``n_slots`` batch axis allocated once
    (``init_cache(cfg, n_slots, max_len)``);
  * admission prefills a request on its own (batch-1) and writes the
    padded prefill cache into the free slot's row (:func:`write_slot`);
  * every :meth:`BatchScheduler.step` runs ONE jitted ``decode_step``
    over the whole slot batch with a per-slot position *vector* — live
    slots advance together, finished slots free their row and the next
    queued request is admitted into it.

Sampling is keyed by (engine seed, request id, step) via
``Engine.sample``, so a request's token sequence is bit-identical to
serial ``Engine.generate_ids`` — greedy parity is enforced by test.

``EngineClient`` is the blocking handle that multiplexes many concurrent
agent runs onto one scheduler: callers block in ``generate`` while one of
them pumps ``step()`` — fan-out runs (``Session.execute_many`` workers)
therefore share the decode batch instead of serializing on the engine.

Observability: each step emits a serving-side
:class:`repro.core.events.EngineStepped` run event (occupancy, queue
depth, tokens decoded) to subscribers — ``RunMonitor`` consumes it live.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..core.events import EngineStepped
from ..models.model import init_cache
from .engine import Engine, GenerationResult, cache_leaf_name


@dataclasses.dataclass
class Request:
    rid: int
    prompt_ids: List[int]
    max_new: int
    out_ids: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    def to_result(self, tokenizer) -> GenerationResult:
        return GenerationResult(tokenizer.decode(self.out_ids),
                                len(self.prompt_ids), len(self.out_ids),
                                list(self.out_ids))


# cache leaves carry their slot (batch) axis at a name-dependent offset
# from the right: (*stack, B, C, Hkv, hd) for k/v, (*stack, B, nh, hd, ds)
# for ssd states, (*stack, B, C, r) for MLA, (*stack, B, W-1, ch) for conv.
_ROW_AXIS_OFFSET = {"k": 4, "v": 4, "ssd": 4, "ckv": 3, "kpe": 3, "conv": 3}


def write_slot(batched_cache, row_cache, slot):
    """Write a batch-1 cache (already padded to the batched cache's seq
    length, see ``pad_cache_to``) into row ``slot`` of the slot-batched
    decode cache. Works for every cache family (GQA/MLA/SSM/hybrid) via
    the leaf-name -> batch-axis table."""
    def ins(path, big, small):
        axis = big.ndim - _ROW_AXIS_OFFSET[cache_leaf_name(path)]
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis)
    return jax.tree_util.tree_map_with_path(ins, batched_cache, row_cache)


class BatchScheduler:
    """Drives an Engine's model with a fixed slot batch.

    ``submit()`` enqueues; ``step()`` admits queued requests into free
    slots (prefill + slot write) then advances all live slots by one
    batched decode; ``drain()`` steps to completion. ``run()`` is the
    historical drain-to-text entry point.

    ``requests`` keeps per-rid bookkeeping for inspection after a
    bounded submit/drain cycle; long-lived callers should go through
    :class:`EngineClient`, which prunes completed entries.
    """

    def __init__(self, engine: Engine, n_slots: int = 4,
                 max_len: int = 512,
                 on_event: Optional[Callable] = None):
        self.engine = engine
        self.cfg = engine.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self._offset = self.cfg.frontend_positions if self.cfg.frontend else 0
        self._cache_len = max_len + self._offset
        self.queue: Deque[Request] = deque()
        self._qlock = threading.Lock()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self._steps = 0
        self._pos = [0] * n_slots   # next decode position per slot
        self._tok = [0] * n_slots   # last sampled token per slot
        self._cache = init_cache(self.cfg, n_slots, self._cache_len,
                                 dtype=self.engine.params["embed"].dtype)
        # batched cache is donated through admission writes too: the slot
        # row update happens in place instead of copying all slots
        self._insert = jax.jit(write_slot, donate_argnums=(0,))
        self._subscribers: List[Callable] = []
        if on_event is not None:
            self._subscribers.append(on_event)

    # -- events -------------------------------------------------------------
    def subscribe(self, fn: Callable) -> None:
        self._subscribers.append(fn)

    def _emit(self, event) -> None:
        for fn in self._subscribers:
            fn(event)

    # -- admission ----------------------------------------------------------
    def submit(self, prompt: Optional[str] = None, max_new: int = 32,
               prompt_ids: Optional[List[int]] = None) -> int:
        """Enqueue one request; returns its rid. Thread-safe.

        The prompt is truncated to half the slot context and ``max_new``
        clamped so prompt+generation always fit the fixed cache."""
        ids = (list(prompt_ids) if prompt_ids is not None
               else self.engine.tokenizer.encode(prompt))
        ids = ids[-(self.max_len // 2):]
        max_new = max(1, min(max_new, self.max_len - len(ids)))
        with self._qlock:
            req = Request(self._next_rid, ids, max_new)
            self._next_rid += 1
            self.requests[req.rid] = req
            self.queue.append(req)
        return req.rid

    def _prefill_into(self, slot: int, req: Request) -> None:
        """Prefill one request (``Engine.prefill_ids`` — the same recipe
        the serial path uses) and, if it survives its first token, write
        the padded cache into the free slot's row."""
        logits, cache = self.engine.prefill_ids(req.prompt_ids, self.max_len)
        tok = int(self.engine.sample(logits, [req.rid], [0])[0])
        req.out_ids.append(tok)
        if tok == self.engine.tokenizer.eos or len(req.out_ids) >= req.max_new:
            req.done = True   # finished on the prefill token: skip the
            return            # whole-batch slot write, nothing reads it
        self._cache = self._insert(self._cache, cache, slot)
        self.slots[slot] = req
        self._pos[slot] = self._offset + len(req.prompt_ids)
        self._tok[slot] = tok

    def _admit(self, finished: List[Request]) -> None:
        for i in range(self.n_slots):
            while self.slots[i] is None:
                with self._qlock:
                    if not self.queue:
                        return
                    req = self.queue.popleft()
                self._prefill_into(i, req)
                if req.done:   # eos/budget hit on the prefill logits
                    finished.append(req)

    # -- the batched decode step --------------------------------------------
    def step(self) -> List[Request]:
        """Admit into free slots, then advance ALL live slots one token
        with a single jitted decode over the slot batch. Returns the
        requests that finished this step."""
        finished: List[Request] = []
        self._admit(finished)
        live = [i for i in range(self.n_slots) if self.slots[i] is not None]
        if live:
            tokens = jnp.asarray([[t] for t in self._tok], jnp.int32)
            pos = jnp.asarray(self._pos, jnp.int32)
            logits, self._cache = self.engine._decode(
                self.engine.params, cache=self._cache, token=tokens, pos=pos)
            rids = [r.rid if (r := self.slots[i]) is not None else 0
                    for i in range(self.n_slots)]
            steps = [len(r.out_ids) if (r := self.slots[i]) is not None else 0
                     for i in range(self.n_slots)]
            toks = [int(t) for t in self.engine.sample(logits, rids, steps)]
            eos = self.engine.tokenizer.eos
            for i in live:
                req = self.slots[i]
                req.out_ids.append(toks[i])
                self._pos[i] += 1
                self._tok[i] = toks[i]
                if toks[i] == eos or len(req.out_ids) >= req.max_new:
                    req.done = True
                    finished.append(req)
                    self.slots[i] = None   # slot freed -> next admission
        self._steps += 1
        with self._qlock:
            queued = len(self.queue)
        self._emit(EngineStepped(t=float(self._steps), live=len(live),
                                 queued=queued, generated=len(live)))
        return finished

    # -- draining -----------------------------------------------------------
    def has_work(self) -> bool:
        with self._qlock:
            queued = bool(self.queue)
        return queued or any(s is not None for s in self.slots)

    def occupancy(self) -> int:
        return sum(s is not None for s in self.slots)

    def drain(self) -> Dict[int, GenerationResult]:
        """Step to completion; returns {rid: GenerationResult}."""
        done: Dict[int, GenerationResult] = {}
        while self.has_work():
            for req in self.step():
                done[req.rid] = req.to_result(self.engine.tokenizer)
        return done

    def run(self) -> Dict[int, str]:
        """Historical entry point: drain and return {rid: text}."""
        return {rid: r.text for rid, r in self.drain().items()}


class EngineClient:
    """Blocking, thread-safe handle multiplexing concurrent callers onto
    one :class:`BatchScheduler`.

    ``generate`` submits and blocks until its request completes. While
    any request is in flight exactly one blocked caller "pumps" the
    scheduler (``step()``) with the lock released, so other threads keep
    submitting into the SAME decode batch — this is the pump mode that
    lets ``Session.execute_many`` fan-out share the engine. Duck-types
    ``Engine.generate``, so ``JaxLLMBackend`` can point at either.
    """

    def __init__(self, scheduler: BatchScheduler):
        self.scheduler = scheduler
        self._cv = threading.Condition()
        self._pumping = False
        self._results: Dict[int, GenerationResult] = {}

    def generate(self, prompt: str, max_new_tokens: int = 32
                 ) -> GenerationResult:
        with self._cv:
            rid = self.scheduler.submit(prompt, max_new=max_new_tokens)
            while rid not in self._results:
                if self._pumping:
                    # someone else is driving the engine; wake on step end
                    self._cv.wait(timeout=0.002)
                    continue
                self._pumping = True
                self._cv.release()
                try:
                    finished = self.scheduler.step()
                finally:
                    self._cv.acquire()
                    self._pumping = False
                tokenizer = self.scheduler.engine.tokenizer
                for req in finished:
                    self._results[req.rid] = req.to_result(tokenizer)
                    # the client is the long-lived path (backend
                    # singleton): drop completed bookkeeping so the
                    # scheduler doesn't grow without bound
                    self.scheduler.requests.pop(req.rid, None)
                self._cv.notify_all()
            return self._results.pop(rid)
