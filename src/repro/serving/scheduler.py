"""Continuous-batching scheduler.

Slot-based: a fixed decode batch of ``n_slots`` sequences; finished
sequences free their slot and the next queued request is prefilled into it
(vLLM-style continuous batching, TPU-friendly fixed shapes — no paged
indirection, which doesn't map well onto dense XLA buffers).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..data.tokenizer import HashTokenizer
from ..models.model import decode_step, init_cache, prefill
from .engine import Engine, pad_cache_to


@dataclasses.dataclass
class Request:
    rid: int
    prompt_ids: List[int]
    max_new: int
    out_ids: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Drives an Engine's model with a fixed slot batch."""

    def __init__(self, engine: Engine, n_slots: int = 4,
                 max_len: int = 512):
        self.engine = engine
        self.cfg = engine.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self._next_rid = 0

    def submit(self, prompt: str, max_new: int = 32) -> int:
        ids = self.engine.tokenizer.encode(prompt)[-(self.max_len // 2):]
        req = Request(self._next_rid, ids, max_new)
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                self.slots[i] = self.queue.popleft()

    def run(self) -> Dict[int, str]:
        """Run to completion (simple synchronous loop; per-slot decode)."""
        results: Dict[int, str] = {}
        self._admit()
        while any(s is not None for s in self.slots) or self.queue:
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                gen = self.engine.generate_ids(req.prompt_ids, req.max_new)
                req.out_ids = gen.token_ids
                req.done = True
                results[req.rid] = gen.text
                self.slots[i] = None
            self._admit()
        return results
