"""Continuous-batching scheduler v2: batched + chunked prefill with
priority preemption, with an optional block-paged KV mode.

Slot-based, vLLM-style, TPU-friendly fixed shapes:

  * the decode cache carries an ``n_slots`` batch axis allocated once
    (``init_cache(cfg, n_slots, max_len)``);
  * every :meth:`BatchScheduler.step` runs ONE jitted ``decode_step``
    over the whole slot batch with a per-slot position *vector* — live
    slots advance together, finished slots free their row and queued
    requests are admitted into it.

**Paged KV mode** (``paged_kv=True``): the dense ``n_slots x max_len``
cache is replaced by a refcounted block pool
(:mod:`repro.serving.paging` bookkeeping +
:func:`repro.models.model.init_paged_cache` arrays) with per-slot block
tables, and a content-hashed prefix cache on top — admissions whose
leading full blocks match a cached prefix pin the SHARED blocks and
prefill only the divergent suffix (``Engine.prefill_continue``).  The
decode step stays bit-identical to the contiguous path by construction:
the pool is gathered through the block tables into the exact dense view
the contiguous cache holds (``max_len % block_size == 0`` makes the
widths equal), that view runs through the SAME jitted ``decode_step``
executable, and the freshly written rows scatter back into the pool.
Junk rows gathered from recycled blocks sit beyond every sequence's
valid length, where the decode validity mask zeroes them exactly as it
zeroes the contiguous cache's stale rows.  Preemption frees the
victim's blocks; resume re-pins (prefix blocks re-shared, the rest
freshly allocated).  The contiguous path stays the default — parity is
testable request-for-request (``tests/test_properties.py``).

Admission (the v2 overhaul) no longer prefills one request per exact
prompt length:

  * **bucketed batched prefill** — waiting requests are padded to shared
    power-of-two length buckets (:func:`repro.serving.engine.prefill_bucket`)
    and a same-bucket group is prefilled into the freed slots with ONE
    jitted call per bucket (``Engine.prefill_batch_ids``), eliminating
    per-length recompiles from the admission path;
  * **chunked prefill** — a prompt longer than the engine's
    ``prefill_chunk`` budget is prefilled one fixed-shape chunk per
    scheduler step (:class:`repro.serving.engine.PrefillJob`) while live
    slots keep decoding, so a long prompt *bounds* rather than
    monopolizes the stall it imposes;
  * **priority classes + preemption** — ``submit(priority=...)`` feeds a
    priority queue (FIFO within a class); when a waiting request
    outranks the lowest-priority live slot and no slot is free, that
    slot is evicted and requeued *keeping its generated tokens*; on
    re-admission the engine replays them through the identical decode
    recipe (``Engine.replay_ids``), so a preempted request's token
    stream is bit-identical to an uninterrupted run.

Sampling is keyed by (engine seed, request id, step) via
``Engine.sample``, and all three admission paths share the engine's
canonical prefill recipe — a request's token sequence is bit-identical
to serial ``Engine.generate_ids`` whether it was admitted alone, inside
a bucket batch, in chunks, or after an eviction (enforced by test).

``EngineClient`` is the blocking handle that multiplexes many concurrent
agent runs onto one scheduler: callers block in ``generate`` while one of
them pumps ``step()`` — fan-out runs (``Session.execute_many`` workers)
therefore share the decode batch instead of serializing on the engine.

Observability: each step emits a serving-side
:class:`repro.core.events.EngineStepped` run event (occupancy, queue
depth, tokens decoded, prompt tokens prefilled, slots preempted) to
subscribers — ``RunMonitor`` consumes it live.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.events import EngineStepped
from ..models.model import (copy_block, gather_cache, init_cache,
                            init_paged_cache, scatter_cache,
                            scatter_decode_rows, supports_paged_cache)
from .engine import (Engine, GenerationResult, PrefillJob, cache_leaf_name,
                     prefill_bucket)
from .paging import BlockAllocator, PrefixCache


@dataclasses.dataclass
class Request:
    """One in-flight generation request.

    ``priority``: higher jumps the queue (FIFO within a class).
    ``seq``: the submission ticket — preserved across preemptions so a
    requeued request keeps its place among equal-priority peers.
    ``tenant``: the billing principal (multi-tenant serving) — under
    fair-share admission requests queue per tenant and slots are granted
    in deficit-round-robin order across tenants.
    ``t_submit`` / ``t_first_token``: wall-clock stamps (``time.perf_counter``)
    used by ``benchmarks/serving.py`` for admission-latency (TTFT)
    percentiles.
    """
    rid: int
    prompt_ids: List[int]
    max_new: int
    priority: int = 0
    tenant: str = ""
    out_ids: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    seq: int = 0
    preemptions: int = 0
    t_submit: float = 0.0
    t_first_token: float = 0.0

    def to_result(self, tokenizer) -> GenerationResult:
        return GenerationResult(tokenizer.decode(self.out_ids),
                                len(self.prompt_ids), len(self.out_ids),
                                list(self.out_ids))


# cache leaves carry their slot (batch) axis at a name-dependent offset
# from the right: (*stack, B, C, Hkv, hd) for k/v, (*stack, B, nh, hd, ds)
# for ssd states, (*stack, B, C, r) for MLA, (*stack, B, W-1, ch) for conv.
_ROW_AXIS_OFFSET = {"k": 4, "v": 4, "ssd": 4, "ckv": 3, "kpe": 3, "conv": 3}


def write_slot(batched_cache, row_cache, slot):
    """Write a batch-1 cache (already padded to the batched cache's seq
    length, see ``pad_cache_to``) into row ``slot`` of the slot-batched
    decode cache. Works for every cache family (GQA/MLA/SSM/hybrid) via
    the leaf-name -> batch-axis table."""
    def ins(path, big, small):
        axis = big.ndim - _ROW_AXIS_OFFSET[cache_leaf_name(path)]
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis)
    return jax.tree_util.tree_map_with_path(ins, batched_cache, row_cache)


def take_slot(batched_cache, slot):
    """Inverse of :func:`write_slot`: slice row ``slot`` out of a
    slot-batched cache as a batch-1 cache (used to move rows of a
    bucketed batch-prefill result into their target slots)."""
    def take(path, big):
        axis = big.ndim - _ROW_AXIS_OFFSET[cache_leaf_name(path)]
        return jax.lax.dynamic_slice_in_dim(big, slot, 1, axis)
    return jax.tree_util.tree_map_with_path(take, batched_cache)


class BatchScheduler:
    """Drives an Engine's model with a fixed slot batch.

    ``submit()`` enqueues (with a priority class); ``step()`` runs one
    scheduler cycle — preempt, admit, decode — and ``drain()`` steps to
    completion. ``run()`` is the historical drain-to-text entry point.

    One ``step()`` performs, in order:

    1. *preempt*: if the queue head outranks the lowest-priority live
       slot and no slot is free, that slot is evicted and requeued (at
       most one eviction per step — bounds thrash); equal priority never
       preempts;
    2. *admit*: advance the in-flight chunked admission by ONE chunk,
       then fill free slots in strict priority order — same-bucket
       groups via one batched prefill call, preempted requests via
       decode replay, long prompts by starting a chunk job;
    3. *decode*: ONE jitted ``decode_step`` over the whole slot batch
       advances every live slot by a token.

    ``batched_prefill=False`` restores the v1 admission (one
    exact-length prefill per request, a trace per prompt length) — kept
    as the benchmark baseline; the bit-identical-to-serial contract is
    guaranteed for the default ``True``.

    ``requests`` keeps per-rid bookkeeping for inspection after a
    bounded submit/drain cycle; long-lived callers should go through
    :class:`EngineClient`, which prunes completed entries.

    Invariants (tested):
      * a request's tokens are bit-identical to serial
        ``Engine.generate_ids(prompt_ids, max_new, rid, cache_len=max_len)``
        across bucketed, chunked and preempted admission;
      * a preempted request never loses generated tokens, and never
        resumes with different ones;
      * slots are preempted only by strictly higher priority.
    """

    def __init__(self, engine: Engine, n_slots: int = 4,
                 max_len: int = 512,
                 on_event: Optional[Callable] = None,
                 batched_prefill: bool = True,
                 fair_share=None,
                 paged_kv: bool = False,
                 block_size: int = 32,
                 n_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 prefix_salt: str = ""):
        self.engine = engine
        self.cfg = engine.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.batched_prefill = batched_prefill
        self._offset = self.cfg.frontend_positions if self.cfg.frontend else 0
        self._cache_len = max_len + self._offset
        # priority queue of (-priority, seq, Request): highest priority
        # first, FIFO (submission ticket) within a class
        self._heap: List[Tuple[int, int, Request]] = []
        # fair-share admission (multi-tenant serving): per-tenant heaps
        # drained in deficit-round-robin order — DRR picks WHICH tenant
        # admits next, priority classes still order WITHIN a tenant.
        # ``fair_share`` is the weight source (TenantRegistry / dict /
        # callable / True for equal weights); None keeps the single
        # global heap, bit-identical to the pre-tenancy scheduler.
        if fair_share is not None:
            from ..tenancy.fair_share import TenantQueue
            self._tq: Optional["TenantQueue"] = TenantQueue(
                None if fair_share is True else fair_share)
        else:
            self._tq = None
        self._qlock = threading.Lock()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self._reserved: set = set()   # slots held by an in-flight chunk job
        self._chunk_job: Optional[Tuple[PrefillJob, Request, int]] = None
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self._seq = 0
        self._steps = 0
        self._pos = [0] * n_slots   # next decode position per slot
        self._tok = [0] * n_slots   # last sampled token per slot
        dtype = self.engine.params["embed"].dtype
        self._paged = bool(paged_kv)
        if self._paged:
            if (not supports_paged_cache(self.cfg)
                    or not engine.supports_fixed_shape_prefill):
                raise NotImplementedError(
                    f"paged KV needs an attention cache with fixed-shape "
                    f"prefill; {self.cfg.name} keeps the contiguous path")
            if max_len % block_size != 0:
                # gathered view width (max_blocks * block_size) must equal
                # the contiguous cache width — that equality is what lets
                # both paths share one decode executable (bit parity)
                raise ValueError(
                    f"max_len ({max_len}) must be a multiple of "
                    f"block_size ({block_size})")
            self.block_size = int(block_size)
            self._mb = max_len // self.block_size   # max blocks / sequence
            self.n_blocks = (int(n_blocks) if n_blocks is not None
                             else n_slots * self._mb)
            if self.n_blocks < self._mb:
                raise ValueError(
                    "n_blocks must cover at least one full-length sequence")
            # physical pool carries one extra TRASH block (index n_blocks):
            # the scatter target for rows outside a sequence's allocated
            # blocks (prefill padding, shared-prefix redirects, dead slots)
            self._trash = self.n_blocks
            self._alloc = BlockAllocator(self.n_blocks, self.block_size)
            self._prefix = (PrefixCache(self._alloc,
                                        salt=f"{self.cfg.name}:{prefix_salt}")
                            if prefix_cache else None)
            self._pool = init_paged_cache(self.cfg, self.n_blocks + 1,
                                          self.block_size, dtype=dtype)
            self._blocks: List[List[int]] = [[] for _ in range(n_slots)]
            self._tables_dirty = True
            self._tables_dev = None
            self._cache = None
            self._gather = jax.jit(gather_cache)
            self._scatter_rows = jax.jit(scatter_decode_rows,
                                         donate_argnums=(0,))
            self._scatter_prefill = jax.jit(scatter_cache,
                                            donate_argnums=(0,))
            self._copy = jax.jit(copy_block, donate_argnums=(0,))
        else:
            self._prefix = None
            self._cache = init_cache(self.cfg, n_slots, self._cache_len,
                                     dtype=dtype)
        # batched cache is donated through admission writes too: the slot
        # row update happens in place instead of copying all slots
        self._insert = jax.jit(write_slot, donate_argnums=(0,))
        self._take = jax.jit(take_slot)
        self._subscribers: List[Callable] = []
        if on_event is not None:
            self._subscribers.append(on_event)

    # -- events -------------------------------------------------------------
    def subscribe(self, fn: Callable) -> None:
        self._subscribers.append(fn)

    def _emit(self, event) -> None:
        for fn in self._subscribers:
            fn(event)

    # -- admission ----------------------------------------------------------
    def submit(self, prompt: Optional[str] = None, max_new: int = 32,
               prompt_ids: Optional[List[int]] = None,
               priority: int = 0, tenant: str = "") -> int:
        """Enqueue one request; returns its rid. Thread-safe.

        ``priority``: higher-priority requests are admitted first and may
        preempt lower-priority live slots; within a class admission is
        FIFO. ``tenant``: under fair-share admission the request queues
        with its tenant's peers and waits its tenant's DRR turn.
        ``max_new`` is clamped to the slot context minus one, then the
        prompt keeps its last ``max_len - max_new`` ids — the requested
        decode budget is always honored and prompt+generation always fit
        the fixed cache.  Prompts that fit are admitted verbatim even
        when their prefill bucket equals ``max_len``: the fixed-shape
        prefill recipe masks the padded cache rows (``col <= q_pos``)
        and decode overwrites them before they become visible, so
        ``bucket == cache_len`` is exact — the historical half-context
        clamp (which silently dropped prompt heads and desynced the
        serial cross-check) is gone."""
        ids = (list(prompt_ids) if prompt_ids is not None
               else self.engine.tokenizer.encode(prompt))
        max_new = max(1, min(max_new, self.max_len - 1))
        ids = ids[-(self.max_len - max_new):]
        with self._qlock:
            req = Request(self._next_rid, ids, max_new, priority=priority,
                          tenant=tenant, seq=self._seq,
                          t_submit=time.perf_counter())
            self._next_rid += 1
            self._seq += 1
            self.requests[req.rid] = req
            if self._tq is not None:
                self._tq.push(req.tenant, (-req.priority, req.seq), req)
            else:
                heapq.heappush(self._heap, (-req.priority, req.seq, req))
        return req.rid

    def queue_depth(self) -> int:
        with self._qlock:
            return (len(self._tq) if self._tq is not None
                    else len(self._heap))

    def _peek(self) -> Optional[Request]:
        with self._qlock:
            if self._tq is not None:
                return self._tq.peek()
            return self._heap[0][2] if self._heap else None

    def _pop(self) -> Optional[Request]:
        with self._qlock:
            if self._tq is not None:
                popped = self._tq.pop()
                return popped[1] if popped is not None else None
            return heapq.heappop(self._heap)[2] if self._heap else None

    def _push(self, req: Request) -> None:
        with self._qlock:
            if self._tq is not None:
                self._tq.push(req.tenant, (-req.priority, req.seq), req)
            else:
                heapq.heappush(self._heap, (-req.priority, req.seq, req))

    def _needs_chunk(self, req: Request) -> bool:
        return bool(self.engine.prefill_chunk
                    and len(req.prompt_ids) > self.engine.prefill_chunk
                    and self.engine.supports_fixed_shape_prefill)

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.n_slots)
                if self.slots[i] is None and i not in self._reserved]

    def _first_token(self, req: Request, tok: int,
                     finished: List[Request]) -> bool:
        """Record a request's prefill-sampled first token; returns True
        when the request stays live (False: finished on the prefill
        token — the slot write is skipped, nothing would read it)."""
        req.out_ids.append(tok)
        req.t_first_token = time.perf_counter()
        if tok == self.engine.tokenizer.eos or len(req.out_ids) >= req.max_new:
            req.done = True
            finished.append(req)
            return False
        return True

    def _occupy(self, slot: int, req: Request, pos: int, tok: int) -> None:
        self.slots[slot] = req
        self._pos[slot] = pos
        self._tok[slot] = tok

    # -- paged-KV bookkeeping ------------------------------------------------
    def _table_row(self, slot: int) -> jax.Array:
        """One slot's device block table, trash-padded to max blocks."""
        blocks = self._blocks[slot]
        return jnp.asarray(blocks + [self._trash] * (self._mb - len(blocks)),
                           jnp.int32)

    def _tables_device(self) -> jax.Array:
        """The (n_slots, max_blocks) int32 block-table array, rebuilt
        lazily after any host-side table change."""
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(
                [b + [self._trash] * (self._mb - len(b))
                 for b in self._blocks], jnp.int32)
            self._tables_dirty = False
        return self._tables_dev

    def _alloc_block(self) -> Optional[int]:
        """One fresh block, evicting LRU prefix-cache entries on demand;
        ``None`` only once the pool is exhausted AND the prefix cache is
        empty (live sequences hold everything)."""
        while True:
            bid = self._alloc.alloc()
            if bid is not None:
                return bid
            if self._prefix is not None and len(self._prefix):
                self._prefix.evict()
                continue
            return None

    def _paged_admit_blocks(self, ids: List[int], n_rows: int,
                            stats: Dict[str, int]
                            ) -> Optional[Tuple[int, List[int]]]:
        """Pin the longest cached prefix of ``ids`` and allocate fresh
        blocks to cover ``n_rows`` rows.  Returns ``(start, blocks)``
        with ``start`` the reused-prefix row count, or ``None`` (all
        acquisitions rolled back) when the pool is exhausted."""
        start, shared = 0, []
        if self._prefix is not None:
            start, shared = self._prefix.match(ids)
            for bid in shared:
                self._alloc.incref(bid)     # pin before anything can evict
        blocks = list(shared)
        need = -(-n_rows // self.block_size)
        while len(blocks) < need:
            bid = self._alloc_block()
            if bid is None:
                for b in blocks:
                    self._alloc.decref(b)
                return None
            blocks.append(bid)
        if start:
            stats["prefix_hits"] += 1
        return start, blocks

    def _free_slot_blocks(self, slot: int) -> None:
        """Drop one slot's block references (finish / preemption) — the
        allocator reclaims blocks nobody else shares."""
        for bid in self._blocks[slot]:
            self._alloc.decref(bid)
        self._blocks[slot] = []
        self._tables_dirty = True

    def _ensure_block(self, slot: int) -> bool:
        """Make the block holding this slot's next write position exist
        and be exclusively owned.  The fork branch is defensive
        copy-on-write: admission never leaves a shared block at the
        write position (cached prefix blocks are always *full*, and the
        next write lands past them), but if a layout change ever does,
        the shared block is copied rather than corrupted.  False = pool
        exhausted (the caller self-preempts the slot)."""
        bi = self._pos[slot] // self.block_size
        blocks = self._blocks[slot]
        while bi >= len(blocks):
            bid = self._alloc_block()
            if bid is None:
                return False
            blocks.append(bid)
            self._tables_dirty = True
        if self._alloc.ref(blocks[bi]) > 1:
            got = self._alloc.fork(blocks[bi])
            while got is None:
                if self._prefix is None or not len(self._prefix):
                    return False
                self._prefix.evict()
                got = self._alloc.fork(blocks[bi])
            new, needs_copy = got
            if needs_copy:
                self._pool = self._copy(self._pool, jnp.int32(blocks[bi]),
                                        jnp.int32(new))
                blocks[bi] = new
                self._tables_dirty = True
        return True

    def paging_stats(self) -> Dict[str, int]:
        """Allocator + prefix-cache counters (benchmarks/tests); empty
        for the contiguous path."""
        if not self._paged:
            return {}
        s = {"blocks_in_use": self._alloc.in_use,
             "blocks_free": self._alloc.free_count,
             "n_blocks": self.n_blocks, "block_size": self.block_size}
        if self._prefix is not None:
            s.update(self._prefix.stats())
        return s

    def _prefill_into(self, slot: int, req: Request,
                      finished: List[Request], stats: Dict[str, int]) -> bool:
        """Admit one request on its own: the engine's canonical prefill
        (bucketed where supported), or the v1 exact-length recipe when
        ``batched_prefill=False``.  False = paged pool exhausted (the
        request was requeued; stop admitting this step)."""
        if self._paged:
            return self._paged_prefill_into(slot, req, finished, stats)
        prefill = (self.engine.prefill_ids if self.batched_prefill
                   else self.engine.prefill_ids_exact)
        logits, cache = prefill(req.prompt_ids, self.max_len)
        stats["prefilled"] += len(req.prompt_ids)
        tok = int(self.engine.sample(logits, [req.rid], [0])[0])
        if self._first_token(req, tok, finished):
            self._cache = self._insert(self._cache, cache, slot)
            self._occupy(slot, req, self._offset + len(req.prompt_ids), tok)
        return True

    def _paged_prefill_into(self, slot: int, req: Request,
                            finished: List[Request],
                            stats: Dict[str, int]) -> bool:
        """Paged admission of one request: pin/allocate its blocks, skip
        the cached prefix (suffix-only prefill on a hit), scatter the
        prefilled rows into the pool, and index the prompt's full blocks
        in the prefix cache for the next same-prefix admission."""
        got = self._paged_admit_blocks(req.prompt_ids, len(req.prompt_ids),
                                       stats)
        if got is None:
            self._push(req)
            return False
        start, blocks = got
        self._blocks[slot] = blocks
        self._tables_dirty = True
        if start:
            # shared blocks already hold rows 0..start-1: gather this
            # slot's view and prefill only the divergent suffix
            view = self._gather(self._pool, self._table_row(slot)[None])
            logits, cache = self.engine.prefill_continue(
                req.prompt_ids, start, view)
            stats["prefilled"] += len(req.prompt_ids) - start
        else:
            logits, cache = self.engine.prefill_ids(req.prompt_ids,
                                                    self.max_len)
            stats["prefilled"] += len(req.prompt_ids)
        self._pool = self._scatter_prefill(self._pool, cache,
                                           self._table_row(slot),
                                           jnp.int32(start))
        if self._prefix is not None:
            self._prefix.insert(req.prompt_ids, blocks)
        tok = int(self.engine.sample(logits, [req.rid], [0])[0])
        if self._first_token(req, tok, finished):
            self._occupy(slot, req, self._offset + len(req.prompt_ids), tok)
        else:
            self._free_slot_blocks(slot)
        return True

    def _admit_bucket(self, group: List[Request], free: List[int],
                      finished: List[Request], stats: Dict[str, int]) -> bool:
        """Admit a same-bucket group with ONE jitted batched prefill
        (batch padded to ``n_slots`` rows so every group size shares the
        same trace).  In paged mode (prefix cache off — hit-aware
        admission goes per-request through ``_paged_prefill_into``) each
        row scatters into its slot's freshly allocated blocks.  False =
        the paged pool ran out mid-group (unplaced members requeued)."""
        logits, cache = self.engine.prefill_batch_ids(
            [r.prompt_ids for r in group], self.max_len, width=self.n_slots)
        slot_iter = iter(free)
        exhausted = False
        for j, req in enumerate(group):
            if exhausted:
                self._push(req)
                continue
            blocks: List[int] = []
            if self._paged:
                got = self._paged_admit_blocks(req.prompt_ids,
                                               len(req.prompt_ids), stats)
                if got is None:
                    exhausted = True
                    self._push(req)
                    continue
                _, blocks = got
            stats["prefilled"] += len(req.prompt_ids)
            tok = int(self.engine.sample(logits[j:j + 1], [req.rid], [0])[0])
            if self._first_token(req, tok, finished):
                slot = next(slot_iter)
                row = self._take(cache, j)
                if self._paged:
                    self._blocks[slot] = blocks
                    self._tables_dirty = True
                    self._pool = self._scatter_prefill(
                        self._pool, row, self._table_row(slot), jnp.int32(0))
                else:
                    self._cache = self._insert(self._cache, row, slot)
                self._occupy(req=req, slot=slot, tok=tok,
                             pos=self._offset + len(req.prompt_ids))
            elif self._paged:
                for bid in blocks:
                    self._alloc.decref(bid)
        return not exhausted

    def _resume_into(self, slot: int, req: Request,
                     stats: Dict[str, int]) -> bool:
        """Re-admit a preempted request: canonical prefill of the prompt
        plus decode replay of its kept tokens (``Engine.replay_ids``) —
        the state rebuild is bit-identical, generated tokens are never
        resampled.  In paged mode the replayed rows scatter into
        re-pinned blocks (shared prefix blocks are reused, not
        rewritten).  False = pool exhausted (request requeued)."""
        if self._paged:
            n_rows = len(req.prompt_ids) + len(req.out_ids) - 1
            got = self._paged_admit_blocks(req.prompt_ids, n_rows, stats)
            if got is None:
                self._push(req)
                return False
            start, blocks = got
            self._blocks[slot] = blocks
            self._tables_dirty = True
            cache, pos, tok = self.engine.replay_ids(
                req.prompt_ids, req.out_ids, self.max_len)
            stats["prefilled"] += len(req.prompt_ids) + len(req.out_ids) - 1
            self._pool = self._scatter_prefill(self._pool, cache,
                                               self._table_row(slot),
                                               jnp.int32(start))
            if self._prefix is not None:
                self._prefix.insert(req.prompt_ids, blocks)
            self._occupy(slot, req, pos, tok)
            return True
        cache, pos, tok = self.engine.replay_ids(
            req.prompt_ids, req.out_ids, self.max_len)
        stats["prefilled"] += len(req.prompt_ids) + len(req.out_ids) - 1
        self._cache = self._insert(self._cache, cache, slot)
        self._occupy(slot, req, pos, tok)
        return True

    def _admit(self, finished: List[Request], stats: Dict[str, int]) -> None:
        """Fill free slots from the priority queue (strict priority
        order), advancing the in-flight chunked admission by one chunk
        first."""
        if self._chunk_job is not None:
            job, req, slot = self._chunk_job
            stats["prefilled"] += job.step()
            if job.done:
                self._chunk_job = None
                self._reserved.discard(slot)
                tok = int(self.engine.sample(job.logits, [req.rid], [0])[0])
                if self._paged:
                    # scatter skips the job's reused-prefix rows (they
                    # live in shared blocks the job never rewrote)
                    self._pool = self._scatter_prefill(
                        self._pool, job.cache, self._table_row(slot),
                        jnp.int32(job.start))
                    if self._prefix is not None:
                        self._prefix.insert(req.prompt_ids,
                                            self._blocks[slot])
                    if self._first_token(req, tok, finished):
                        self._occupy(slot, req,
                                     self._offset + len(req.prompt_ids), tok)
                    else:
                        self._free_slot_blocks(slot)
                elif self._first_token(req, tok, finished):
                    self._cache = self._insert(self._cache, job.cache, slot)
                    self._occupy(slot, req,
                                 self._offset + len(req.prompt_ids), tok)
        while True:
            free = self._free_slots()
            if not free:
                return
            req = self._pop()
            if req is None:
                return
            if req.out_ids:                     # preempted: replay resume
                if not self._resume_into(free[0], req, stats):
                    return                      # pool exhausted this step
                continue
            if self._needs_chunk(req):
                if self._chunk_job is not None:
                    # strict priority order: wait for the running chunk
                    # admission rather than admitting around the head
                    self._push(req)
                    return
                slot = free[0]
                if self._paged:
                    got = self._paged_admit_blocks(
                        req.prompt_ids, len(req.prompt_ids), stats)
                    if got is None:
                        self._push(req)
                        return
                    start, blocks = got
                    self._blocks[slot] = blocks
                    self._tables_dirty = True
                    if start:
                        # hot prefix: the chunk job starts at the first
                        # divergent row against the gathered slot view
                        view = self._gather(self._pool,
                                            self._table_row(slot)[None])
                        job = PrefillJob(self.engine, req.prompt_ids,
                                         self.max_len, cache=view,
                                         start=start)
                    else:
                        job = self.engine.prefill_job(req.prompt_ids,
                                                      self.max_len)
                else:
                    job = self.engine.prefill_job(req.prompt_ids,
                                                  self.max_len)
                self._reserved.add(slot)
                stats["prefilled"] += job.step()   # first chunk this step
                self._chunk_job = (job, req, slot)
                continue
            if (self.batched_prefill
                    and self.engine.supports_fixed_shape_prefill
                    and not (self._paged and self._prefix is not None)):
                group = [req]
                bucket = prefill_bucket(len(req.prompt_ids))
                while len(group) < len(free):
                    nxt = self._pop_matching(bucket, req)
                    if nxt is None:
                        break
                    group.append(nxt)
                if not self._admit_bucket(group, free, finished, stats):
                    return
            else:
                if not self._prefill_into(free[0], req, finished, stats):
                    return

    def _pop_matching(self, bucket: int,
                      leader: Optional[Request] = None) -> Optional[Request]:
        """Pop the queue head iff it is a plain same-bucket admission
        (no resume, no chunking) — grows a bucket group without
        reordering across priorities.  Under fair-share admission the
        group additionally stays within the ``leader``'s tenant, and
        each extra member spends one more of that tenant's DRR turns —
        a batched prefill never becomes a cross-tenant queue jump."""
        def plain(r: Request) -> bool:
            return (not r.out_ids and not self._needs_chunk(r)
                    and prefill_bucket(len(r.prompt_ids)) == bucket)

        with self._qlock:
            if self._tq is not None:
                if leader is None:
                    return None
                return self._tq.pop_same_tenant(leader.tenant, plain)
            if not self._heap:
                return None
            req = self._heap[0][2]
            if not plain(req):
                return None
            return heapq.heappop(self._heap)[2]

    # -- preemption ---------------------------------------------------------
    def _preempt(self, stats: Dict[str, int]) -> None:
        """Evict the lowest-priority live slot when the queue head
        strictly outranks it and no slot is free (at most one eviction
        per step; equal priority never preempts — no thrash). The victim
        keeps its generated tokens and requeues with its original
        submission ticket."""
        head = self._peek()
        if head is None or self._free_slots():
            return
        if self._needs_chunk(head) and self._chunk_job is not None:
            return   # head cannot be admitted yet; don't waste a slot
        live = [(self.slots[i].priority, -self.slots[i].rid, i)
                for i in range(self.n_slots) if self.slots[i] is not None]
        if not live:
            return
        pri, _, victim = min(live)   # lowest priority; tie: youngest rid
        if head.priority <= pri:
            return
        req = self.slots[victim]
        self.slots[victim] = None
        if self._paged:
            self._free_slot_blocks(victim)
        req.preemptions += 1
        stats["preempted"] += 1
        self._push(req)

    # -- the batched decode step --------------------------------------------
    def step(self) -> List[Request]:
        """One scheduler cycle: preempt if a waiting request outranks a
        live slot, admit into free slots (chunked / bucketed / resume),
        then advance ALL live slots one token with a single jitted decode
        over the slot batch. Returns the requests that finished this
        step."""
        finished: List[Request] = []
        stats = {"prefilled": 0, "preempted": 0, "prefix_hits": 0}
        self._preempt(stats)
        self._admit(finished, stats)
        live = [i for i in range(self.n_slots) if self.slots[i] is not None]
        if self._paged:
            # grow each live slot's table to cover its write position;
            # a slot that cannot get a block self-preempts (resume later
            # replays it bit-identically, so nothing is lost)
            for i in list(live):
                if not self._ensure_block(i):
                    req = self.slots[i]
                    self.slots[i] = None
                    self._free_slot_blocks(i)
                    req.preemptions += 1
                    stats["preempted"] += 1
                    self._push(req)
                    live.remove(i)
        if live:
            tokens = jnp.asarray([[t] for t in self._tok], jnp.int32)
            pos = jnp.asarray(self._pos, jnp.int32)
            if self._paged:
                # gather pool -> dense view, decode with the SAME jitted
                # executable as the contiguous path (bit parity), scatter
                # the freshly written rows back into the pool.  Only LIVE
                # slots write back: a dead or chunk-reserved slot decodes
                # junk at a stale position (exactly like the contiguous
                # path), and its table may already hold SHARED prefix
                # blocks — its row is redirected to the trash block.
                tables = self._tables_device()
                live_rows = jnp.asarray(
                    [self.slots[i] is not None for i in range(self.n_slots)])
                wtables = jnp.where(live_rows[:, None], tables, self._trash)
                view = self._gather(self._pool, tables)
                logits, view = self.engine._decode(
                    self.engine.params, cache=view, token=tokens, pos=pos)
                self._pool = self._scatter_rows(self._pool, view, wtables,
                                                pos)
            else:
                logits, self._cache = self.engine._decode(
                    self.engine.params, cache=self._cache, token=tokens,
                    pos=pos)
            rids = [r.rid if (r := self.slots[i]) is not None else 0
                    for i in range(self.n_slots)]
            steps = [len(r.out_ids) if (r := self.slots[i]) is not None else 0
                     for i in range(self.n_slots)]
            toks = [int(t) for t in self.engine.sample(logits, rids, steps)]
            eos = self.engine.tokenizer.eos
            for i in live:
                req = self.slots[i]
                req.out_ids.append(toks[i])
                self._pos[i] += 1
                self._tok[i] = toks[i]
                if toks[i] == eos or len(req.out_ids) >= req.max_new:
                    req.done = True
                    finished.append(req)
                    self.slots[i] = None   # slot freed -> next admission
                    if self._paged:
                        self._free_slot_blocks(i)
        self._steps += 1
        self._emit(EngineStepped(t=float(self._steps), live=len(live),
                                 queued=self.queue_depth(),
                                 generated=len(live),
                                 prefilled=stats["prefilled"],
                                 preempted=stats["preempted"],
                                 blocks_in_use=(self._alloc.in_use
                                                if self._paged else 0),
                                 prefix_hits=stats["prefix_hits"]))
        return finished

    # -- draining -----------------------------------------------------------
    def has_work(self) -> bool:
        if self.queue_depth() or self._chunk_job is not None:
            return True
        return any(s is not None for s in self.slots)

    def occupancy(self) -> int:
        return sum(s is not None for s in self.slots)

    def drain(self) -> Dict[int, GenerationResult]:
        """Step to completion; returns {rid: GenerationResult}."""
        done: Dict[int, GenerationResult] = {}
        while self.has_work():
            for req in self.step():
                done[req.rid] = req.to_result(self.engine.tokenizer)
        return done

    def run(self) -> Dict[int, str]:
        """Historical entry point: drain and return {rid: text}."""
        return {rid: r.text for rid, r in self.drain().items()}


class EngineClient:
    """Blocking, thread-safe handle multiplexing concurrent callers onto
    one :class:`BatchScheduler`.

    ``generate`` submits and blocks until its request completes. While
    any request is in flight exactly one blocked caller "pumps" the
    scheduler (``step()``) with the lock released, so other threads keep
    submitting into the SAME decode batch — this is the pump mode that
    lets ``Session.execute_many`` fan-out share the engine. Duck-types
    ``Engine.generate``, so ``JaxLLMBackend`` can point at either.

    ``priority`` flows through to ``BatchScheduler.submit``:
    latency-sensitive agent runs (``RunSpec.priority``) jump the
    admission queue and may preempt lower-priority slots.
    """

    def __init__(self, scheduler: BatchScheduler):
        self.scheduler = scheduler
        self._cv = threading.Condition()
        self._pumping = False
        self._results: Dict[int, GenerationResult] = {}

    def generate(self, prompt: str, max_new_tokens: int = 32,
                 priority: int = 0, tenant: str = "") -> GenerationResult:
        with self._cv:
            rid = self.scheduler.submit(prompt, max_new=max_new_tokens,
                                        priority=priority, tenant=tenant)
            while rid not in self._results:
                if self._pumping:
                    # someone else is driving the engine; wake on step end
                    self._cv.wait(timeout=0.002)
                    continue
                self._pumping = True
                self._cv.release()
                try:
                    finished = self.scheduler.step()
                finally:
                    self._cv.acquire()
                    self._pumping = False
                self._collect(finished)
            return self._results.pop(rid)

    def _collect(self, finished: List[Request]) -> None:
        """Bank finished requests and drop the scheduler's completed
        bookkeeping — the client is the long-lived path (backend
        singleton), so the scheduler must not grow without bound.
        Caller holds ``_cv``."""
        tokenizer = self.scheduler.engine.tokenizer
        for req in finished:
            self._results[req.rid] = req.to_result(tokenizer)
            self.scheduler.requests.pop(req.rid, None)
        self._cv.notify_all()

    async def generate_async(self, prompt: str, max_new_tokens: int = 32,
                             priority: int = 0,
                             tenant: str = "") -> GenerationResult:
        """Asyncio-friendly pump: like :meth:`generate`, but awaitable —
        many coroutines on ONE event loop multiplex onto the shared
        decode batch with no thread per request.

        While its request is in flight, exactly one waiter pumps
        ``scheduler.step()`` on the loop's default executor (the step is
        a blocking jitted call — running it off-loop keeps other
        coroutines submitting into the same batch); the rest yield.
        Thread-safe alongside blocking ``generate`` callers: both paths
        share the ``_pumping`` baton and the results table."""
        import asyncio
        loop = asyncio.get_running_loop()
        with self._cv:
            rid = self.scheduler.submit(prompt, max_new=max_new_tokens,
                                        priority=priority, tenant=tenant)
        while True:
            with self._cv:
                if rid in self._results:
                    return self._results.pop(rid)
                pump = not self._pumping
                if pump:
                    self._pumping = True
            if pump:
                try:
                    finished = await loop.run_in_executor(
                        None, self.scheduler.step)
                finally:
                    with self._cv:
                        self._pumping = False
                with self._cv:
                    self._collect(finished)
            else:
                # another caller (thread or coroutine) drives the
                # engine; yield the loop until the next step lands
                await asyncio.sleep(0.001)
