from .engine import Engine, GenerationResult, pad_cache_to
from .scheduler import BatchScheduler
