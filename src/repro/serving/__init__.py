from .engine import Engine, GenerationResult, RunMonitor, pad_cache_to
from .scheduler import BatchScheduler
