"""Serving package — lazy exports (PEP 562).

``Session`` resolves ``RunSpec.llm`` through :mod:`repro.serving.api`
on every run, including oracle-only paper sweeps that never touch a
real model; importing this package therefore must not pull the JAX
stack. Engine/scheduler symbols load on first attribute access.
"""
import importlib

_EXPORTS = {
    "Engine": "engine", "GenerationResult": "engine",
    "RunMonitor": "engine", "pad_cache_to": "engine",
    "PrefillJob": "engine", "prefill_bucket": "engine",
    "BatchScheduler": "scheduler", "EngineClient": "scheduler",
    "Request": "scheduler", "write_slot": "scheduler",
    "take_slot": "scheduler",
    "BlockAllocator": "paging", "PrefixCache": "paging",
    "PagingError": "paging", "prefix_block_keys": "paging",
    "ServingBackend": "api", "ServingCapabilities": "api",
    "get_llm_backend": "api", "llm_backend_names": "api",
    "register_llm_backend": "api", "reset_llm_backends": "api",
    "resolve_llm_backend": "api",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    return getattr(importlib.import_module(f".{module}", __name__), name)


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
