"""MCP client + transports.

``McpClient`` is what agent frameworks hold; a ``Transport`` hides whether
the server runs in-process (local deployment, Fig. 2a), behind a FaaS
Function URL (Fig. 2b/2c), or behind an A2A remote agent (``A2ATransport``,
the ``a2a`` deployment backend).

Remote transports also carry the run-event side channel: when a response
envelope includes wire-serialized :class:`repro.core.events.RunEvent`
dicts, the transport replays them into its ``on_event`` observer, so a
local ``RunMonitor`` sees a remotely executed run live.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Optional

from ..env.world import World
from .protocol import (METHOD_CALL_TOOL, METHOD_DELETE, METHOD_INITIALIZE,
                       METHOD_LIST_TOOLS, McpRequest, McpResponse,
                       RequestIdGenerator, ToolSpec)
from .server import MCPServer, ToolContext


def _replay_events(wire_events, on_event: Optional[Callable]) -> None:
    """Deserialize wire-streamed run events and feed them to an observer."""
    if not wire_events or on_event is None:
        return
    # deferred import: core.runtime imports this module at package init
    from ..core.events import from_wire
    for d in wire_events:
        on_event(from_wire(d))


class Transport:
    def send(self, req: McpRequest) -> McpResponse:
        raise NotImplementedError


class LocalTransport(Transport):
    """In-process server on the agent workstation (paper Fig. 2a)."""

    def __init__(self, server: MCPServer, world: World, workspace, s3=None):
        self.server = server
        self.world = world
        self.workspace = workspace
        self.s3 = s3

    def send(self, req: McpRequest) -> McpResponse:
        ctx = ToolContext(world=self.world, workspace=self.workspace,
                          s3=self.s3, faas=False)
        return self.server.handle(req, ctx)


class FaaSTransport(Transport):
    """HTTPS Function-URL transport (paper §4.2).

    ``on_event`` (optional) receives deserialized ``RunEvent``s whenever a
    response envelope wire-streams them (remote orchestrator functions,
    see ``repro.faas.deployments.deploy_run_service``).
    """

    def __init__(self, platform, url: str, server_name: Optional[str] = None,
                 on_event: Optional[Callable] = None):
        self.platform = platform
        self.url = url
        self.server_name = server_name   # set for monolithic deployments
        self.on_event = on_event

    def send(self, req: McpRequest) -> McpResponse:
        if self.server_name is not None:
            req = McpRequest(method=req.method,
                             params=dict(req.params, server=self.server_name),
                             id=req.id, session_id=req.session_id)
        raw = self.platform.invoke_url(self.url, req.to_json())
        resp = McpResponse.from_json(raw)
        _replay_events(resp.events, self.on_event)
        return resp


class A2ATransport(Transport):
    """MCP-over-A2A transport (the ``a2a`` deployment): each JSON-RPC
    request is delegated as an A2A task to a remote agent hosting the MCP
    server; the response envelope rides back in the task artifact.

    Failed tasks with no artifact (unknown skill, agent crash) surface as
    JSON-RPC errors, so agents see the same ``<tool-error ...>`` shape as
    on every other deployment.
    """

    def __init__(self, a2a_client, agent_name: str, skill_id: str,
                 on_event: Optional[Callable] = None):
        self.a2a_client = a2a_client
        self.agent_name = agent_name
        self.skill_id = skill_id
        self.on_event = on_event

    def send(self, req: McpRequest) -> McpResponse:
        task = self.a2a_client.delegate(self.agent_name, self.skill_id,
                                        req.to_json())
        _replay_events(task.events, self.on_event)
        if not task.artifacts:
            detail = task.history[-1]["text"] if task.history else task.status
            return McpResponse(req.id, error={"code": -32000,
                                              "message": f"A2A task "
                                                         f"{task.status}: "
                                                         f"{detail}"})
        return McpResponse.from_json(task.artifacts[0]["text"])


@dataclasses.dataclass
class ToolHandle:
    """A tool as exposed to an agent: spec + the client that can call it."""
    spec: ToolSpec
    client: "McpClient"

    @property
    def name(self) -> str:
        return self.spec.name

    def describe(self) -> str:
        return self.spec.describe()

    def call(self, **args) -> str:
        return self.client.call_tool(self.spec.name, args)


class McpClient:
    def __init__(self, transport: Transport, server_name: str):
        self.transport = transport
        self.server_name = server_name
        self.session_id: Optional[str] = None
        self.call_log: List[Dict[str, Any]] = []
        # per-client JSON-RPC ids: concurrent runs never interleave wire ids
        self._ids = RequestIdGenerator()

    def initialize(self) -> str:
        resp = self.transport.send(McpRequest(METHOD_INITIALIZE, {},
                                              id=self._ids.next()))
        if not resp.ok:
            raise RuntimeError(f"initialize failed: {resp.error}")
        self.session_id = resp.session_id
        return self.session_id or ""

    def list_tools(self) -> List[ToolHandle]:
        resp = self.transport.send(McpRequest(METHOD_LIST_TOOLS, {},
                                              id=self._ids.next(),
                                              session_id=self.session_id))
        if not resp.ok:
            raise RuntimeError(f"tools/list failed: {resp.error}")
        out = []
        for t in resp.result["tools"]:
            spec = ToolSpec(t["name"], t["description"], t["inputSchema"])
            out.append(ToolHandle(spec, self))
        return out

    def call_tool(self, name: str, args: Dict[str, Any]) -> str:
        req = McpRequest(METHOD_CALL_TOOL,
                         {"name": name, "arguments": args},
                         id=self._ids.next(), session_id=self.session_id)
        resp = self.transport.send(req)
        self.call_log.append({"tool": name, "args": args, "ok": resp.ok})
        if not resp.ok:
            return f"<tool-error server={self.server_name} tool={name}: " \
                   f"{resp.error.get('message')}>"
        content = resp.result.get("content", [])
        return "".join(c.get("text", "") for c in content)

    def close(self):
        if self.session_id:
            self.transport.send(McpRequest(METHOD_DELETE, {},
                                           id=self._ids.next(),
                                           session_id=self.session_id))
            self.session_id = None
