"""MCP client + transports.

``McpClient`` is what agent frameworks hold; a ``Transport`` hides whether
the server runs in-process (local deployment, Fig. 2a) or behind a FaaS
Function URL (Fig. 2b/2c).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from ..env.world import World
from .protocol import (METHOD_CALL_TOOL, METHOD_DELETE, METHOD_INITIALIZE,
                       METHOD_LIST_TOOLS, McpRequest, McpResponse,
                       RequestIdGenerator, ToolSpec)
from .server import MCPServer, ToolContext


class Transport:
    def send(self, req: McpRequest) -> McpResponse:
        raise NotImplementedError


class LocalTransport(Transport):
    """In-process server on the agent workstation (paper Fig. 2a)."""

    def __init__(self, server: MCPServer, world: World, workspace, s3=None):
        self.server = server
        self.world = world
        self.workspace = workspace
        self.s3 = s3

    def send(self, req: McpRequest) -> McpResponse:
        ctx = ToolContext(world=self.world, workspace=self.workspace,
                          s3=self.s3, faas=False)
        return self.server.handle(req, ctx)


class FaaSTransport(Transport):
    """HTTPS Function-URL transport (paper §4.2)."""

    def __init__(self, platform, url: str, server_name: Optional[str] = None):
        self.platform = platform
        self.url = url
        self.server_name = server_name   # set for monolithic deployments

    def send(self, req: McpRequest) -> McpResponse:
        if self.server_name is not None:
            req = McpRequest(method=req.method,
                             params=dict(req.params, server=self.server_name),
                             id=req.id, session_id=req.session_id)
        raw = self.platform.invoke_url(self.url, req.to_json())
        return McpResponse.from_json(raw)


@dataclasses.dataclass
class ToolHandle:
    """A tool as exposed to an agent: spec + the client that can call it."""
    spec: ToolSpec
    client: "McpClient"

    @property
    def name(self) -> str:
        return self.spec.name

    def describe(self) -> str:
        return self.spec.describe()

    def call(self, **args) -> str:
        return self.client.call_tool(self.spec.name, args)


class McpClient:
    def __init__(self, transport: Transport, server_name: str):
        self.transport = transport
        self.server_name = server_name
        self.session_id: Optional[str] = None
        self.call_log: List[Dict[str, Any]] = []
        # per-client JSON-RPC ids: concurrent runs never interleave wire ids
        self._ids = RequestIdGenerator()

    def initialize(self) -> str:
        resp = self.transport.send(McpRequest(METHOD_INITIALIZE, {},
                                              id=self._ids.next()))
        if not resp.ok:
            raise RuntimeError(f"initialize failed: {resp.error}")
        self.session_id = resp.session_id
        return self.session_id or ""

    def list_tools(self) -> List[ToolHandle]:
        resp = self.transport.send(McpRequest(METHOD_LIST_TOOLS, {},
                                              id=self._ids.next(),
                                              session_id=self.session_id))
        if not resp.ok:
            raise RuntimeError(f"tools/list failed: {resp.error}")
        out = []
        for t in resp.result["tools"]:
            spec = ToolSpec(t["name"], t["description"], t["inputSchema"])
            out.append(ToolHandle(spec, self))
        return out

    def call_tool(self, name: str, args: Dict[str, Any]) -> str:
        req = McpRequest(METHOD_CALL_TOOL,
                         {"name": name, "arguments": args},
                         id=self._ids.next(), session_id=self.session_id)
        resp = self.transport.send(req)
        self.call_log.append({"tool": name, "args": args, "ok": resp.ok})
        if not resp.ok:
            return f"<tool-error server={self.server_name} tool={name}: " \
                   f"{resp.error.get('message')}>"
        content = resp.result.get("content", [])
        return "".join(c.get("text", "") for c in content)

    def close(self):
        if self.session_id:
            self.transport.send(McpRequest(METHOD_DELETE, {},
                                           id=self._ids.next(),
                                           session_id=self.session_id))
            self.session_id = None
