"""MCP server runtime.

An ``MCPServer`` hosts tools/resources/prompts and dispatches JSON-RPC
requests. Servers are deployment-agnostic: the same instance can be mounted
behind a LocalTransport (paper Fig. 2a) or packaged into a FaaS function
(Fig. 2b/2c) — execution context (``ToolContext``) carries the differences
(virtual clock, /tmp dir vs S3, session store).
"""
from __future__ import annotations

import dataclasses
import uuid
from typing import Any, Callable, Dict, List, Optional

from .protocol import (METHOD_CALL_TOOL, METHOD_DELETE, METHOD_INITIALIZE,
                       METHOD_GET_PROMPT, METHOD_LIST_PROMPTS,
                       METHOD_LIST_RESOURCES, METHOD_LIST_TOOLS,
                       METHOD_READ_RESOURCE, McpError, McpRequest,
                       McpResponse, PromptSpec, ResourceSpec, ToolSpec)


@dataclasses.dataclass
class ToolContext:
    """Execution environment handed to each tool invocation."""
    world: Any                      # repro.env.world.World
    workspace: Any                  # filesystem-ish store (local dir or /tmp)
    s3: Any = None                  # object store (FaaS deployments)
    session: Optional[Dict] = None  # per-session state dict
    faas: bool = False              # running inside a FaaS container?

    def sleep_for(self, tool: str):
        self.world.clock.sleep(self.world.latency.sample(tool, faas=self.faas))


class MCPServer:
    name: str = "server"
    origin: str = "custom"          # custom | community | official
    execution: str = "local"        # local | remote | local-remote
    memory_mb: int = 512
    storage_mb: int = 512

    def __init__(self):
        self.tools: Dict[str, ToolSpec] = {}
        self.resources: List[ResourceSpec] = []
        self.prompts: List[PromptSpec] = []
        self._sessions: Dict[str, Dict] = {}
        self.register()

    # -- registration -----------------------------------------------------
    def register(self):  # overridden by concrete servers
        raise NotImplementedError

    def tool(self, name: str, description: str,
             params: Dict[str, Dict[str, Any]] | None = None):
        schema = {"type": "object", "properties": params or {},
                  "required": [k for k, v in (params or {}).items()
                               if not v.get("optional")]}

        def deco(fn: Callable):
            self.tools[name] = ToolSpec(name, description, schema, fn)
            return fn
        return deco

    def amend_description(self, tool: str, extra: str):
        """Append a hint to a tool description (paper §5.2)."""
        t = self.tools[tool]
        self.tools[tool] = ToolSpec(t.name, t.description.rstrip() + " " + extra,
                                    t.input_schema, t.fn)

    def drop_tools(self, keep: List[str]):
        """FaaS deployments host only the app-relevant subset (§5.2)."""
        self.tools = {k: v for k, v in self.tools.items() if k in keep}

    # -- dispatch ----------------------------------------------------------
    def handle(self, req: McpRequest, ctx: ToolContext) -> McpResponse:
        try:
            if req.method == METHOD_INITIALIZE:
                sid = str(uuid.uuid4())
                self._sessions[sid] = {}
                return McpResponse(req.id, {"protocolVersion": "2025-03-26",
                                            "serverInfo": {"name": self.name}},
                                   session_id=sid)
            if req.method == METHOD_LIST_TOOLS:
                return McpResponse(req.id, {"tools": [t.to_wire()
                                                      for t in self.tools.values()]})
            if req.method == METHOD_LIST_RESOURCES:
                return McpResponse(req.id, {"resources": [r.to_wire()
                                                          for r in self.resources]})
            if req.method == METHOD_LIST_PROMPTS:
                return McpResponse(req.id, {"prompts": [p.to_wire()
                                                        for p in self.prompts]})
            if req.method == METHOD_GET_PROMPT:
                for p in self.prompts:
                    if p.name == req.params.get("name"):
                        return McpResponse(req.id, {"template": p.template})
                raise McpError(-32602, f"unknown prompt {req.params.get('name')}")
            if req.method == METHOD_DELETE:
                self._sessions.pop(req.session_id, None)
                return McpResponse(req.id, {"deleted": True})
            if req.method == METHOD_CALL_TOOL:
                return self._call_tool(req, ctx)
            raise McpError(-32601, f"method not found: {req.method}")
        except McpError as e:
            return McpResponse(req.id, error=e.to_wire())
        except Exception as e:  # tool bug -> JSON-RPC error, not crash
            return McpResponse(req.id, error={"code": -32000,
                                              "message": f"{type(e).__name__}: {e}"})

    def _call_tool(self, req: McpRequest, ctx: ToolContext) -> McpResponse:
        name = req.params.get("name")
        args = req.params.get("arguments") or {}
        spec = self.tools.get(name)
        if spec is None:
            raise McpError(-32602, f"unknown tool {name!r} on {self.name}")
        session = self._sessions.setdefault(req.session_id or "default", {})
        ctx = dataclasses.replace(ctx, session=session)
        ctx.sleep_for(name)
        result = spec.fn(ctx, **args)
        return McpResponse(req.id,
                           {"content": [{"type": "text",
                                         "text": result if isinstance(result, str)
                                         else __import__("json").dumps(result)}]},
                           session_id=req.session_id)

    # convenience for Table 1
    def describe_row(self):
        return {"server": self.name, "tools": len(self.tools),
                "origin": self.origin, "execution": self.execution,
                "memory_mb": self.memory_mb, "storage_mb": self.storage_mb}
