"""Agent-to-Agent (A2A) protocol layer — the paper's second future-work
item (§2.3/§7: "we leave A2A as future work").

Implements the A2A essentials: an ``AgentCard`` describing skills,
security schemes and supported formats (used for discovery), an
``A2AServer`` that exposes any pattern runner as a remote agent with a
task lifecycle (submitted -> working -> completed/failed), and an
``A2AClient`` for inter-agent delegation. ``examples/a2a_composition.py``
shows AgentX delegating a whole sub-application to a remote agent.

Tasks carry a run-event envelope: a handler that returns an ``events``
list of wire dicts (``repro.core.events.to_wire``) gets them attached to
the completed ``A2ATask``, and an ``A2AClient(on_event=...)`` replays
them to the caller's observers — a local ``RunMonitor`` sees the remote
run's full event stream, identical to an in-process subscriber's.
"""
from __future__ import annotations

import dataclasses
import uuid
from typing import Any, Callable, Dict, List, Optional

from ..env.world import World


@dataclasses.dataclass
class AgentSkill:
    id: str
    name: str
    description: str
    input_modes: List[str] = dataclasses.field(
        default_factory=lambda: ["text"])
    output_modes: List[str] = dataclasses.field(
        default_factory=lambda: ["text"])


@dataclasses.dataclass
class AgentCard:
    name: str
    description: str
    url: str
    skills: List[AgentSkill]
    version: str = "0.1.0"
    security_schemes: Dict[str, str] = dataclasses.field(
        default_factory=lambda: {"bearer": "Bearer token"})
    default_input_modes: List[str] = dataclasses.field(
        default_factory=lambda: ["text"])

    def to_wire(self) -> Dict[str, Any]:
        return {
            "name": self.name, "description": self.description,
            "url": self.url, "version": self.version,
            "securitySchemes": self.security_schemes,
            "defaultInputModes": self.default_input_modes,
            "skills": [dataclasses.asdict(s) for s in self.skills],
        }


@dataclasses.dataclass
class A2ATask:
    task_id: str
    skill_id: str
    message: str
    status: str = "submitted"       # submitted | working | completed | failed
    artifacts: List[Dict] = dataclasses.field(default_factory=list)
    history: List[Dict] = dataclasses.field(default_factory=list)
    # wire-serialized RunEvents of the remote run (to_wire dicts)
    events: List[Dict] = dataclasses.field(default_factory=list)


class A2AServer:
    """Hosts one agent behind the A2A task API."""

    def __init__(self, card: AgentCard, world: World,
                 handlers: Dict[str, Callable[[str], Dict]]):
        """handlers: skill_id -> fn(message) -> {"text":..., "success":...}"""
        self.card = card
        self.world = world
        self.handlers = handlers
        self.tasks: Dict[str, A2ATask] = {}

    # discovery
    def agent_card(self) -> Dict[str, Any]:
        return self.card.to_wire()

    # task lifecycle
    def send_task(self, skill_id: str, message: str) -> A2ATask:
        task = A2ATask(task_id=uuid.uuid4().hex[:12], skill_id=skill_id,
                       message=message)
        self.tasks[task.task_id] = task
        if skill_id not in self.handlers:
            task.status = "failed"
            task.history.append({"role": "agent",
                                 "text": f"unknown skill {skill_id!r}"})
            return task
        task.status = "working"
        task.history.append({"role": "user", "text": message})
        try:
            result = self.handlers[skill_id](message)
        except Exception as e:   # remote agent crash -> failed task
            task.status = "failed"
            task.history.append({"role": "agent", "text": f"error: {e}"})
            return task
        task.status = "completed" if result.get("success", True) else "failed"
        task.artifacts.append({"type": "text",
                               "text": result.get("text", "")})
        task.history.append({"role": "agent",
                             "text": result.get("text", "")[:200]})
        task.events.extend(result.get("events", []))
        return task

    def get_task(self, task_id: str) -> Optional[A2ATask]:
        return self.tasks.get(task_id)


class A2AClient:
    def __init__(self, world: World,
                 on_event: Optional[Callable] = None):
        self.world = world
        self.known: Dict[str, A2AServer] = {}
        self.on_event = on_event   # receives replayed remote RunEvents

    def discover(self, server: A2AServer) -> AgentCard:
        self.world.clock.sleep(0.05)          # card fetch
        self.known[server.card.name] = server
        return server.card

    def delegate(self, agent_name: str, skill_id: str,
                 message: str) -> A2ATask:
        server = self.known.get(agent_name)
        if server is None:
            raise KeyError(f"unknown agent {agent_name!r}; discover first")
        self.world.clock.sleep(0.08)          # task POST round trip
        task = server.send_task(skill_id, message)
        if task.events and self.on_event is not None:
            from ..core.events import from_wire
            for d in task.events:
                self.on_event(from_wire(d))
        return task


def expose_app_as_agent(world: World, app_name: str, pattern: str,
                        deployment: str, url: str) -> A2AServer:
    """Wrap a whole (app, pattern) pipeline as a remote A2A agent.

    The remote run's event stream is wire-streamed back on the task
    envelope, so callers with an ``on_event`` observer see it live.
    """
    from ..apps.apps import APPS
    from ..apps.runner import run_app
    from ..core.events import events_to_wire

    app = APPS[app_name]
    skill = AgentSkill(
        id=app_name, name=app_name.replace("_", " "),
        description=f"Executes the {app_name} workflow with the {pattern} "
                    f"pattern over {deployment} MCP servers.")
    card = AgentCard(
        name=f"{pattern}-{app_name}-agent",
        description=f"{pattern} agent for {app_name}", url=url,
        skills=[skill])

    def handler(message: str) -> Dict:
        instance = next((k for k in app.instances if k in message.lower()),
                        list(app.instances)[0])
        result = run_app(app_name, instance, pattern, deployment, seed=0)
        # bill the remote agent's virtual time on the caller's clock
        world.clock.sleep(result.total_latency)
        return {"text": result.artifact or "", "success": result.success,
                "events": events_to_wire(result.extras["events"])}

    return A2AServer(card, world, {app_name: handler})
