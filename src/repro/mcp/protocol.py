"""MCP protocol types: JSON-RPC 2.0 framing + tool/resource/prompt specs.

Mirrors the Model Context Protocol wire format (initialize / tools/list /
tools/call / resources/list / prompts/list / session lifecycle) closely
enough that transports are interchangeable: in-process "local stdio" or the
FaaS Function-URL HTTP bridge (``repro.faas``).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Callable, Dict, List, Optional

JSONRPC = "2.0"


class RequestIdGenerator:
    """Per-client JSON-RPC id sequence (1, 2, 3, ...).

    Each ``McpClient`` owns one, so concurrent runs (``execute_many``)
    produce deterministic, non-interleaved wire traces — there is no
    process-global counter shared across clients.
    """

    def __init__(self) -> None:
        self._ids = itertools.count(1)

    def next(self) -> int:
        return next(self._ids)


@dataclasses.dataclass
class ToolSpec:
    name: str
    description: str
    input_schema: Dict[str, Any]
    fn: Optional[Callable] = None

    def to_wire(self) -> Dict[str, Any]:
        return {"name": self.name, "description": self.description,
                "inputSchema": self.input_schema}

    def describe(self) -> str:
        args = ", ".join(
            f"{k}: {v.get('type', 'any')}"
            for k, v in self.input_schema.get("properties", {}).items())
        return f"{self.name}({args}): {self.description}"


@dataclasses.dataclass
class ResourceSpec:
    uri: str
    name: str
    description: str
    reader: Optional[Callable] = None

    def to_wire(self):
        return {"uri": self.uri, "name": self.name,
                "description": self.description}


@dataclasses.dataclass
class PromptSpec:
    name: str
    description: str
    template: str

    def to_wire(self):
        return {"name": self.name, "description": self.description}


@dataclasses.dataclass
class McpRequest:
    method: str
    params: Dict[str, Any]
    id: int = 0
    session_id: Optional[str] = None

    def to_json(self) -> str:
        body = {"jsonrpc": JSONRPC, "id": self.id, "method": self.method,
                "params": self.params}
        if self.session_id:
            body["params"] = dict(body["params"], _session_id=self.session_id)
        return json.dumps(body)

    @staticmethod
    def from_json(raw: str) -> "McpRequest":
        d = json.loads(raw)
        params = dict(d.get("params") or {})
        sid = params.pop("_session_id", None)
        return McpRequest(method=d["method"], params=params,
                          id=d.get("id", 0), session_id=sid)


@dataclasses.dataclass
class McpResponse:
    id: int
    result: Any = None
    error: Optional[Dict[str, Any]] = None
    session_id: Optional[str] = None
    # wire-streamed run events (``repro.core.events.to_wire`` dicts): set by
    # remote orchestrators so transports can replay a run's event stream to
    # local observers.
    events: Optional[List[Dict[str, Any]]] = None

    @property
    def ok(self) -> bool:
        return self.error is None

    def to_json(self) -> str:
        body: Dict[str, Any] = {"jsonrpc": JSONRPC, "id": self.id}
        if self.error is not None:
            body["error"] = self.error
        else:
            body["result"] = self.result
        if self.session_id:
            body["sessionId"] = self.session_id
        if self.events:
            body["events"] = self.events
        return json.dumps(body)

    @staticmethod
    def from_json(raw: str) -> "McpResponse":
        d = json.loads(raw)
        return McpResponse(id=d.get("id", 0), result=d.get("result"),
                           error=d.get("error"),
                           session_id=d.get("sessionId"),
                           events=d.get("events"))


class McpError(Exception):
    def __init__(self, code: int, message: str):
        super().__init__(message)
        self.code = code
        self.message = message

    def to_wire(self):
        return {"code": self.code, "message": self.message}


METHOD_INITIALIZE = "initialize"
METHOD_LIST_TOOLS = "tools/list"
METHOD_CALL_TOOL = "tools/call"
METHOD_LIST_RESOURCES = "resources/list"
METHOD_READ_RESOURCE = "resources/read"
METHOD_LIST_PROMPTS = "prompts/list"
METHOD_GET_PROMPT = "prompts/get"
METHOD_DELETE = "session/delete"
