"""Serper MCP server (community, remote): web search via the Google Serper
API — 13 tools per Table 1."""
from __future__ import annotations

import json
import zlib

from ..server import MCPServer, ToolContext


class SerperServer(MCPServer):
    name = "serper"
    origin = "community"
    execution = "remote"
    memory_mb = 512
    storage_mb = 512

    def register(self):
        t = self.tool

        @t("google_search", "Search Google for a query and return organic "
           "results with URLs and snippets.",
           {"query": {"type": "string", "description": "search query"},
            "num_results": {"type": "integer", "optional": True,
                            "description": "number of results (default 8)"}})
        def google_search(ctx: ToolContext, query: str, num_results: int = 8):
            pages = ctx.world.web.search(query, num_results)
            return json.dumps({"organic": [
                {"title": p.title, "link": p.url, "snippet": p.snippet}
                for p in pages]})

        @t("news_search", "Search Google News.", {"query": {"type": "string"}})
        def news_search(ctx, query: str):
            pages = ctx.world.web.search(query, 5)
            return json.dumps({"news": [{"title": p.title, "link": p.url}
                                        for p in pages]})

        @t("image_search", "Search Google Images.", {"query": {"type": "string"}})
        def image_search(ctx, query: str):
            return json.dumps({"images": []})

        @t("video_search", "Search Google Videos.", {"query": {"type": "string"}})
        def video_search(ctx, query: str):
            return json.dumps({"videos": []})

        @t("places_search", "Search Google Places.", {"query": {"type": "string"}})
        def places_search(ctx, query: str):
            return json.dumps({"places": []})

        @t("maps_search", "Search Google Maps.", {"query": {"type": "string"}})
        def maps_search(ctx, query: str):
            return json.dumps({"maps": []})

        @t("reviews_search", "Search Google Reviews.", {"query": {"type": "string"}})
        def reviews_search(ctx, query: str):
            return json.dumps({"reviews": []})

        @t("shopping_search", "Search Google Shopping.", {"query": {"type": "string"}})
        def shopping_search(ctx, query: str):
            return json.dumps({"shopping": []})

        @t("scholar_search", "Search Google Scholar.", {"query": {"type": "string"}})
        def scholar_search(ctx, query: str):
            pages = ctx.world.web.search(query, 3)
            return json.dumps({"scholar": [{"title": p.title} for p in pages]})

        @t("autocomplete", "Google query autocomplete suggestions.",
           {"query": {"type": "string"}})
        def autocomplete(ctx, query: str):
            return json.dumps({"suggestions": [query + " 2025", query + " review"]})

        @t("webpage_scrape", "Scrape a webpage via Serper scraping endpoint.",
           {"url": {"type": "string"}})
        def webpage_scrape(ctx, url: str):
            chunk, _ = ctx.world.web.fetch(url, 0, 3000)
            return chunk

        @t("trends_search", "Google Trends interest over time.",
           {"query": {"type": "string"}})
        def trends_search(ctx, query: str):
            # crc32, not builtin hash: responses must not vary per process
            return json.dumps(
                {"trend": [50 + (zlib.crc32(f"{query}{i}".encode()) % 40)
                           for i in range(12)]})

        @t("patents_search", "Search Google Patents.", {"query": {"type": "string"}})
        def patents_search(ctx, query: str):
            return json.dumps({"patents": []})
