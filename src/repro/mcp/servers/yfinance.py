"""YFinance MCP server (community, remote): 17 tools per Table 1."""
from __future__ import annotations

import json

from ..server import MCPServer, ToolContext


class YFinanceServer(MCPServer):
    name = "yfinance"
    origin = "community"
    execution = "remote"
    memory_mb = 128
    storage_mb = 512

    def register(self):
        t = self.tool

        @t("get_stock_history", "Get historical daily closing prices for a "
           "ticker over a period.",
           {"ticker": {"type": "string", "description": "ticker or company name"},
            "days": {"type": "integer", "optional": True,
                     "description": "lookback window in days (default 250)"}})
        def get_stock_history(ctx: ToolContext, ticker: str, days: int = 250):
            return json.dumps(ctx.world.stocks.history(ticker, days))

        @t("get_quote", "Latest quote for a ticker.", {"ticker": {"type": "string"}})
        def get_quote(ctx, ticker: str):
            h = ctx.world.stocks.history(ticker, 1)
            return json.dumps({"ticker": h["ticker"], "price": h["close"][-1]})

        simple = [
            ("get_dividends", "Dividend history."),
            ("get_splits", "Stock split history."),
            ("get_earnings", "Earnings reports."),
            ("get_balance_sheet", "Balance sheet."),
            ("get_income_statement", "Income statement."),
            ("get_cash_flow", "Cash-flow statement."),
            ("get_recommendations", "Analyst recommendations."),
            ("get_institutional_holders", "Institutional holders."),
            ("get_major_holders", "Major holders."),
            ("get_news", "Recent news for a ticker."),
            ("get_options_chain", "Options chain."),
            ("get_sector_info", "Sector and industry info."),
            ("get_market_cap", "Market capitalization."),
            ("get_analyst_targets", "Analyst price targets."),
        ]
        for name, desc in simple:
            def make(n):
                def fn(ctx, ticker: str):
                    tic = ctx.world.stocks.resolve(ticker)
                    return json.dumps({"ticker": tic, n.removeprefix("get_"): []})
                return fn
            t(name, desc, {"ticker": {"type": "string"}})(make(name))

        @t("compare_tickers", "Compare summary statistics of multiple tickers.",
           {"tickers": {"type": "array", "description": "list of tickers"}})
        def compare_tickers(ctx, tickers):
            out = {}
            for tk in tickers:
                h = ctx.world.stocks.history(tk, 30)
                out[h["ticker"]] = {"last": h["close"][-1],
                                    "mean30": round(sum(h["close"]) / 30, 2)}
            return json.dumps(out)
