"""File System MCP server (official, local): 10 tools per Table 1.

Not deployed on FaaS (Lambda lacks persistent local storage) — the custom
S3 server is its FaaS analogue (§4.1).
"""
from __future__ import annotations

import json

from ..server import MCPServer, ToolContext


class FileSystemServer(MCPServer):
    name = "filesystem"
    origin = "official"
    execution = "local"
    memory_mb = 0          # N/A in Table 1
    storage_mb = 0

    def register(self):
        t = self.tool

        @t("write_file", "Write text content to a file (creates or overwrites).",
           {"path": {"type": "string"}, "content": {"type": "string"}})
        def write_file(ctx: ToolContext, path: str, content: str):
            ctx.workspace.write(path, content)
            return json.dumps({"written": path, "bytes": len(content)})

        @t("read_file", "Read the contents of a file.",
           {"path": {"type": "string"}})
        def read_file(ctx, path: str):
            return ctx.workspace.read(path)

        @t("append_file", "Append text to a file.",
           {"path": {"type": "string"}, "content": {"type": "string"}})
        def append_file(ctx, path: str, content: str):
            old = ctx.workspace.read(path) if ctx.workspace.exists(path) else ""
            ctx.workspace.write(path, old + content)
            return json.dumps({"appended": path})

        @t("list_directory", "List files under a directory prefix.",
           {"path": {"type": "string", "optional": True}})
        def list_directory(ctx, path: str = ""):
            return json.dumps(ctx.workspace.list(path))

        @t("file_exists", "Check whether a file exists.",
           {"path": {"type": "string"}})
        def file_exists(ctx, path: str):
            return json.dumps({"exists": ctx.workspace.exists(path)})

        @t("delete_file", "Delete a file.", {"path": {"type": "string"}})
        def delete_file(ctx, path: str):
            ctx.workspace.delete(path)
            return json.dumps({"deleted": path})

        @t("move_file", "Move/rename a file.",
           {"src": {"type": "string"}, "dst": {"type": "string"}})
        def move_file(ctx, src: str, dst: str):
            ctx.workspace.write(dst, ctx.workspace.read(src))
            ctx.workspace.delete(src)
            return json.dumps({"moved": [src, dst]})

        @t("copy_file", "Copy a file.",
           {"src": {"type": "string"}, "dst": {"type": "string"}})
        def copy_file(ctx, src: str, dst: str):
            ctx.workspace.write(dst, ctx.workspace.read(src))
            return json.dumps({"copied": [src, dst]})

        @t("file_info", "Size and metadata of a file.",
           {"path": {"type": "string"}})
        def file_info(ctx, path: str):
            content = ctx.workspace.read(path)
            return json.dumps({"path": path, "bytes": len(content)})

        @t("search_files", "Search file contents for a substring.",
           {"pattern": {"type": "string"}})
        def search_files(ctx, pattern: str):
            hits = [p for p in ctx.workspace.list()
                    if pattern in ctx.workspace.read(p)]
            return json.dumps(hits)


class S3Server(MCPServer):
    """Custom S3 MCP server (Table 1): FaaS analogue of the filesystem."""
    name = "s3"
    origin = "custom"
    execution = "local"
    memory_mb = 128
    storage_mb = 512

    def register(self):
        t = self.tool

        @t("s3_write", "Write text content to an S3 object.",
           {"uri": {"type": "string", "description": "s3://bucket/key"},
            "content": {"type": "string"}})
        def s3_write(ctx: ToolContext, uri: str, content: str):
            ctx.s3.put_object(uri, content)
            return json.dumps({"written": uri, "bytes": len(content)})

        @t("s3_read", "Read an S3 object.", {"uri": {"type": "string"}})
        def s3_read(ctx, uri: str):
            return ctx.s3.get_object(uri)

        @t("s3_list", "List S3 objects under a prefix.",
           {"prefix": {"type": "string"}})
        def s3_list(ctx, prefix: str):
            return json.dumps(ctx.s3.list_objects(prefix))
