"""Code Execution MCP server (custom, local): 4 tools per Table 1.

Executes real Python in a restricted namespace. A stub ``matplotlib.pyplot``
records plotted series and ``savefig`` writes a synthetic PNG (header +
JSON payload of the plotted data) to the workspace or S3 — letting the
accuracy judge verify Data Accuracy / Data Quantity against the simulated
market ground truth (paper §5.4.1).
"""
from __future__ import annotations

import io
import json
import traceback
import types
from contextlib import redirect_stdout

from ..server import MCPServer, ToolContext

PREINSTALLED = ["matplotlib", "pandas", "numpy", "json", "math",
                "statistics", "datetime"]


def _make_pyplot(ctx: ToolContext):
    plt = types.SimpleNamespace()
    state = {"series": [], "title": "", "xlabel": "", "ylabel": "",
             "legend": False, "grid": False}

    def plot(*args, **kw):
        if len(args) >= 2:
            x, y = args[0], args[1]
        else:
            x, y = list(range(len(args[0]))), args[0]
        state["series"].append({"label": kw.get("label", ""),
                                "n": len(list(y)),
                                "y": [float(v) for v in list(y)[:1000]]})

    def savefig(path, **kw):
        payload = "PNG\x00" + json.dumps(state)
        store = ctx.s3 if (str(path).startswith("s3://") and ctx.s3 is not None) \
            else ctx.workspace
        store.write(str(path), payload)

    plt.plot = plot
    plt.savefig = savefig
    plt.title = lambda s, **k: state.__setitem__("title", s)
    plt.xlabel = lambda s, **k: state.__setitem__("xlabel", s)
    plt.ylabel = lambda s, **k: state.__setitem__("ylabel", s)
    plt.legend = lambda *a, **k: state.__setitem__("legend", True)
    plt.grid = lambda *a, **k: state.__setitem__("grid", True)
    plt.figure = lambda *a, **k: None
    plt.tight_layout = lambda *a, **k: None
    plt.show = lambda *a, **k: None
    plt.close = lambda *a, **k: None
    return plt, state


class CodeExecutionServer(MCPServer):
    name = "code-execution"
    origin = "custom"
    execution = "local"
    memory_mb = 512
    storage_mb = 512

    def register(self):
        t = self.tool

        @t("execute_python", "Execute a Python script in a sandboxed "
           "environment with matplotlib/pandas preinstalled; returns stdout "
           "or the error traceback.",
           {"code": {"type": "string", "description": "python source"}})
        def execute_python(ctx: ToolContext, code: str):
            import math as _math
            import statistics as _stats
            plt, plot_state = _make_pyplot(ctx)
            mpl = types.SimpleNamespace(pyplot=plt)
            modules = {"matplotlib": mpl, "matplotlib.pyplot": mpl,
                       "json": json, "math": _math, "statistics": _stats}

            def _sandbox_import(name, *a, **kw):
                if name in modules:
                    return modules[name.split(".")[0]]
                raise ImportError(f"module {name!r} not preinstalled in sandbox")

            builtin_src = (__builtins__ if isinstance(__builtins__, dict)
                           else vars(__builtins__))
            safe_builtins = {k: builtin_src.get(k)
                             for k in ("len", "range", "min", "max", "sum",
                                       "sorted", "enumerate", "zip", "map",
                                       "filter", "list", "dict", "set",
                                       "tuple", "str", "int", "float",
                                       "round", "abs", "print", "Exception",
                                       "ValueError", "KeyError")}
            safe_builtins["__import__"] = _sandbox_import
            ns = {"__builtins__": safe_builtins, "json": json, "math": _math,
                  "statistics": _stats, "matplotlib": mpl, "plt": plt}
            buf = io.StringIO()
            try:
                with redirect_stdout(buf):
                    exec(compile(code, "<agent-code>", "exec"), ns)  # noqa: S102
            except Exception:
                tb = traceback.format_exc(limit=2)
                return json.dumps({"status": "error", "stdout": buf.getvalue(),
                                   "error": tb})
            return json.dumps({"status": "ok", "stdout": buf.getvalue(),
                               "plots": len(plot_state["series"])})

        @t("list_packages", "List preinstalled Python packages.", {})
        def list_packages(ctx):
            return json.dumps(PREINSTALLED)

        @t("check_syntax", "Check Python source for syntax errors without "
           "executing it.", {"code": {"type": "string"}})
        def check_syntax(ctx, code: str):
            try:
                compile(code, "<check>", "exec")
                return json.dumps({"ok": True})
            except SyntaxError as e:
                return json.dumps({"ok": False, "error": str(e)})

        @t("reset_environment", "Reset the execution environment state.", {})
        def reset_environment(ctx):
            return json.dumps({"reset": True})
