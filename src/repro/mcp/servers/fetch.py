"""Fetch MCP server (official, remote): 9 tools per Table 1.

Reproduces the paper's fetch semantics: 5000-char chunks with the
``<error>Content truncated...</error>`` trailer that drives ReAct's repeated
re-fetch behaviour (§6.2).
"""
from __future__ import annotations

import json

from ..server import MCPServer, ToolContext

TRUNC = ("\n<error>Content truncated. Call the fetch tool with a "
         "start_index of {next} to get more content.</error>")


class FetchServer(MCPServer):
    name = "fetch"
    origin = "official"
    execution = "remote"
    memory_mb = 256
    storage_mb = 512

    def register(self):
        t = self.tool

        def _fetch(ctx, url, start_index=0, max_length=5000):
            chunk, truncated = ctx.world.web.fetch(url, start_index, max_length)
            if truncated:
                chunk += TRUNC.format(next=start_index + max_length)
            return chunk

        @t("fetch", "Fetches a URL from the internet and optionally extracts "
           "its contents as markdown.",
           {"url": {"type": "string", "description": "URL to fetch"},
            "max_length": {"type": "integer", "optional": True,
                           "description": "max characters to return (default 5000)"},
            "start_index": {"type": "integer", "optional": True,
                            "description": "character offset to start from"}})
        def fetch(ctx: ToolContext, url: str, max_length: int = 5000,
                  start_index: int = 0):
            return _fetch(ctx, url, start_index, max_length)

        @t("fetch_html", "Fetch a URL and return raw HTML.",
           {"url": {"type": "string"}})
        def fetch_html(ctx, url: str):
            body, _ = ctx.world.web.fetch(url, 0, 5000)
            return f"<html><body>{body}</body></html>"

        @t("fetch_markdown", "Fetch a URL and return markdown.",
           {"url": {"type": "string"}})
        def fetch_markdown(ctx, url: str):
            return _fetch(ctx, url)

        @t("fetch_txt", "Fetch a URL and return plain text.",
           {"url": {"type": "string"}})
        def fetch_txt(ctx, url: str):
            return _fetch(ctx, url)

        @t("fetch_json", "Fetch a URL and parse JSON.",
           {"url": {"type": "string"}})
        def fetch_json(ctx, url: str):
            return json.dumps({"url": url, "ok": True})

        @t("fetch_title", "Fetch only the page title.",
           {"url": {"type": "string"}})
        def fetch_title(ctx, url: str):
            return ctx.world.web.pages[url].title

        @t("fetch_links", "Fetch and list hyperlinks on the page.",
           {"url": {"type": "string"}})
        def fetch_links(ctx, url: str):
            topic = url.split("/")[3] if url.count("/") > 3 else ""
            return json.dumps({"links": ctx.world.web.by_topic.get(topic, [])[:5]})

        @t("fetch_headers", "HEAD request: response headers only.",
           {"url": {"type": "string"}})
        def fetch_headers(ctx, url: str):
            return json.dumps({"content-type": "text/html", "status": 200})

        @t("fetch_batch", "Fetch several URLs (first chunk each).",
           {"urls": {"type": "array"}})
        def fetch_batch(ctx, urls):
            return json.dumps({u: ctx.world.web.fetch(u, 0, 1000)[0]
                               for u in urls})
