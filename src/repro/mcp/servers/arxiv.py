"""arXiv MCP server (community, remote): 8 tools per Table 1.

Carries the paper's problematic default description for
``load_article_to_context`` (§5.2) — the local deployment amends it with
the "never use for research papers" hint.
"""
from __future__ import annotations

import json

from ..server import MCPServer, ToolContext


class ArxivServer(MCPServer):
    name = "arxiv"
    origin = "community"
    execution = "remote"
    memory_mb = 256
    storage_mb = 512

    def register(self):
        t = self.tool

        @t("search_arxiv", "Search arXiv.org for papers matching a query; "
           "returns ids, titles and abstracts.",
           {"query": {"type": "string"}, "max_results":
            {"type": "integer", "optional": True}})
        def search_arxiv(ctx: ToolContext, query: str, max_results: int = 5):
            hits = ctx.world.arxiv.search(query, max_results)
            return json.dumps([{"id": p.arxiv_id, "title": p.title,
                                "abstract": p.abstract[:300]} for p in hits])

        @t("get_article_url", "Get the arXiv URL of an article.",
           {"arxiv_id": {"type": "string"}})
        def get_article_url(ctx, arxiv_id: str):
            ctx.world.arxiv.get(arxiv_id)
            return f"https://arxiv.org/abs/{arxiv_id}"

        @t("download_article", "Download a paper PDF from arXiv to storage; "
           "returns the saved file path or S3 URI.",
           {"arxiv_id": {"type": "string"},
            "dest": {"type": "string", "optional": True,
                     "description": "target path or s3:// URI"}})
        def download_article(ctx: ToolContext, arxiv_id: str, dest: str = ""):
            paper = ctx.world.arxiv.get(arxiv_id)
            ctx.sleep_for("download_article")
            path = dest or f"/tmp/{arxiv_id}.pdf"
            store = ctx.s3 if (path.startswith("s3://") and ctx.s3 is not None) \
                else ctx.workspace
            store.write(path, paper.full_text())
            return json.dumps({"saved_to": path, "title": paper.title})

        @t("load_article_to_context", "Load the article hosted on arXiv.org "
           "into context as plain text.",
           {"arxiv_id": {"type": "string"}})
        def load_article_to_context(ctx, arxiv_id: str):
            ctx.sleep_for("load_article")
            return ctx.world.arxiv.get(arxiv_id).full_text()

        @t("get_details", "Get metadata (authors, categories, dates) for an "
           "arXiv article.", {"arxiv_id": {"type": "string"}})
        def get_details(ctx, arxiv_id: str):
            p = ctx.world.arxiv.get(arxiv_id)
            return json.dumps({"id": p.arxiv_id, "title": p.title,
                               "categories": ["cs.DC"],
                               "published": "2025-01-01"})

        @t("list_new_papers", "List newly announced papers in a category.",
           {"category": {"type": "string"}})
        def list_new_papers(ctx, category: str):
            return json.dumps([p.title for p in ctx.world.arxiv.papers.values()])

        @t("get_citations", "Get citation count / references of a paper.",
           {"arxiv_id": {"type": "string"}})
        def get_citations(ctx, arxiv_id: str):
            ctx.world.arxiv.get(arxiv_id)
            return json.dumps({"citations": 42})

        @t("get_abstract", "Get only the abstract of an arXiv article.",
           {"arxiv_id": {"type": "string"}})
        def get_abstract(ctx, arxiv_id: str):
            return ctx.world.arxiv.get(arxiv_id).abstract
