"""RAG MCP server (custom, local-remote): 1 tool per Table 1.

Mirrors the paper's design (§5.3.3): documents are split into overlapping
chunks, embedded via an "external embeddings API" (simulated latency; the
embedding itself is deterministic feature hashing of word n-grams computed
with numpy — a real, runnable embedding, just not a neural one), stored in
an in-memory vector store inside the server, and queried by cosine
similarity with a score threshold.

The FaaS variant takes an ``s3_uri`` instead of a local path (§4.2).
"""
from __future__ import annotations

import hashlib
import json
import math
from typing import Dict, List

import numpy as np

from ..server import MCPServer, ToolContext

EMBED_DIM = 256
CHUNK_CHARS = 800
OVERLAP = 160
THRESHOLD = 0.04


def embed(text: str) -> np.ndarray:
    """Feature-hashed bag-of-ngrams embedding (deterministic, offline)."""
    vec = np.zeros(EMBED_DIM, dtype=np.float64)
    words = text.lower().split()
    grams = words + [" ".join(words[i:i + 2]) for i in range(len(words) - 1)]
    for g in grams:
        h = int(hashlib.md5(g.encode()).hexdigest()[:8], 16)
        vec[h % EMBED_DIM] += 1.0 if (h >> 8) % 2 else -1.0
    norm = np.linalg.norm(vec)
    return vec / norm if norm else vec


def chunk_text(text: str) -> List[str]:
    out, i = [], 0
    while i < len(text):
        out.append(text[i:i + CHUNK_CHARS])
        i += CHUNK_CHARS - OVERLAP
    return out


class RagServer(MCPServer):
    name = "rag"
    origin = "custom"
    execution = "local-remote"   # embeddings API remote, vector store local
    memory_mb = 512
    storage_mb = 512

    def register(self):
        @self.tool(
            "document_retriever",
            "Retrieves relevant text snippets from a PDF based on a query. "
            "Input: path (str): path to the PDF file (local path, or an S3 "
            "URI like s3://my-bucket/report.pdf in cloud deployments). "
            "query (str): the query to search in the PDF file. Output: "
            "snippets of text from the PDF relevant to the query, with "
            "similarity metrics.",
            {"path": {"type": "string"}, "query": {"type": "string"}})
        def document_retriever(ctx: ToolContext, path: str, query: str):
            store = ctx.s3 if (path.startswith("s3://") and ctx.s3 is not None) \
                else ctx.workspace
            text = store.read(path)     # raises FileNotFoundError -> RPC error
            # vector store is cached per session per document
            cache: Dict = ctx.session.setdefault("vector_store", {})
            key = hashlib.md5((path + str(len(text))).encode()).hexdigest()
            if key not in cache:
                chunks = chunk_text(text)
                # one "external embeddings API" call per chunk batch
                ctx.world.clock.sleep(0.04 * len(chunks))
                mat = np.stack([embed(c) for c in chunks])
                cache[key] = (chunks, mat)
            chunks, mat = cache[key]
            qv = embed(query)
            ctx.world.clock.sleep(0.08)   # query-embedding API call
            scores = mat @ qv
            order = np.argsort(-scores)[:4]
            hits = [{"snippet": chunks[int(i)], "score": round(float(scores[i]), 4)}
                    for i in order if scores[i] > THRESHOLD]
            return json.dumps({"query": query, "results": hits})
