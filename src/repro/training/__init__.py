from .optimizer import OptConfig, init_opt_state, adamw_update
from .train_loop import make_train_step, train
from .checkpoint import save_checkpoint, load_checkpoint
from .data import SyntheticLM, AgentTraceCorpus
