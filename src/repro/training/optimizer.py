"""AdamW with cosine schedule + global-norm clipping (pure pytree impl).

Optimizer state shards exactly like the parameters (same tree structure),
so the dry-run's 2D (FSDP × TP) sharding covers m/v for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: OptConfig, params, grads, state):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
