"""Training step + loop."""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import loss_fn
from .checkpoint import save_checkpoint
from .data import SyntheticLM
from .optimizer import OptConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig
                    ) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}
    return train_step


def train(cfg: ModelConfig, steps: int = 50, batch: int = 4,
          seq_len: int = 128, seed: int = 0, lr: float = 3e-4,
          dtype=jnp.float32, log_every: int = 10,
          checkpoint_dir: Optional[str] = None,
          data=None, params=None) -> Dict[str, Any]:
    """Single-host training loop (multi-host goes through repro.launch)."""
    from ..models.params import init_params
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                        total_steps=steps)
    key = jax.random.key(seed)
    if params is None:
        params = init_params(cfg, key, dtype=dtype)
    opt_state = init_opt_state(params)
    data = data or SyntheticLM(cfg.vocab_size, seq_len, batch, seed,
                               cfg.frontend_positions if cfg.frontend else 0,
                               cfg.d_model)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    history = []
    t0 = time.time()
    for step in range(steps):
        batch_np = data.batch_at(step)
        batch_j = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch_j)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
    wall = time.time() - t0
    if checkpoint_dir:
        save_checkpoint(checkpoint_dir, params, opt_state, steps,
                        {"arch": cfg.name})
    return {"params": params, "opt_state": opt_state, "history": history,
            "wall_s": wall,
            "final_loss": history[-1]["loss"] if history else float("nan")}
