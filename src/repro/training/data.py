"""Deterministic LM data pipeline.

Two sources:
  - ``SyntheticLM``: seeded Zipf-ish token stream (infinite, shardable) —
    used by train loops and the dry-run's weak-type-correct batches.
  - ``AgentTraceCorpus``: text harvested from the agentic benchmark traces
    (tool outputs + summaries), tokenized with HashTokenizer — trains the
    serving models on the same distribution the agents produce, closing the
    loop between the two halves of the framework.
"""
from __future__ import annotations

import math
from typing import Dict, Iterator, List, Optional

import numpy as np

from ..data.tokenizer import HashTokenizer


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, batch: int,
                 seed: int = 0, frontend_positions: int = 0,
                 d_model: int = 0):
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.frontend_positions = frontend_positions
        self.d_model = d_model

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 100_003 + step)
        # Zipf-ish marginal over the vocab for realistic token stats
        z = rng.zipf(1.3, size=(self.batch, self.seq_len))
        tokens = (z % self.vocab).astype(np.int32)
        out = {"tokens": tokens}
        if self.frontend_positions:
            out["frontend_embeds"] = rng.standard_normal(
                (self.batch, self.frontend_positions, self.d_model),
                dtype=np.float32) * 0.02
        return out


class AgentTraceCorpus:
    """Tokenized corpus of agent-produced text."""

    def __init__(self, texts: List[str], vocab_size: int, seq_len: int,
                 batch: int, seed: int = 0):
        self.tok = HashTokenizer(vocab_size)
        ids: List[int] = []
        for t in texts:
            ids.extend(self.tok.encode(t))
        need = max(batch * seq_len + 1, 2)
        reps = math.ceil(need / max(len(ids), 1))
        self.stream = np.array((ids * max(reps, 1))[:need], dtype=np.int32)
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + step)
        n = len(self.stream) - self.seq_len - 1
        starts = rng.integers(0, max(n, 1), size=self.batch)
        toks = np.stack([self.stream[s:s + self.seq_len] for s in starts])
        return {"tokens": toks.astype(np.int32)}
