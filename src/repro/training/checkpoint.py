"""Checkpointing: flattened-pytree .npz save/restore with metadata.

Pure numpy (no orbax dependency): keys are '/'-joined tree paths, values
host-gathered arrays. Restores into an arbitrary target sharding by letting
jax.device_put re-shard on load.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    meta: Dict | None = None) -> str:
    os.makedirs(path, exist_ok=True)
    np.savez_compressed(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez_compressed(os.path.join(path, "opt.npz"),
                            **_flatten(opt_state))
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, **(meta or {})}, f)
    return path


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(jax.device_put(arr.astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_checkpoint(path: str, params_template, opt_template=None
                    ) -> Tuple[Any, Any, int]:
    flat = dict(np.load(os.path.join(path, "params.npz")))
    params = _unflatten_into(params_template, flat)
    opt_state = None
    opt_file = os.path.join(path, "opt.npz")
    if opt_template is not None and os.path.exists(opt_file):
        opt_state = _unflatten_into(opt_template, dict(np.load(opt_file)))
    with open(os.path.join(path, "meta.json")) as f:
        step = json.load(f).get("step", 0)
    return params, opt_state, step
