"""Plan compilation: trace -> DAG workflow graphs + template-keyed cache.

The layer between the pattern registry and the run cache (ROADMAP item
"plan compilation").  A successful AgentX run's event trace is lifted
into a :class:`PlanGraph` — a typed DAG of tool-call nodes with
data-flow edges — keyed by an (app, task-template) fingerprint that
normalizes spec-specific values (entity names, seeds) out of the task
text.  Repeat-shaped traffic then replays the graph through the
``agentx-compiled`` pattern with ZERO stage-designer/planner LLM calls,
falling back to full re-planning on any deviation.

    from repro.apps.session import RunSpec, Session
    from repro.plans import PlanCache

    session = Session(plan_cache=PlanCache())
    session.execute(spec)                # miss: plans fresh, compiles
    session.execute(spec.with_seed(1))   # hit: replays the graph, 0 planner calls
"""
from .cache import PlanCache
from .compile import (PlanGraph, PlanNode, PlanSlot, PlanStage, compile_result,
                      compile_trace, extract_params, graph_from_wire,
                      graph_to_wire, normalize_task, plan_key)
from .execute import CompiledAgentXRunner, PlanDeviation

__all__ = [
    "PlanCache", "PlanGraph", "PlanNode", "PlanSlot", "PlanStage",
    "CompiledAgentXRunner", "PlanDeviation", "compile_result",
    "compile_trace", "extract_params", "graph_from_wire", "graph_to_wire",
    "normalize_task", "plan_key",
]
