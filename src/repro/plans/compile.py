"""Trace -> PlanGraph compiler.

Lifts a successful run's :class:`RunEvent` trace into a typed DAG of
tool-call nodes.  Each node's arguments are split into *slots*:

  - ``param``   — the whole value equals one of the task's extracted
                  parameters (entity name, query, filename): spec-bound,
                  re-bound per replay from the replay task's text;
  - ``extract`` — the value is recoverable from a PRIOR node's result by
                  a deterministic extractor (URL list, arXiv id, saved
                  path): a data-flow edge of the DAG, re-extracted from
                  the LIVE result at replay time;
  - ``lit``     — template-bound literal (tool constants, fixed paths);
                  parameter substrings inside it are parameterized
                  (``s3://.../<<filename>>``) so the literal survives a
                  change of instance;
  - ``dyn``     — the value overlaps prior tool results in a way no
                  extractor explains (generated summaries, plotting
                  code): the node keeps its executor LLM call on replay.

The graph is keyed by :func:`plan_key` — a fingerprint over the app, the
pattern (+ its ``PatternConfig`` fingerprint), the deployment capability
fingerprint and the *normalized* task template with the spec-specific
variable text removed (:func:`normalize_task`).  Two specs that differ
only in seed or entity names share a key; different task structure
cannot collide (the template text itself is hashed).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from typing import Any, Dict, List, Optional, Tuple

from ..apps.apps import APPS
from ..core.events import (PlanProduced, RunCompleted, RunEvent, RunStarted,
                           StageCompleted, StageStarted, ToolInvoked)

GRAPH_VERSION = 1

# the s3 hint AppSpec.prompt appends under remote deployments
S3_HINT = (" ...you can read/write from s3 from this location: "
           "'s3://dummy-bucket/agent/'")

# parameter placeholder delimiters — visually distinct, never produced by
# the simulated tools, and JSON-safe on the wire
_OPEN, _CLOSE = "⟪", "⟫"


class TemplateMismatch(ValueError):
    """The task text does not match the app's template (stale graph,
    hand-built task)."""


# ---------------------------------------------------------------------------
# task-template normalization + parameter extraction


def normalize_task(app: str, task: str) -> Tuple[str, str, bool]:
    """Normalize a task back to its template: returns
    ``(template_text, var, remote)`` where ``template_text`` is the app
    template with the instance variable UNsubstituted (plus a remote
    marker when the s3 hint was appended).  Raises
    :class:`TemplateMismatch` when the task is not an instantiation of
    the app's template."""
    spec = APPS.get(app)
    if spec is None:
        raise TemplateMismatch(f"unknown app {app!r}")
    remote = task.endswith(S3_HINT)
    body = task[: -len(S3_HINT)] if remote else task
    pattern = re.escape(spec.template).replace(re.escape("{var}"), "(.+)")
    m = re.fullmatch(pattern, body, flags=re.DOTALL)
    if m is None:
        raise TemplateMismatch(
            f"task does not match the {app!r} template: {task[:120]!r}")
    template = spec.template + (" [remote-storage]" if remote else "")
    return template, m.group(1), remote


def extract_params(app: str, task: str) -> Dict[str, str]:
    """Spec-bound parameters of a task, mirroring the app policies'
    parsers (:mod:`repro.core.policies`) so slot binding agrees with
    what the oracle decisions contain."""
    _, var, _ = normalize_task(app, task)
    if app == "web_search":
        return {"query": var.strip("'\"")}
    if app == "stock_correlation":
        m = re.match(r"(.+?),? and save it as (\S+?\.png)", var)
        if m is None:
            return {"var": var}
        companies = [c.strip() for c in re.split(r",| and ", m.group(1))
                     if c.strip()]
        params = {f"c{i}": c for i, c in enumerate(companies)}
        params["filename"] = m.group(2)
        return params
    if app == "research_report":
        return {"title": var.strip(" '\"")}
    if app == "multi_topic_digest":
        topics = [t.strip(" '\"") for t in var.split(";") if t.strip()]
        return {f"t{i}": t for i, t in enumerate(topics)}
    return {"var": var}


def parameterize(text: str, params: Dict[str, str]) -> str:
    """Replace every parameter value occurring in ``text`` with its
    placeholder, longest value first (so ``AppleAlphabetMicrosoft.png``
    is consumed by ``filename`` before ``Microsoft`` matches)."""
    for name, value in sorted(params.items(), key=lambda kv: -len(kv[1])):
        if value:
            text = text.replace(value, f"{_OPEN}{name}{_CLOSE}")
    return text


def materialize(text: str, params: Dict[str, str]) -> str:
    """Inverse of :func:`parameterize` under the replay spec's params."""
    for name, value in params.items():
        text = text.replace(f"{_OPEN}{name}{_CLOSE}", value)
    return text


# ---------------------------------------------------------------------------
# data-flow extractors (shared by compile- and replay-time binding)


def _x_urls(text: str) -> List[str]:
    return re.findall(r"https?://\S+?(?=[\s,\"')\]]|$)", text)


def _x_arxiv_ids(text: str) -> List[str]:
    return re.findall(r"\d{4}\.\d{4,5}", text)


def _x_saved_paths(text: str) -> List[str]:
    return re.findall(r'"saved_to":\s*"([^"]+)"', text)


EXTRACTORS = {
    "url": _x_urls,
    "arxiv_id": _x_arxiv_ids,
    "saved_path": _x_saved_paths,
}


# ---------------------------------------------------------------------------
# graph types


@dataclasses.dataclass(frozen=True)
class PlanSlot:
    """One argument slot of a node; ``kind`` in lit|param|extract|dyn."""
    kind: str
    value: Any = None          # lit: the (parameterized) literal
    param: str = ""            # param: parameter name
    what: str = ""             # extract: extractor kind
    src: int = -1              # extract: source node id
    index: int = 0             # extract: item index in the extraction


@dataclasses.dataclass(frozen=True)
class PlanNode:
    id: int
    stage: int
    server: str
    tool: str
    slots: Dict[str, PlanSlot]
    desc: str = ""             # parameterized step description
    ok: bool = True            # the source invocation's ok flag

    @property
    def dyn(self) -> bool:
        return any(s.kind == "dyn" for s in self.slots.values())


@dataclasses.dataclass(frozen=True)
class PlanStage:
    index: int
    name: str                  # parameterized stage name
    tools_needed: Tuple[str, ...]
    nodes: Tuple[int, ...]     # node ids, execution order


@dataclasses.dataclass(frozen=True)
class PlanGraph:
    app: str
    pattern: str
    template: str              # normalized task template (incl. remote marker)
    params: Tuple[str, ...]    # parameter-name schema
    stages: Tuple[PlanStage, ...]
    nodes: Tuple[PlanNode, ...]
    source: Dict[str, Any]     # provenance: instance / seed / deployment
    version: int = GRAPH_VERSION

    def node(self, node_id: int) -> PlanNode:
        return self.nodes[node_id]

    def edges(self) -> List[Tuple[int, int]]:
        """Data-flow edges (src -> dst) implied by extract slots."""
        out = []
        for n in self.nodes:
            for s in n.slots.values():
                if s.kind == "extract":
                    out.append((s.src, n.id))
        return sorted(set(out))

    @property
    def dyn_nodes(self) -> int:
        return sum(1 for n in self.nodes if n.dyn)


# ---------------------------------------------------------------------------
# wire serialization (RunCache conventions: JSON-safe dicts, versioned)


def graph_to_wire(graph: PlanGraph) -> Dict[str, Any]:
    d = dataclasses.asdict(graph)
    return json.loads(json.dumps(d))   # tuples -> lists, JSON-safe


def graph_from_wire(d: Dict[str, Any]) -> PlanGraph:
    if d.get("version") != GRAPH_VERSION:
        raise ValueError(f"plan-graph version {d.get('version')!r} != "
                         f"{GRAPH_VERSION}")
    nodes = tuple(
        PlanNode(id=n["id"], stage=n["stage"], server=n["server"],
                 tool=n["tool"], desc=n.get("desc", ""),
                 ok=n.get("ok", True),
                 slots={k: PlanSlot(**s) for k, s in n["slots"].items()})
        for n in d["nodes"])
    stages = tuple(
        PlanStage(index=s["index"], name=s["name"],
                  tools_needed=tuple(s["tools_needed"]),
                  nodes=tuple(s["nodes"]))
        for s in d["stages"])
    return PlanGraph(app=d["app"], pattern=d["pattern"],
                     template=d["template"], params=tuple(d["params"]),
                     stages=stages, nodes=nodes, source=d.get("source", {}),
                     version=d["version"])


# ---------------------------------------------------------------------------
# plan key (template fingerprint chain)


def _compilable_runner(runner_cls: type) -> bool:
    from ..core.agentx import AgentXRunner
    return (isinstance(runner_cls, type)
            and issubclass(runner_cls, AgentXRunner)
            and not getattr(runner_cls, "is_compiled", False))


def plan_key(spec) -> Optional[str]:
    """Template fingerprint of a spec, or ``None`` when the spec is not
    plan-compilable (non-AgentX pattern, custom backend factory, task
    outside the app template).

    The chain mirrors ``spec_fingerprint`` minus everything spec-bound:
    app + normalized template (+ remote marker) + pattern name + pattern
    config fingerprint + deployment capability fingerprint.  ``seed``,
    ``instance``, ``llm`` and ``priority`` are deliberately absent —
    that is the generalization from *identical* specs (run cache) to
    *similar* ones (plan cache)."""
    if spec.backend_factory is not None:
        return None
    from ..core.runtime import resolve_pattern
    from ..faas.deployments import resolve_deployment
    try:
        rp = resolve_pattern(spec.pattern)
        caps = resolve_deployment(spec.deployment).capabilities
    except KeyError:
        return None
    if not _compilable_runner(rp.runner_cls):
        return None
    try:
        task = APPS[spec.app].prompt(spec.instance, caps.remote)
        template, _, remote = normalize_task(spec.app, task)
    except (KeyError, TemplateMismatch):
        return None
    payload = json.dumps({
        "app": spec.app,
        "template": template,
        "remote": remote,
        "pattern": spec.pattern,
        "pattern_config": rp.config.fingerprint(),
        "deployment_caps": caps.fingerprint(),
        "graph_version": GRAPH_VERSION,
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()


# ---------------------------------------------------------------------------
# slot classification


_MIN_OVERLAP_LEN = 24    # shorter strings are treated as constants
_WINDOW = 40
_MAX_WINDOWS = 64


def _overlaps_prior(value: str, results: List[str]) -> bool:
    """Does ``value`` look derived from prior tool output?  True when any
    ~window of it appears verbatim in a prior result — the signature of
    generated content (summaries, code embedding fetched data)."""
    if len(value) < _MIN_OVERLAP_LEN:
        return False
    if len(value) <= _WINDOW:
        windows = [value]
    else:
        step = _WINDOW // 2
        starts = range(0, len(value) - _WINDOW + 1, step)
        windows = [value[i:i + _WINDOW] for i in list(starts)[:_MAX_WINDOWS]]
    for r in results:
        if any(w in r for w in windows):
            return True
    return False


def _classify(value: Any, params: Dict[str, str],
              prior: List[Tuple[PlanNode, str]]) -> PlanSlot:
    """Classify one argument value against the params and the results of
    all prior nodes (``prior`` = [(node, result_text), ...])."""
    if not isinstance(value, str):
        return PlanSlot("lit", value=value)
    for name, pv in params.items():
        if value == pv:
            return PlanSlot("param", param=name)
    for node, result in reversed(prior):
        for what, fn in EXTRACTORS.items():
            items = fn(result)
            if value in items:
                return PlanSlot("extract", what=what, src=node.id,
                                index=items.index(value))
    if _overlaps_prior(value, [r for _, r in prior]):
        return PlanSlot("dyn")
    return PlanSlot("lit", value=parameterize(value, params))


# ---------------------------------------------------------------------------
# the compiler


def compile_trace(events: List[RunEvent], *, app: str, pattern: str,
                  instance: str = "", seed: int = 0,
                  deployment: str = "") -> Optional[PlanGraph]:
    """Compile a successful run's event stream into a :class:`PlanGraph`.

    Returns ``None`` when the trace is not compilable: the run did not
    complete, has no stage structure (non-AgentX trace), the task does
    not match the app template, or tool events predate the ``args`` /
    ``result`` fields (a pre-plan disk cache)."""
    task = next((e.task for e in events if isinstance(e, RunStarted)), None)
    completed = any(e.completed for e in events if isinstance(e, RunCompleted))
    if task is None or not completed:
        return None
    try:
        template, _, _ = normalize_task(app, task)
    except TemplateMismatch:
        return None
    params = extract_params(app, task)

    stages: List[Dict[str, Any]] = []
    nodes: List[PlanNode] = []
    prior: List[Tuple[PlanNode, str]] = []
    cur: Optional[Dict[str, Any]] = None
    for ev in events:
        if isinstance(ev, StageStarted):
            cur = {"index": ev.index, "name": ev.name, "plan": None,
                   "nodes": []}
            stages.append(cur)
        elif isinstance(ev, PlanProduced) and cur is not None:
            cur["plan"] = ev.plan
        elif isinstance(ev, ToolInvoked):
            te = ev.event
            if cur is None or te.args is None or te.result is None:
                return None   # stage-less or pre-plan trace
            slots = {k: _classify(v, params, prior)
                     for k, v in te.args.items()}
            step = _step_for(cur, len(cur["nodes"]), te.tool)
            node = PlanNode(id=len(nodes), stage=cur["index"],
                            server=te.server, tool=te.tool, slots=slots,
                            desc=parameterize(step, params), ok=te.ok)
            nodes.append(node)
            cur["nodes"].append(node.id)
            prior.append((node, te.result))
        elif isinstance(ev, StageCompleted):
            if not ev.success:
                return None
            cur = None
    if not stages:
        return None

    plan_stages = tuple(
        PlanStage(index=s["index"], name=parameterize(s["name"], params),
                  tools_needed=tuple((s["plan"] or {}).get(
                      "tools_needed", sorted({nodes[i].tool
                                              for i in s["nodes"]}))),
                  nodes=tuple(s["nodes"]))
        for s in stages)
    return PlanGraph(app=app, pattern=pattern, template=template,
                     params=tuple(params), stages=plan_stages,
                     nodes=tuple(nodes),
                     source={"instance": instance, "seed": seed,
                             "deployment": deployment})


def _step_for(stage: Dict[str, Any], pos: int, tool: str) -> str:
    """Description for the ``pos``-th invocation of a stage, from the
    source plan when the step aligns, else synthesized."""
    steps = (stage["plan"] or {}).get("steps", [])
    if pos < len(steps) and steps[pos].get("tool") in ("", tool):
        return str(steps[pos].get("description", f"call {tool}"))
    return f"call {tool}"


def compile_result(result) -> Optional[PlanGraph]:
    """Convenience: compile a completed :class:`RunResult` (uses the event
    stream in ``extras`` and the spec identity)."""
    spec = result.extras.get("spec")
    events = result.extras.get("events", [])
    if spec is None or not events or not result.success:
        return None
    return compile_trace(events, app=spec.app, pattern=spec.pattern,
                         instance=spec.instance, seed=spec.seed,
                         deployment=spec.deployment)
