"""Template-keyed plan cache: in-memory store with optional disk
persistence, built on the shared :mod:`repro.core.persist` conventions
(thread lock, atomic ``os.replace`` writes, corrupt-file skip on load,
hit/miss counters) that the run cache and the durable run journal also
use.

Keys are :func:`repro.plans.compile.plan_key` fingerprints — one entry
per (app template, pattern config, deployment capabilities) combination,
shared across instances and seeds.  ``put`` overwrites: when a replay
deviates and the fallback run recompiles, the fresh graph replaces the
stale one.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Optional

from ..core.persist import atomic_write_json, load_json_dir
from .compile import PlanGraph, graph_from_wire, graph_to_wire


class PlanCache:
    """In-memory + optionally disk-persistent store of compiled plans."""

    def __init__(self, cache_dir: Optional[str] = None):
        self.cache_dir = cache_dir
        self._lock = threading.Lock()
        self._store: Dict[str, PlanGraph] = {}
        self.hits = 0
        self.misses = 0
        self.fallbacks = 0
        if cache_dir:
            os.makedirs(cache_dir, exist_ok=True)
            self._load()

    # ------------------------------------------------------------------
    def get(self, key: str) -> Optional[PlanGraph]:
        with self._lock:
            graph = self._store.get(key)
            if graph is None:
                self.misses += 1
            else:
                self.hits += 1
            return graph

    def put(self, key: str, graph: PlanGraph) -> None:
        with self._lock:
            self._store[key] = graph
        if self.cache_dir:
            self._persist(key, graph)

    def record_fallback(self, key: str) -> None:
        with self._lock:
            self.fallbacks += 1

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "entries": len(self._store),
                "hits": self.hits,
                "misses": self.misses,
                "fallbacks": self.fallbacks,
                "hit_rate": (self.hits / lookups) if lookups else 0.0,
            }

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = self.misses = self.fallbacks = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._store

    # ------------------------------------------------------------------
    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"plan_{key}.json")

    def _persist(self, key: str, graph: PlanGraph) -> None:
        atomic_write_json(self._path(key),
                          {"key": key, "graph": graph_to_wire(graph)})

    def _load(self) -> None:
        # corrupt or version-mismatched entries are skipped: recompile
        self._store.update(load_json_dir(
            self.cache_dir,
            lambda stem, payload: (payload["key"],
                                   graph_from_wire(payload["graph"])),
            prefix="plan_"))
