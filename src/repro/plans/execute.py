"""Compiled-plan executor: walks a :class:`PlanGraph` with zero
stage-designer/planner LLM calls.

``CompiledAgentXRunner`` registers as the ``agentx-compiled`` pattern and
subclasses :class:`repro.core.agentx.AgentXRunner`, so every tool call
goes through the SAME :meth:`AgentRuntime.invoke` path — retry/hedge
policies, fault injection, deployment transports and ``RunEvent``
emission apply unchanged.  Per stage it emits the familiar
``StageStarted`` / ``PlanProduced`` / ``ReflectionEmitted`` /
``StageCompleted`` events, re-binding the graph's argument slots against
the replay task's parameters and the LIVE results of upstream nodes.

LLM calls that remain on replay:

  - one executor call per *dyn* node (arguments the compiler could not
    bind statically: generated summaries, plotting code), and
  - one executor reflection per stage (it produces the cross-stage
    summary later stages' content depends on).

Everything else — the stage-designer call, every planner call, every
executor dispatch whose tool call is statically bound — is gone.

Any divergence from the graph raises :class:`PlanDeviation`; the session
catches it and falls back to full AgentX re-planning (recompiling from
the fresh run), so a stale or mismatched graph degrades to exactly the
uncompiled behavior.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from ..core.agentx import EXECUTOR_SYSTEM, AgentXRunner
from ..core.events import PlanProduced, StageCompleted, StageStarted
from ..core.llm import Decision, LLMRequest, ToolCall
from ..core.runtime import PatternConfig, RunOutcome, register_pattern
from ..core.schema import REFLECTION_SCHEMA
from .compile import (EXTRACTORS, _OPEN, PlanGraph, PlanNode, TemplateMismatch,
                      extract_params, materialize, normalize_task)


class PlanDeviation(RuntimeError):
    """A compiled replay diverged from its graph (node failure, tool or
    template mismatch, unbindable slot).  Carries the stage index for the
    ``PlanFallback`` event the session emits on the fallback run."""

    def __init__(self, reason: str, stage: int = -1):
        super().__init__(reason)
        self.reason = reason
        self.stage = stage


@register_pattern("agentx-compiled", rank=24)
class CompiledAgentXRunner(AgentXRunner):
    """AgentX with the planning layer replaced by a compiled graph.

    Requires :meth:`bind_graph` before :meth:`run`; ``Session`` does this
    when its plan cache holds a graph for the spec's template key.  The
    small per-stage ``plan-rebind`` overhead replaces the pattern's
    stage-dispatch + plan-dispatch overheads."""

    pattern = "agentx-compiled"
    is_compiled = True
    default_config = PatternConfig(max_steps=14, overhead_local_s=0.05,
                                   overhead_faas_s=0.04)

    graph: PlanGraph = None   # type: ignore[assignment]

    def bind_graph(self, graph: PlanGraph) -> None:
        self.graph = graph

    # ------------------------------------------------------------------
    def _run(self, task: str) -> RunOutcome:
        g = self.graph
        if g is None:
            raise RuntimeError("agentx-compiled requires bind_graph() — "
                               "drive it through Session(plan_cache=...)")
        try:
            template, _, _ = normalize_task(g.app, task)
        except TemplateMismatch:
            raise PlanDeviation("template-mismatch")
        if template != g.template:
            raise PlanDeviation("template-mismatch")
        params = extract_params(g.app, task)
        if set(params) != set(g.params):
            raise PlanDeviation("param-schema-mismatch")

        summaries: List[str] = []
        results: Dict[int, str] = {}
        stage_names = []
        for stage in g.stages:
            name = materialize(stage.name, params)
            stage_names.append(name)
            self._replay_stage(task, stage, name, params, summaries, results)
        return RunOutcome(completed=True, data={
            "stages": stage_names, "summaries": summaries, "compiled": True})

    # ------------------------------------------------------------------
    def _replay_stage(self, task, stage, name, params, summaries, results):
        g = self.graph
        idx = stage.index
        self.emit(StageStarted(t=self.now(), index=idx, name=name))
        self.overhead("plan-rebind")
        plan = self._materialize_plan(stage, params, results)
        self.emit(PlanProduced(t=self.now(), index=idx, plan=plan))
        filtered = [t for t in self.tools if t.name in stage.tools_needed]

        stage_history: List[Dict] = []
        exec_calls = 0
        for node_id in stage.nodes:
            node = g.node(node_id)
            if node.dyn:
                if exec_calls >= self.config.max_steps:
                    raise PlanDeviation("step-budget", idx)
                d = self._executor(task, name, idx, plan, stage_history,
                                   summaries, filtered)
                exec_calls += 1
                if d.tool_call is None:
                    raise PlanDeviation("early-reflection", idx)
                if d.tool_call.tool != node.tool:
                    raise PlanDeviation(
                        f"tool-mismatch:{d.tool_call.tool}!={node.tool}", idx)
                call = d.tool_call
            else:
                call = ToolCall(node.server, node.tool,
                                self._bind_args(node, params, results, idx))
            result = self.invoke(call)
            stage_history.append({"tool": call.tool, "args": call.args,
                                  "result": result})
            results[node.id] = result
            if result.startswith("<tool-error") and node.ok:
                raise PlanDeviation(f"node-failed:{node.tool}", idx)

        # terminal reflection: produces the cross-stage summary
        d = self._executor(task, name, idx, plan, stage_history, summaries,
                           filtered)
        if d.tool_call is not None:
            raise PlanDeviation("extra-tool-call:" + d.tool_call.tool, idx)
        reflection = d.structured
        self.reflect(idx, reflection)
        summaries.append(reflection["execution_results"])
        success = bool(reflection["success"])
        self.emit(StageCompleted(t=self.now(), index=idx, success=success))
        if not success:
            raise PlanDeviation("stage-failed", idx)

    # ------------------------------------------------------------------
    def _materialize_plan(self, stage, params, results) -> Dict[str, Any]:
        """Rebuild the stage plan from the graph: static slots bound (so
        the executor policy sees e.g. the fetch URLs, exactly as the
        fresh planner would have written them), dyn and not-yet-resolved
        extract slots omitted (the fresh planner left those empty too)."""
        steps = []
        for node_id in stage.nodes:
            node = self.graph.node(node_id)
            bound = {}
            for k, slot in node.slots.items():
                if slot.kind == "lit" or slot.kind == "param":
                    bound[k] = self._bind_slot(slot, params, results,
                                               stage.index)
                elif slot.kind == "extract" and slot.src in results:
                    bound[k] = self._bind_slot(slot, params, results,
                                               stage.index)
            steps.append({"description": materialize(node.desc, params),
                          "tool": node.tool, "params": bound})
        return {"steps": steps, "tools_needed": list(stage.tools_needed)}

    def _bind_args(self, node: PlanNode, params, results, idx) -> Dict:
        return {k: self._bind_slot(s, params, results, idx)
                for k, s in node.slots.items()}

    def _bind_slot(self, slot, params, results, idx):
        if slot.kind == "lit":
            if isinstance(slot.value, str):
                value = materialize(slot.value, params)
                if _OPEN in value:
                    raise PlanDeviation("unbound-placeholder", idx)
                return value
            return slot.value
        if slot.kind == "param":
            if slot.param not in params:
                raise PlanDeviation(f"param-missing:{slot.param}", idx)
            return params[slot.param]
        if slot.kind == "extract":
            src = results.get(slot.src)
            if src is None:
                raise PlanDeviation("dangling-edge", idx)
            items = EXTRACTORS[slot.what](src)
            if slot.index >= len(items):
                raise PlanDeviation(f"extract-short:{slot.what}", idx)
            return items[slot.index]
        raise PlanDeviation(f"unbindable-slot:{slot.kind}", idx)

    # ------------------------------------------------------------------
    def _executor(self, task, name, idx, plan, stage_history, summaries,
                  filtered) -> Decision:
        """One execution-agent inference, prompt-identical to the fresh
        AgentX executor loop (same message text, meta and filtered tool
        surface), so token accounting and policy behavior match."""
        history_text = "\n".join(
            f"[{h['tool']}] -> {h['result'][:2000]}" for h in stage_history)
        resp = self.complete(LLMRequest(
            agent="executor", system=EXECUTOR_SYSTEM,
            messages=[
                {"role": "user", "content":
                 f"{json.dumps(plan['steps'])}\n"
                 f"Context: {' '.join(summaries)}\n"
                 f"Tool results so far:\n{history_text}"},
            ],
            tools=filtered, schema=REFLECTION_SCHEMA,
            meta={"task": task, "stage": name, "stage_idx": idx,
                  "plan": plan, "stage_history": stage_history,
                  "summaries": summaries, "cot": False}))
        return resp.decision
