"""Tokenizers.

``CountTokenizer``: deterministic token *accounting* for the agentic
benchmarks (≈ GPT-4-class BPE density: ~4 chars/token with a word floor).

``HashTokenizer``: a real reversible-enough tokenizer for the JAX serving
engine — byte-level with a vocab-sized hash bucketing, so any ModelConfig
vocab works without shipping a BPE model.
"""
from __future__ import annotations

import math
from typing import List


class CountTokenizer:
    """Token counting compatible with the paper's accounting granularity."""

    @staticmethod
    def count(text: str) -> int:
        if not text:
            return 0
        words = len(text.split())
        return max(math.ceil(len(text) / 4), words)


class HashTokenizer:
    """Byte tokenizer bucketed into an arbitrary vocab size (>=260)."""

    def __init__(self, vocab_size: int):
        assert vocab_size >= 260, vocab_size
        self.vocab_size = vocab_size
        self.bos = vocab_size - 1
        self.eos = vocab_size - 2

    def encode(self, text: str, add_bos: bool = True) -> List[int]:
        ids = [b + 1 for b in text.encode("utf-8")]  # 1..256
        return ([self.bos] + ids) if add_bos else ids

    def decode(self, ids: List[int]) -> str:
        bs = bytes(i - 1 for i in ids
                   if 1 <= i <= 256)
        return bs.decode("utf-8", errors="replace")
