"""SloMonitor: windowed error-budget burn rate against ``SLOTarget``s.

The classic SRE construction, made deterministic: runs are bucketed
into tumbling windows of ``window_s`` *virtual* seconds aligned to the
timeline origin (window k covers ``[k*window_s, (k+1)*window_s)``).
When an observation arrives past a window's end, the window finalizes:

    error budget  = 1 - target          (success objective)
    burn rate     = window error rate / error budget

A burn rate of 1.0 means the window spent budget exactly at the rate
that exhausts it over the SLO period; ``threshold`` (default 2.0) is
the multiple that fires an alert.  Latency and TTFT objectives treat a
run over ``slo.latency_s`` / ``slo.ttft_s`` as an error against the
same budget — one uniform burn-rate currency across objectives, so the
alert stream is comparable across dimensions.

Alerts are typed events (:class:`repro.core.events.SloAlertFired`)
handed to ``on_alert`` and counted into the registry
(``repro_slo_alerts_total{slo=...}``, ``repro_slo_burn_rate{slo=...}``)
— replaying the same seeded workload re-fires byte-identical alerts.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from ..core.events import SloAlertFired
from .metrics import MetricsRegistry


@dataclasses.dataclass
class _Window:
    index: int
    bad: Dict[str, int] = dataclasses.field(default_factory=dict)
    total: Dict[str, int] = dataclasses.field(default_factory=dict)


class SloMonitor:
    """Feed one finished run per :meth:`observe` call (or a whole
    traffic report via :meth:`observe_records`); call :meth:`finalize`
    after the last observation to flush the open window."""

    OBJECTIVES = ("success", "latency", "ttft")

    def __init__(self, slo, window_s: float = 60.0,
                 threshold: float = 2.0, min_count: int = 1,
                 registry: Optional[MetricsRegistry] = None,
                 on_alert: Optional[Callable] = None):
        self.slo = slo
        self.window_s = float(window_s)
        self.threshold = float(threshold)
        self.min_count = int(min_count)
        self.on_alert = on_alert
        self.alerts: List[SloAlertFired] = []
        self._window: Optional[_Window] = None
        self._registry = registry
        if registry is not None:
            self._alert_counter = registry.counter(
                "repro_slo_alerts_total",
                "SLO burn-rate alerts, by objective")
            self._burn_gauge = registry.gauge(
                "repro_slo_burn_rate",
                "Last finalized window's burn rate, by objective")
        else:
            self._alert_counter = None
            self._burn_gauge = None

    # -- budgets -------------------------------------------------------------
    def _budget(self, objective: str) -> float:
        """Error budget for one objective: the tolerated error fraction.
        The success target doubles as the attainment target for the
        latency/TTFT objectives (the SLO says: ``success_rate`` of runs
        succeed AND meet latency)."""
        return max(1.0 - float(self.slo.success_rate), 1e-9)

    # -- observation ---------------------------------------------------------
    def observe(self, t: float, ok: bool, latency_s: float,
                ttft_s: Optional[float] = None) -> None:
        """One finished run at virtual time ``t``."""
        idx = int(t // self.window_s) if self.window_s > 0 else 0
        if self._window is None:
            self._window = _Window(idx)
        elif idx > self._window.index:
            self._finalize_window()
            self._window = _Window(idx)
        w = self._window
        checks = {
            "success": not ok,
            "latency": latency_s > float(self.slo.latency_s),
        }
        if ttft_s is not None:
            checks["ttft"] = ttft_s > float(self.slo.ttft_s)
        for objective, violated in checks.items():
            w.total[objective] = w.total.get(objective, 0) + 1
            if violated:
                w.bad[objective] = w.bad.get(objective, 0) + 1

    def observe_records(self, records) -> None:
        """Fold traffic records in record-index order (deterministic)."""
        for r in sorted(records, key=lambda r: r.index):
            self.observe(r.end, r.result.success, r.latency, r.ttft)
        self.finalize()

    def finalize(self) -> None:
        """Flush the open window (call once after the last run)."""
        if self._window is not None:
            self._finalize_window()
            self._window = None

    # -- the burn check ------------------------------------------------------
    def _finalize_window(self) -> None:
        w = self._window
        start = w.index * self.window_s
        end = start + self.window_s
        for objective in self.OBJECTIVES:
            total = w.total.get(objective, 0)
            if total < self.min_count:
                continue
            bad = w.bad.get(objective, 0)
            burn = (bad / total) / self._budget(objective)
            if self._burn_gauge is not None:
                self._burn_gauge.set(burn, slo=objective)
            if burn >= self.threshold:
                target = {"success": self.slo.success_rate,
                          "latency": self.slo.latency_s,
                          "ttft": self.slo.ttft_s}[objective]
                alert = SloAlertFired(
                    t=end, slo=objective, window_start=start,
                    window_s=self.window_s, burn_rate=burn,
                    threshold=self.threshold, bad=bad, total=total,
                    target=float(target))
                self.alerts.append(alert)
                if self._alert_counter is not None:
                    self._alert_counter.inc(slo=objective)
                if self.on_alert is not None:
                    self.on_alert(alert)

    # -- summary -------------------------------------------------------------
    def summary(self) -> Dict[str, object]:
        return {
            "window_s": self.window_s,
            "threshold": self.threshold,
            "alerts": len(self.alerts),
            "by_objective": {
                o: sum(1 for a in self.alerts if a.slo == o)
                for o in self.OBJECTIVES},
        }
