"""Deterministic metrics core: Counter / Gauge / Histogram / Registry.

The design constraint that shapes everything here is *reproducibility*:
the same event stream folded twice — in-process or wire-replayed, under
the virtual clock — must yield byte-identical exports.  So:

  * bucket bounds are FIXED log-spaced constants (no adaptive buckets);
  * series are keyed by sorted ``(label, value)`` tuples and exports
    iterate metrics and series in sorted order (insertion order never
    leaks into the output);
  * timestamps come from the registry's injected ``clock`` — pass a
    :class:`repro.traffic.driver.VirtualTimeline`'s ``now`` (or any
    deterministic callable) and nothing in an export depends on wall
    time.

Thread-safe: one registry lock covers every mutation, so a registry can
sit behind ``Session.execute_many``'s worker threads exactly like the
pre-telemetry ``RunMonitor`` did.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def log_buckets(start: float, decades: int) -> List[float]:
    """Fixed 1-2.5-5 log-spaced bounds: ``start`` scaled through
    ``decades`` powers of ten.  The mantissa pattern keeps every bound
    exactly representable and human-readable while staying (near-)
    uniform in log space."""
    out: List[float] = []
    for d in range(decades):
        for m in (1.0, 2.5, 5.0):
            out.append(start * (10.0 ** d) * m)
    return out


# latency: 1ms .. 500s  (runs, tool calls, queue waits)
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = tuple(log_buckets(0.001, 6))
# counts: 1 .. 50k  (tokens per call, batch sizes)
DEFAULT_COUNT_BUCKETS: Tuple[float, ...] = tuple(log_buckets(1.0, 5))


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Metric:
    """Base: one named family holding labeled series."""

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 unit: str = ""):
        self.registry = registry
        self.name = name
        self.help = help
        self.unit = unit
        self.series: Dict[LabelKey, Any] = {}

    def _get(self, labels: Dict[str, str], default):
        key = _label_key(labels)
        if key not in self.series:
            self.series[key] = default()
        return key

    def labelsets(self) -> List[LabelKey]:
        return sorted(self.series)


class Counter(Metric):
    """Monotonic accumulator."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        with self.registry._lock:
            key = self._get(labels, float)
            self.series[key] += amount

    def value(self, **labels) -> float:
        with self.registry._lock:
            return self.series.get(_label_key(labels), 0.0)

    def total(self) -> float:
        with self.registry._lock:
            return sum(self.series.values())


class Gauge(Metric):
    """Last-written value per series (plus ``add`` / ``max_of`` for
    running gauges like peak occupancy)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self.registry._lock:
            key = self._get(labels, float)
            self.series[key] = float(value)

    def add(self, amount: float, **labels) -> None:
        with self.registry._lock:
            key = self._get(labels, float)
            self.series[key] += amount

    def max_of(self, value: float, **labels) -> None:
        with self.registry._lock:
            key = self._get(labels, float)
            self.series[key] = max(self.series[key], float(value))

    def value(self, **labels) -> float:
        with self.registry._lock:
            return self.series.get(_label_key(labels), 0.0)


class HistogramSeries:
    """One labeled histogram series: per-bucket counts + sum + count,
    with at most one exemplar per bucket (the LAST observation that
    landed there — deterministic for a deterministic stream)."""

    __slots__ = ("counts", "sum", "count", "exemplars")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)   # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self.exemplars: Dict[int, Tuple[Dict[str, str], float, float]] = {}


class Histogram(Metric):
    """Fixed-bound histogram.  ``le`` semantics match Prometheus: an
    observation equal to a bound lands in that bound's bucket (bucket
    counts are cumulative only at export time)."""

    kind = "histogram"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 unit: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(registry, name, help, unit)
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))

    def _bucket_index(self, value: float) -> int:
        for i, b in enumerate(self.buckets):
            if value <= b:
                return i
        return len(self.buckets)             # +Inf

    def observe(self, value: float,
                exemplar: Optional[Dict[str, str]] = None,
                t: Optional[float] = None, **labels) -> None:
        """Record one observation; ``exemplar`` (e.g. ``{"run": ...,
        "span": ...}``) links this sample back to its span tree, stamped
        at ``t`` (defaults to the registry clock)."""
        with self.registry._lock:
            key = self._get(labels,
                            lambda: HistogramSeries(len(self.buckets)))
            s: HistogramSeries = self.series[key]
            idx = self._bucket_index(value)
            s.counts[idx] += 1
            s.sum += value
            s.count += 1
            if exemplar is not None:
                when = t if t is not None else self.registry.now()
                s.exemplars[idx] = (dict(exemplar), float(value),
                                    float(when))

    def snapshot(self, **labels) -> Dict[str, Any]:
        with self.registry._lock:
            s = self.series.get(_label_key(labels))
            if s is None:
                return {"count": 0, "sum": 0.0, "counts": []}
            return {"count": s.count, "sum": s.sum,
                    "counts": list(s.counts)}


class Scope:
    """A registry view that stamps constant labels on every write —
    ``registry.scope(layer="engine")`` gives subsystem code its own
    namespace without threading label dicts everywhere.  Metrics created
    through a scope live in the parent registry (same families, same
    export)."""

    def __init__(self, registry: "MetricsRegistry",
                 const_labels: Dict[str, str]):
        self._registry = registry
        self._const = dict(const_labels)

    def _bind(self, metric):
        const = self._const

        class _Bound:
            def __getattr__(self, item):
                fn = getattr(metric, item)
                if item in ("inc", "set", "add", "max_of", "observe",
                            "value"):
                    reserved = ("amount", "value", "exemplar", "t")

                    def call(*a, **kw):
                        merged = dict(const)
                        merged.update({k: v for k, v in kw.items()
                                       if k not in reserved})
                        merged.update({k: kw[k] for k in reserved
                                       if k in kw})
                        return fn(*a, **merged)
                    return call
                return fn

        return _Bound()

    def counter(self, name: str, help: str = "", unit: str = ""):
        return self._bind(self._registry.counter(name, help, unit))

    def gauge(self, name: str, help: str = "", unit: str = ""):
        return self._bind(self._registry.gauge(name, help, unit))

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_LATENCY_BUCKETS):
        return self._bind(self._registry.histogram(name, help, unit,
                                                   buckets))


class MetricsRegistry:
    """The scoped home of every metric family.

    ``clock`` is the single time source for exemplar/export timestamps:
    inject a virtual clock (``VirtualTimeline().now``) and exports are a
    pure function of the folded stream — the byte-identical-replay
    invariant the telemetry tests enforce.  Re-requesting a name returns
    the existing family (kind mismatches raise)."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._lock = threading.RLock()
        self._metrics: Dict[str, Metric] = {}
        self._clock = clock if clock is not None else (lambda: 0.0)

    def now(self) -> float:
        return float(self._clock())

    def set_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock

    # -- family constructors -------------------------------------------------
    def _family(self, cls, name: str, help: str, unit: str, **kw) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(self, name, help, unit, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "", unit: str = "") -> Counter:
        return self._family(Counter, name, help, unit)

    def gauge(self, name: str, help: str = "", unit: str = "") -> Gauge:
        return self._family(Gauge, name, help, unit)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._family(Histogram, name, help, unit, buckets=buckets)

    def scope(self, **const_labels) -> Scope:
        return Scope(self, {k: str(v) for k, v in const_labels.items()})

    # -- reads ---------------------------------------------------------------
    def get(self, name: str) -> Optional[Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def total(self, name: str) -> float:
        """Sum of a counter/gauge family across all its series (0.0 for
        an unregistered name)."""
        m = self.get(name)
        if m is None:
            return 0.0
        with self._lock:
            if isinstance(m, Histogram):
                return float(sum(s.count for s in m.series.values()))
            return float(sum(m.series.values()))

    def series_values(self, name: str) -> Dict[LabelKey, Any]:
        """Sorted {label key: value} snapshot of one family."""
        m = self.get(name)
        if m is None:
            return {}
        with self._lock:
            if isinstance(m, Histogram):
                return {k: {"count": s.count, "sum": s.sum}
                        for k, s in sorted(m.series.items())}
            return dict(sorted(m.series.items()))

    def label_values(self, name: str, label: str) -> List[str]:
        """Sorted distinct values of ``label`` across a family's series."""
        m = self.get(name)
        if m is None:
            return []
        with self._lock:
            vals = {dict(k).get(label) for k in m.series}
        return sorted(v for v in vals if v is not None)
