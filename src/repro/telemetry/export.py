"""Registry exporters: Prometheus text format and OTLP-metrics JSON.

Both renderings are pure functions of the registry state plus the
registry clock — metrics iterate in sorted name order, series in sorted
label order, floats format through one canonical ``repr``-based helper —
so two registries folded from the same deterministic stream export
byte-identically (the telemetry CI smoke asserts exactly this).

Histogram exemplars render in OpenMetrics style
(``... # {run="3",span="000000000000000a"} value timestamp``), carrying
the run/span ids the bridge assigned — the same deterministic sequence
ids :func:`repro.tenancy.tracing.fold_spans` gives the matching span
tree, so a latency outlier in a dashboard links straight back to its
span.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry


def _fmt(v: float) -> str:
    """Canonical number rendering: integers without the trailing ``.0``,
    everything else through ``repr`` (shortest round-trip form)."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape(v: str) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _labels_text(key, extra: Optional[Dict[str, str]] = None) -> str:
    items = list(key)
    if extra:
        items += sorted(extra.items())
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in items)
    return "{" + body + "}"


def _exemplar_text(labels: Dict[str, str], value: float, t: float) -> str:
    body = ",".join(f'{k}="{_escape(v)}"'
                    for k, v in sorted(labels.items()))
    return f" # {{{body}}} {_fmt(value)} {_fmt(t)}"


def render_prometheus(registry: MetricsRegistry,
                      prefix: str = "") -> str:
    """Render every family as Prometheus/OpenMetrics text.  ``prefix``
    filters to names starting with it (e.g. ``"repro_jit_"`` for the
    profiling subsection alone)."""
    lines: List[str] = []
    with registry._lock:
        for name in registry.names():
            if prefix and not name.startswith(prefix):
                continue
            m = registry.get(name)
            lines.append(f"# HELP {name} {_escape(m.help)}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, (Counter, Gauge)):
                for key in m.labelsets():
                    lines.append(f"{name}{_labels_text(key)} "
                                 f"{_fmt(m.series[key])}")
            elif isinstance(m, Histogram):
                for key in m.labelsets():
                    s = m.series[key]
                    cum = 0
                    for i, bound in enumerate(m.buckets):
                        cum += s.counts[i]
                        line = (f"{name}_bucket"
                                f"{_labels_text(key, {'le': _fmt(bound)})}"
                                f" {cum}")
                        ex = s.exemplars.get(i)
                        if ex is not None:
                            line += _exemplar_text(*ex)
                        lines.append(line)
                    cum += s.counts[-1]
                    line = (f"{name}_bucket"
                            f"{_labels_text(key, {'le': '+Inf'})} {cum}")
                    ex = s.exemplars.get(len(m.buckets))
                    if ex is not None:
                        line += _exemplar_text(*ex)
                    lines.append(line)
                    lines.append(f"{name}_sum{_labels_text(key)} "
                                 f"{_fmt(s.sum)}")
                    lines.append(f"{name}_count{_labels_text(key)} "
                                 f"{s.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> Dict[str, Dict[str, float]]:
    """Minimal parser for the text format above (CI smoke + tests):
    returns ``{metric_name: {label_text: value}}``.  Exemplars are
    stripped; the ``le`` label stays part of the label text."""
    out: Dict[str, Dict[str, float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if " # " in line:                      # strip exemplar
            line = line.split(" # ", 1)[0].rstrip()
        head, _, value = line.rpartition(" ")
        if "{" in head:
            name, labels = head.split("{", 1)
            labels = "{" + labels
        else:
            name, labels = head, ""
        out.setdefault(name, {})[labels] = float(value)
    return out


# ---------------------------------------------------------------------------
# OTLP-metrics-shaped JSON


def _otlp_attr(k: str, v: str) -> Dict[str, Any]:
    return {"key": k, "value": {"stringValue": str(v)}}


def _otlp_point(key, value: float, t: float) -> Dict[str, Any]:
    return {"attributes": [_otlp_attr(k, v) for k, v in key],
            "timeUnixNano": str(int(round(t * 1e9))),
            "asDouble": float(value)}


def to_otlp_metrics(registry: MetricsRegistry,
                    service: str = "repro") -> Dict[str, Any]:
    """Render the registry as an OTLP/JSON ``ExportMetricsServiceRequest``
    payload (``resourceMetrics → scopeMetrics → metrics``), mirroring the
    span exporter's shape discipline (:func:`repro.tenancy.tracing.to_otlp`).
    Timestamps come from the registry clock — deterministic under a
    virtual timeline."""
    t = registry.now()
    metrics: List[Dict[str, Any]] = []
    with registry._lock:
        for name in registry.names():
            m = registry.get(name)
            entry: Dict[str, Any] = {"name": name, "description": m.help,
                                     "unit": m.unit}
            if isinstance(m, Counter):
                entry["sum"] = {
                    "aggregationTemporality": 2,   # CUMULATIVE
                    "isMonotonic": True,
                    "dataPoints": [_otlp_point(k, m.series[k], t)
                                   for k in m.labelsets()]}
            elif isinstance(m, Gauge):
                entry["gauge"] = {
                    "dataPoints": [_otlp_point(k, m.series[k], t)
                                   for k in m.labelsets()]}
            elif isinstance(m, Histogram):
                points = []
                for k in m.labelsets():
                    s = m.series[k]
                    point = {
                        "attributes": [_otlp_attr(a, b) for a, b in k],
                        "timeUnixNano": str(int(round(t * 1e9))),
                        "count": str(s.count),
                        "sum": s.sum,
                        "bucketCounts": [str(c) for c in s.counts],
                        "explicitBounds": list(m.buckets),
                    }
                    exemplars = []
                    for idx in sorted(s.exemplars):
                        labels, val, when = s.exemplars[idx]
                        exemplars.append({
                            "filteredAttributes": [
                                _otlp_attr(a, b)
                                for a, b in sorted(labels.items())],
                            "timeUnixNano": str(int(round(when * 1e9))),
                            "asDouble": val})
                    if exemplars:
                        point["exemplars"] = exemplars
                    points.append(point)
                entry["histogram"] = {"aggregationTemporality": 2,
                                      "dataPoints": points}
            metrics.append(entry)
    return {"resourceMetrics": [{
        "resource": {"attributes": [
            {"key": "service.name", "value": {"stringValue": service}},
        ]},
        "scopeMetrics": [{
            "scope": {"name": "repro.telemetry"},
            "metrics": metrics,
        }],
    }]}


def export_otlp_metrics_json(registry: MetricsRegistry,
                             service: str = "repro",
                             indent: Optional[int] = None) -> str:
    return json.dumps(to_otlp_metrics(registry, service=service),
                      indent=indent, sort_keys=True)
