"""Profiling hooks for the jitted hot paths.

``JitProfiler.wrap(name, fn)`` returns a drop-in callable that times
every call (wall seconds, synchronized via ``jax.block_until_ready`` so
async dispatch doesn't hide the work) and counts *compiles*: a call
whose abstract signature — array shapes/dtypes plus static kwargs — has
not been seen before triggers a trace+compile in jax, so first-seen
signatures are counted as compiles (cross-checked against the jit
cache's ``_cache_size`` when the wrapped function exposes it).

The wrapper changes WHEN the python thread resumes, never WHAT the
computation returns — profiled engines stay bit-identical to bare ones
(the parity suite runs both ways).  Wall times are inherently
nondeterministic, which is why the profiler keeps its OWN registry by
default: the deterministic bridge registry can be byte-compared across
replays while profile stats ride in a separate export/section.

``wrap_engine`` hooks the serving engine's jitted members in place
(``decode_step``, the fixed-shape prefill behind ``prefill_batch_ids``,
the extend/chunk path, exact prefill); ``wrap_kernel_ops`` rebinds the
Pallas kernel wrappers (``paged_decode_attention_op`` et al.) at module
level and returns a restore handle.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

from .metrics import MetricsRegistry, log_buckets

# call-time buckets: 10µs .. 5s
JIT_CALL_BUCKETS = tuple(log_buckets(1e-5, 6))


def _signature(args, kwargs) -> tuple:
    """Abstract signature of one call: shapes/dtypes for array-likes,
    values for hashable statics, type names otherwise."""
    def one(v):
        shape = getattr(v, "shape", None)
        dtype = getattr(v, "dtype", None)
        if shape is not None and dtype is not None:
            return ("arr", tuple(shape), str(dtype))
        if isinstance(v, dict):
            return ("dict", tuple((k, one(v[k])) for k in sorted(v)))
        if isinstance(v, (list, tuple)):
            return ("seq", tuple(one(x) for x in v))
        if isinstance(v, (bool, int, float, str, type(None))):
            return ("lit", v)
        return ("type", type(v).__name__)
    return (tuple(one(a) for a in args),
            tuple((k, one(kwargs[k])) for k in sorted(kwargs)))


class JitProfile:
    """Stats for one wrapped function."""

    __slots__ = ("name", "calls", "compiles", "total_s", "min_s", "max_s",
                 "last_s", "_signatures")

    def __init__(self, name: str):
        self.name = name
        self.calls = 0
        self.compiles = 0
        self.total_s = 0.0
        self.min_s = float("inf")
        self.max_s = 0.0
        self.last_s = 0.0
        self._signatures: set = set()

    def as_dict(self) -> Dict[str, Any]:
        return {
            "calls": self.calls,
            "compiles": self.compiles,
            "total_s": self.total_s,
            "avg_ms": (self.total_s / self.calls * 1e3) if self.calls
            else 0.0,
            "min_ms": (self.min_s * 1e3) if self.calls else 0.0,
            "max_ms": self.max_s * 1e3,
        }


class JitProfiler:
    """Owns the profiles plus the metric families they feed.

    ``registry`` defaults to a fresh private one (see module docstring);
    pass a shared registry to co-locate profile series with other
    metrics when byte-determinism of that registry is not required."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._clock = clock
        self._lock = threading.Lock()
        self.profiles: Dict[str, JitProfile] = {}
        self._calls = self.registry.counter(
            "repro_jit_calls_total", "Profiled jit executions, by fn")
        self._compiles = self.registry.counter(
            "repro_jit_compiles_total",
            "Traces compiled (first-seen call signatures), by fn")
        self._seconds = self.registry.histogram(
            "repro_jit_call_seconds", "Per-call wall time, by fn",
            unit="s", buckets=JIT_CALL_BUCKETS)

    def profile(self, name: str) -> JitProfile:
        with self._lock:
            p = self.profiles.get(name)
            if p is None:
                p = self.profiles[name] = JitProfile(name)
            return p

    def wrap(self, name: str, fn: Callable) -> Callable:
        """Profiled drop-in for ``fn``; the original stays reachable as
        ``wrapper.__wrapped__``."""
        prof = self.profile(name)
        cache_size = getattr(fn, "_cache_size", None)

        def wrapper(*args, **kwargs):
            sig = _signature(args, kwargs)
            before = cache_size() if callable(cache_size) else None
            t0 = self._clock()
            out = fn(*args, **kwargs)
            try:
                import jax
                jax.block_until_ready(out)
            except Exception:
                pass
            dt = self._clock() - t0
            with self._lock:
                prof.calls += 1
                prof.total_s += dt
                prof.last_s = dt
                prof.min_s = min(prof.min_s, dt)
                prof.max_s = max(prof.max_s, dt)
                compiled = False
                if before is not None:
                    after = cache_size()
                    compiled = after > before
                    # keep the signature set in sync either way
                    prof._signatures.add(sig)
                elif sig not in prof._signatures:
                    prof._signatures.add(sig)
                    compiled = True
                if compiled:
                    prof.compiles += 1
            self._calls.inc(fn=name)
            if compiled:
                self._compiles.inc(fn=name)
            self._seconds.observe(dt, fn=name)
            return out

        wrapper.__wrapped__ = fn
        # jitted callables already expose __wrapped__ (the undecorated
        # python fn), so idempotency checks use this marker instead
        wrapper._jit_profiled = True
        wrapper.__name__ = f"profiled_{name}"
        return wrapper

    # -- hot-path hookups ----------------------------------------------------
    ENGINE_MEMBERS = (
        ("_decode", "decode_step"),
        ("_prefill_fixed", "prefill_batch_ids"),
        ("_prefill_extend", "prefill_extend"),
        ("_prefill", "prefill_exact"),
    )

    def wrap_engine(self, engine) -> None:
        """Hook the serving engine's jitted members in place.  Idempotent
        per engine (re-wrapping an already-profiled member is skipped)."""
        for attr, name in self.ENGINE_MEMBERS:
            fn = getattr(engine, attr, None)
            if fn is None or getattr(fn, "_jit_profiled", False):
                continue
            setattr(engine, attr, self.wrap(name, fn))

    KERNEL_OPS = ("paged_decode_attention_op", "decode_attention_op",
                  "flash_attention_op")

    def wrap_kernel_ops(self) -> Callable[[], None]:
        """Rebind the Pallas kernel wrappers at module level; returns a
        zero-arg restore function (tests unhook in a finally)."""
        from .. import kernels
        from ..kernels import ops
        originals: List = []
        for name in self.KERNEL_OPS:
            fn = getattr(ops, name, None)
            if fn is None or getattr(fn, "_jit_profiled", False):
                continue
            wrapped = self.wrap(name, fn)
            originals.append((name, fn))
            setattr(ops, name, wrapped)
            if hasattr(kernels, name):
                setattr(kernels, name, wrapped)

        def restore() -> None:
            for name, fn in originals:
                setattr(ops, name, fn)
                if hasattr(kernels, name):
                    setattr(kernels, name, fn)

        return restore

    # -- summaries -----------------------------------------------------------
    def stats(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {name: p.as_dict()
                    for name, p in sorted(self.profiles.items())}

    def table(self) -> List[str]:
        """Aligned text table (launchers print it)."""
        rows = self.stats()
        if not rows:
            return ["  (no profiled jit calls)"]
        head = (f"  {'fn':<22}{'calls':>8}{'compiles':>10}"
                f"{'avg ms':>10}{'max ms':>10}{'total s':>10}")
        out = [head]
        for name, s in rows.items():
            out.append(f"  {name:<22}{s['calls']:>8}{s['compiles']:>10}"
                       f"{s['avg_ms']:>10.3f}{s['max_ms']:>10.3f}"
                       f"{s['total_s']:>10.3f}")
        return out
