"""Unified telemetry: deterministic metrics, exports, profiling, alerts.

One pipeline every layer feeds (ROADMAP "Observability"):

  * :mod:`repro.telemetry.metrics` — Counter/Gauge/Histogram with fixed
    log-spaced buckets, labeled series, scoped :class:`MetricsRegistry`;
    deterministic by construction (sorted iteration, injected clock);
  * :mod:`repro.telemetry.bridge` — :class:`EventMetricsBridge` folds
    any ``RunEvent`` stream (in-process or wire-replayed, identically —
    the ``fold_spans`` discipline) into series, with histogram exemplars
    carrying the span ids of the matching span tree;
  * :mod:`repro.telemetry.export` — Prometheus text and
    OTLP-metrics-shaped JSON renderings; byte-identical across replays
    of the same seeded workload under a virtual clock;
  * :mod:`repro.telemetry.profile` — :class:`JitProfiler` wraps the
    jitted hot paths (``decode_step``, ``prefill_batch_ids``, the Pallas
    kernel ops) to count compiles and record per-call wall time;
  * :mod:`repro.telemetry.alerts` — :class:`SloMonitor`: windowed
    error-budget burn rate against :class:`repro.traffic.SLOTarget`,
    emitting typed :class:`repro.core.events.SloAlertFired` events.

Telemetry is strictly opt-in: nothing here is imported by the serving /
session hot paths unless a caller attaches a bridge or profiler, and
with telemetry off the stack is bit-identical to the pre-telemetry
tree (tested).
"""
from .alerts import SloMonitor
from .bridge import EventMetricsBridge, fold_report
from .export import (export_otlp_metrics_json, parse_prometheus,
                     render_prometheus, to_otlp_metrics)
from .metrics import (DEFAULT_COUNT_BUCKETS, DEFAULT_LATENCY_BUCKETS,
                      Counter, Gauge, Histogram, MetricsRegistry,
                      log_buckets)
from .profile import JitProfiler

__all__ = [
    "Counter", "DEFAULT_COUNT_BUCKETS", "DEFAULT_LATENCY_BUCKETS",
    "EventMetricsBridge", "Gauge", "Histogram", "JitProfiler",
    "MetricsRegistry", "SloMonitor", "export_otlp_metrics_json",
    "fold_report", "log_buckets", "parse_prometheus", "render_prometheus",
    "to_otlp_metrics",
]
