"""EventMetricsBridge: fold any ``RunEvent`` stream into metric series.

The discipline mirrors :func:`repro.tenancy.tracing.fold_spans`: the
event stream is the run's complete history, so metrics are a *derived
view*, never a second instrumentation path — folding an in-process
stream and its wire round-trip (``events_from_wire(events_to_wire(...))``)
writes the identical series (tested).  **Losslessness**: every event
increments ``repro_events_total{type=...}``, so the bridge's totals
always reconcile against the raw stream length — no accounting escapes.

Exemplar linkage: tool/LLM latency observations carry
``{"run": <ordinal>, "span": <id>}`` exemplars where ``span`` reproduces
the deterministic sequence ids ``fold_spans`` assigns the SAME stream —
the bridge replays the span-id counter (which events open spans, which
are annotations) without building the tree, so a histogram exemplar
points at the exact span in the PR-8 OTLP trace export.

Usage::

    registry = MetricsRegistry(clock=timeline.now)
    bridge = EventMetricsBridge(registry)
    bridge.feed(events, deployment="faas", tenant="acme")  # whole stream
    session = Session(on_event=bridge)                     # or live
    scheduler.subscribe(bridge)                            # engine gauges
    bridge.observe_record(record)                          # traffic layer
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Optional

from ..core import events as run_events
from .metrics import (DEFAULT_COUNT_BUCKETS, DEFAULT_LATENCY_BUCKETS,
                      MetricsRegistry)


class _RunContext:
    """Per-run fold state: labels plus the replayed span-id counter."""

    __slots__ = ("deployment", "tenant", "default_tenant", "run_label",
                 "span_seq", "run_open", "stage_open")

    def __init__(self, deployment: str = "", tenant: str = "",
                 run_label: str = ""):
        self.deployment = deployment
        self.tenant = tenant
        self.default_tenant = tenant
        self.run_label = run_label
        self.span_seq = 0
        self.run_open = False
        self.stage_open = False

    def next_span(self) -> str:
        self.span_seq += 1
        return "%016x" % self.span_seq


class EventMetricsBridge:
    """Folds ``RunEvent``s into a :class:`MetricsRegistry`.

    One bridge serves three subscription styles:

      * ``feed(events, ...)`` — fold a complete (possibly wire-replayed)
        stream under explicit labels; deterministic, the exporter path;
      * ``__call__(event)`` — live observer (``Session(on_event=...)``,
        ``scheduler.subscribe``); per-thread run contexts track the
        current tenant exactly like the pre-telemetry ``RunMonitor``;
      * ``wire_observer()`` — live observer accepting raw wire dicts.

    ``observe_record`` / ``observe_result`` / ``observe_caches`` fold the
    layers the stream cannot see: client-side queue wait and latency
    (:class:`repro.traffic.TrafficRecord`), Eq. 2 FaaS spend and success
    (``RunResult``), and run/plan-cache hit rates (their ``stats()``
    dicts — run-cache hits emit no events at all, by design).
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._lock = threading.Lock()
        self._runs_seen = 0
        self._tls = threading.local()
        r = self.registry
        # -- families (created eagerly: export shape is stable) -------------
        self.events = r.counter(
            "repro_events_total", "Run events folded, by type")
        self.runs_started = r.counter(
            "repro_runs_started_total", "Runs started")
        self.runs_completed = r.counter(
            "repro_runs_completed_total",
            "Runs completed, by pattern-level outcome")
        self.runs_in_flight = r.gauge(
            "repro_runs_in_flight", "Started-but-not-completed runs")
        self.llm_calls = r.counter(
            "repro_llm_calls_total", "LLM completions, by agent")
        self.llm_tokens = r.counter(
            "repro_llm_tokens_total", "LLM tokens, by direction")
        self.llm_cost = r.counter(
            "repro_llm_cost_usd_total", "Eq. 1 LLM spend", unit="USD")
        self.llm_latency = r.histogram(
            "repro_llm_latency_seconds", "LLM completion latency",
            unit="s", buckets=DEFAULT_LATENCY_BUCKETS)
        self.tool_calls = r.counter(
            "repro_tool_calls_total",
            "Tool invocations, by server/tool/deployment/outcome")
        self.tool_latency = r.histogram(
            "repro_tool_latency_seconds",
            "Tool-call latency, by server/tool/deployment", unit="s",
            buckets=DEFAULT_LATENCY_BUCKETS)
        self.tool_retries = r.counter(
            "repro_tool_retries_total", "Failed retryable tool attempts")
        self.hedges = r.counter(
            "repro_hedges_total", "Hedged tool calls, by winner")
        self.hedge_saved = r.counter(
            "repro_hedge_saved_seconds_total",
            "Virtual latency shaved off by hedging", unit="s")
        self.overhead = r.counter(
            "repro_framework_overhead_total", "Framework overhead events")
        self.overhead_s = r.counter(
            "repro_framework_overhead_seconds_total",
            "Framework overhead latency", unit="s")
        self.stages = r.counter(
            "repro_stages_total", "Stage completions, by outcome")
        # plan-compiler lifecycle
        self.plan_events = r.counter(
            "repro_plan_cache_events_total",
            "Plan-cache lifecycle events (miss/compiled/fallback/replay)")
        # tenancy
        self.tenant_runs = r.counter(
            "repro_tenant_runs_total", "Runs per tenant")
        self.tenant_completed = r.counter(
            "repro_tenant_completed_total", "Completed runs per tenant")
        self.tenant_llm_calls = r.counter(
            "repro_tenant_llm_calls_total", "LLM calls per tenant")
        self.tenant_tokens = r.counter(
            "repro_tenant_tokens_total", "LLM tokens per tenant")
        self.tenant_spend = r.counter(
            "repro_tenant_spend_usd_total",
            "Per-tenant spend (eq=1: LLM tokens, eq=2: FaaS)", unit="USD")
        self.tenant_degraded = r.counter(
            "repro_tenant_degraded_total", "Soft-budget degradations")
        self.tenant_rejected = r.counter(
            "repro_tenant_rejected_total", "Hard-budget rejections")
        # serving engine (EngineStepped stream)
        self.engine_steps = r.counter(
            "repro_engine_steps_total", "Scheduler decode steps")
        self.engine_decode_tokens = r.counter(
            "repro_engine_decode_tokens_total", "Tokens decoded")
        self.engine_prefill_tokens = r.counter(
            "repro_engine_prefill_tokens_total",
            "Prompt tokens prefilled at admission")
        self.engine_preemptions = r.counter(
            "repro_engine_preemptions_total", "Slot preemptions")
        self.engine_prefix_hits = r.counter(
            "repro_engine_prefix_hits_total",
            "Admissions served from the prefix cache")
        self.engine_live = r.gauge(
            "repro_engine_live", "Decode-batch occupancy (last step)")
        self.engine_queued = r.gauge(
            "repro_engine_queue_depth", "Waiting requests (last step)")
        self.engine_peak_live = r.gauge(
            "repro_engine_peak_live", "Peak decode-batch occupancy")
        self.engine_occupancy = r.histogram(
            "repro_engine_occupancy", "Decode-batch occupancy per step",
            buckets=DEFAULT_COUNT_BUCKETS)
        self.engine_blocks = r.gauge(
            "repro_engine_blocks_in_use",
            "Paged-KV blocks allocated (last step)")
        # SLO alerts (SloMonitor writes, the bridge folds replayed ones)
        self.slo_alerts = r.counter(
            "repro_slo_alerts_total", "SLO burn-rate alerts, by objective")
        # traffic layer (observe_record)
        self.run_latency = r.histogram(
            "repro_run_latency_seconds",
            "Client-side run latency (queueing included), by scenario",
            unit="s", buckets=DEFAULT_LATENCY_BUCKETS)
        self.queue_wait = r.histogram(
            "repro_queue_wait_seconds",
            "Arrival-to-start queue wait, by scenario", unit="s",
            buckets=DEFAULT_LATENCY_BUCKETS)
        self.ttft = r.histogram(
            "repro_ttft_seconds", "Time to first LLM completion",
            unit="s", buckets=DEFAULT_LATENCY_BUCKETS)
        self.run_crashes = r.counter(
            "repro_run_crashes_total", "Injected platform deaths absorbed")
        self.run_resumes = r.counter(
            "repro_run_resumes_total", "Journal-served restarts")
        self.faas_cost = r.counter(
            "repro_faas_cost_usd_total", "Eq. 2 FaaS spend", unit="USD")
        self.runs_succeeded = r.counter(
            "repro_runs_succeeded_total",
            "Runs whose final RunResult.success is True")
        # caches (observe_caches — hits emit no events)
        self.cache_gauge = r.gauge(
            "repro_cache_hit_rate", "Cache hit rate, by cache")
        self.cache_lookups = r.counter(
            "repro_cache_lookups_total", "Cache lookups, by cache/outcome")

    # -- context plumbing ----------------------------------------------------
    def _context(self) -> _RunContext:
        ctx = getattr(self._tls, "ctx", None)
        if ctx is None:
            ctx = self._tls.ctx = _RunContext()
        return ctx

    def _new_run(self, ctx: _RunContext) -> None:
        with self._lock:
            self._runs_seen += 1
            ctx.run_label = str(self._runs_seen)
        ctx.span_seq = 0
        ctx.run_open = False
        ctx.stage_open = False

    # -- entry points --------------------------------------------------------
    def __call__(self, event) -> None:
        self._fold(event, self._context())

    def wire_observer(self):
        """Observer accepting wire-serialized event dicts — the same
        dicts ``fold_spans`` sees after ``events_from_wire``."""
        def observe(wire_dict) -> None:
            self(run_events.from_wire(wire_dict))
        return observe

    def feed(self, events: Iterable, deployment: str = "",
             tenant: str = "", run_label: str = "") -> None:
        """Fold a complete stream under explicit labels.  ``run_label``
        overrides the automatic run ordinal (the traffic layer passes
        the record index so exemplars match the record table)."""
        ctx = _RunContext(deployment=deployment, tenant=tenant)
        if run_label:
            ctx.run_label = run_label
        else:
            self._new_run(ctx)
        for ev in events:
            if isinstance(ev, dict):
                ev = run_events.from_wire(ev)
            self._fold(ev, ctx)

    # -- the fold ------------------------------------------------------------
    def _fold(self, ev, ctx: _RunContext) -> None:
        e = run_events
        self.events.inc(type=type(ev).__name__)
        if isinstance(ev, e.RunStarted):
            self._new_run(ctx)
            ctx.next_span()                          # the run span
            ctx.run_open = True
            # the event's tenant wins; an explicit feed() tenant backs
            # it up when the run was billed without a tenancy config
            ctx.tenant = ev.tenant or ctx.default_tenant
            self.runs_started.inc(pattern=ev.pattern,
                                  deployment=ctx.deployment)
            self.runs_in_flight.add(1)
            self.tenant_runs.inc(tenant=ev.tenant)
            if ev.pattern == "agentx-compiled":
                self.plan_events.inc(event="replay")
        elif isinstance(ev, e.RunCompleted):
            # tenant attribution only inside an open run (the historical
            # RunMonitor tracked the billing context thread-locally
            # between RunStarted and RunCompleted)
            if ctx.run_open:
                self.tenant_completed.inc(tenant=ctx.tenant)
            ctx.run_open = False
            ctx.stage_open = False
            ctx.tenant = ctx.default_tenant
            self.runs_completed.inc(
                completed="true" if ev.completed else "false")
            self.runs_in_flight.add(-1)
        elif isinstance(ev, e.StageStarted):
            ctx.next_span()
            ctx.stage_open = True
        elif isinstance(ev, e.StageCompleted):
            ctx.stage_open = False
            self.stages.inc(success="true" if ev.success else "false")
        elif isinstance(ev, e.LLMCompleted):
            span = ctx.next_span()
            le = ev.event
            self.llm_calls.inc(agent=le.agent)
            self.llm_tokens.inc(le.input_tokens, direction="input")
            self.llm_tokens.inc(le.output_tokens, direction="output")
            self.llm_cost.inc(le.cost)
            self.llm_latency.observe(
                le.latency, agent=le.agent, t=ev.t,
                exemplar={"run": ctx.run_label, "span": span})
            if ctx.run_open:    # billing context, RunMonitor discipline
                self.tenant_llm_calls.inc(tenant=ctx.tenant)
                self.tenant_tokens.inc(le.input_tokens + le.output_tokens,
                                       tenant=ctx.tenant)
                self.tenant_spend.inc(le.cost, tenant=ctx.tenant, eq="1")
        elif isinstance(ev, e.ToolInvoked):
            span = ctx.next_span()
            te = ev.event
            self.tool_calls.inc(server=te.server, tool=te.tool,
                                deployment=ctx.deployment,
                                ok="true" if te.ok else "false")
            self.tool_latency.observe(
                te.latency, server=te.server, tool=te.tool,
                deployment=ctx.deployment, t=ev.t,
                exemplar={"run": ctx.run_label, "span": span})
        elif isinstance(ev, e.ToolRetried):
            ctx.next_span()
            self.tool_retries.inc(server=ev.server, tool=ev.tool)
        elif isinstance(ev, e.RunHedged):
            ctx.next_span()
            self.hedges.inc(server=ev.server, tool=ev.tool,
                            winner=ev.winner)
            self.hedge_saved.inc(ev.saved_s, server=ev.server,
                                 tool=ev.tool)
        elif isinstance(ev, e.OverheadIncurred):
            self._annotation_span(ctx)
            self.overhead.inc(what=ev.event.what)
            self.overhead_s.inc(ev.event.latency, what=ev.event.what)
        elif isinstance(ev, e.PlanCacheMiss):
            self._annotation_span(ctx)
            self.plan_events.inc(event="miss")
        elif isinstance(ev, e.PlanCompiled):
            self._annotation_span(ctx)
            self.plan_events.inc(event="compiled")
        elif isinstance(ev, e.PlanFallback):
            self._annotation_span(ctx)
            self.plan_events.inc(event="fallback")
        elif isinstance(ev, e.RunDegraded):
            if not ctx.run_open:
                ctx.next_span()
            self.tenant_degraded.inc(tenant=ev.tenant)
        elif isinstance(ev, e.BudgetExceeded):
            if not ctx.run_open:
                ctx.next_span()
            self.tenant_rejected.inc(tenant=ev.tenant, kind=ev.kind)
        elif isinstance(ev, e.EngineStepped):
            self.engine_steps.inc()
            self.engine_decode_tokens.inc(ev.generated)
            self.engine_prefill_tokens.inc(ev.prefilled)
            self.engine_preemptions.inc(ev.preempted)
            self.engine_prefix_hits.inc(ev.prefix_hits)
            self.engine_live.set(ev.live)
            self.engine_queued.set(ev.queued)
            self.engine_peak_live.max_of(ev.live)
            self.engine_occupancy.observe(float(ev.live))
            self.engine_blocks.set(ev.blocks_in_use)
        elif isinstance(ev, e.SloAlertFired):
            self._annotation_span(ctx)
            self.slo_alerts.inc(slo=ev.slo)
        else:
            # losslessness: unknown/annotation events (PlanProduced,
            # ReflectionEmitted, future types) still counted above in
            # events_total; mirror fold_spans' span-id bookkeeping
            self._annotation_span(ctx)

    def _annotation_span(self, ctx: _RunContext) -> None:
        """fold_spans turns a non-span event into a zero-width root span
        (consuming an id) only when NO container is open; replicate so
        exemplar span ids keep matching the tree."""
        if not ctx.run_open and not ctx.stage_open:
            ctx.next_span()

    # -- layers the stream cannot see ---------------------------------------
    def observe_result(self, result, tenant: str = "") -> None:
        """Fold one finished ``RunResult``: artifact-level success and
        the Eq. 2 FaaS spend (events carry only Eq. 1)."""
        if result.success:
            self.runs_succeeded.inc()
        if result.faas_cost:
            self.faas_cost.inc(result.faas_cost,
                               deployment=result.deployment)
            self.tenant_spend.inc(result.faas_cost, tenant=tenant, eq="2")

    def observe_record(self, record) -> None:
        """Fold one :class:`repro.traffic.TrafficRecord`: client-side
        latency/queue-wait/TTFT plus durability counters, and the
        record's result via :meth:`observe_result`."""
        scenario = record.scenario
        label = str(record.index)
        self.run_latency.observe(record.latency, scenario=scenario,
                                 t=record.end, exemplar={"run": label})
        self.queue_wait.observe(record.queue_wait, scenario=scenario,
                                t=record.start, exemplar={"run": label})
        if record.ttft is not None:
            self.ttft.observe(record.ttft, scenario=scenario,
                              t=record.start)
        if record.crashes:
            self.run_crashes.inc(record.crashes, scenario=scenario)
        if record.resumes:
            self.run_resumes.inc(record.resumes, scenario=scenario)
        self.observe_result(record.result,
                            tenant=getattr(record.spec, "tenant", ""))

    def observe_caches(self, run_cache: Optional[dict] = None,
                       plan_cache: Optional[dict] = None) -> None:
        """Fold cache ``stats()`` dicts — run-cache hits return stored
        results without emitting a single event, so hit rates can only
        come from the caches themselves."""
        for name, stats in (("run", run_cache), ("plan", plan_cache)):
            if not stats:
                continue
            hits = float(stats.get("hits", 0))
            misses = float(stats.get("misses", 0))
            self.cache_lookups.inc(hits, cache=name, outcome="hit")
            self.cache_lookups.inc(misses, cache=name, outcome="miss")
            lookups = hits + misses
            self.cache_gauge.set(hits / lookups if lookups else 0.0,
                                 cache=name)
            if "fallbacks" in stats:
                self.cache_lookups.inc(float(stats["fallbacks"]),
                                       cache=name, outcome="fallback")


def fold_report(bridge: EventMetricsBridge, report,
                run_cache: Optional[dict] = None,
                plan_cache: Optional[dict] = None) -> None:
    """Fold a whole :class:`repro.traffic.TrafficReport` in record-index
    order (deterministic regardless of completion interleaving): each
    record's event stream under its spec's deployment/tenant labels,
    then the record itself, then the cache stats."""
    for rec in sorted(report.records, key=lambda r: r.index):
        bridge.feed(rec.result.extras.get("events", ()),
                    deployment=getattr(rec.spec, "deployment", ""),
                    tenant=getattr(rec.spec, "tenant", ""),
                    run_label=str(rec.index))
        bridge.observe_record(rec)
    plan = plan_cache if plan_cache is not None else report.plan_cache
    bridge.observe_caches(run_cache=run_cache, plan_cache=plan)
