"""Accuracy judge (paper §5.4.1).

The paper scores final outputs with an LLM judge over weighted attributes.
Offline we implement the judge as deterministic measurements of the same
attributes against the simulated world's ground truth:

  summaries (web search / research report):
    Accuracy(50)  — fraction of summary content traceable to the corpus
                    (hallucination check)
    Relevance(30) — topic-term alignment with the user query
    Depth(10)     — content length / structure beyond surface level
    Breadth(10)   — number of distinct sources/sections covered

  stock correlation:
    Data Accuracy(50)   — plotted series match the true market series
    Query Adherence(30) — requested tickers present, correct filename, saved
    Plot Quality(10)    — title/labels/legend/grid present
    Data Quantity(10)   — enough points for a meaningful plot
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

from ..env.world import World

SUMMARY_WEIGHTS = {"Accuracy": 50, "Relevance": 30, "Depth": 10, "Breadth": 10}
STOCK_WEIGHTS = {"Data Accuracy": 50, "Query Adherence": 30,
                 "Plot Quality": 10, "Data Quantity": 10}


@dataclasses.dataclass
class Score:
    attributes: Dict[str, float]    # each 0..100
    weights: Dict[str, int]

    @property
    def total(self) -> float:
        w = sum(self.weights.values())
        return sum(self.attributes[k] * self.weights[k] for k in self.weights) / w


def _ngram_overlap(text: str, sources: List[str], n: int = 5) -> float:
    """Fraction of text n-grams present in any source (anti-hallucination)."""
    words = re.findall(r"[a-z]+", text.lower())
    if len(words) < n:
        return 0.0
    grams = {" ".join(words[i:i + n]) for i in range(len(words) - n + 1)}
    src = " ".join(s.lower() for s in sources)
    hit = sum(1 for g in grams if g in src)
    return hit / max(len(grams), 1)


def judge_summary(world: World, query: str, summary: Optional[str],
                  kind: str) -> Score:
    if not summary:
        return Score({k: 0.0 for k in SUMMARY_WEIGHTS}, SUMMARY_WEIGHTS)
    if kind == "web_search":
        topic = world.web.topic_of(query)
        sources = [p.content for u in world.web.by_topic[topic]
                   for p in [world.web.pages[u]]]
    else:
        sources = [p.full_text() for p in world.arxiv.papers.values()]
    acc = min(100.0, 35 + 80 * _ngram_overlap(summary, sources))
    qwords = [w for w in re.findall(r"[a-zA-Z]+", query.lower()) if len(w) > 4]
    rel = 100.0 * (sum(1 for w in qwords if w in summary.lower())
                   / max(len(qwords), 1))
    rel = min(100.0, 40 + 0.7 * rel) if summary else 0.0
    depth = min(100.0, len(summary) / 18)
    sections = summary.count("##")
    breadth = min(100.0, 40 + 15 * max(sections, summary.count("http"),
                                       summary.count(":") // 2))
    return Score({"Accuracy": acc, "Relevance": rel, "Depth": depth,
                  "Breadth": breadth}, SUMMARY_WEIGHTS)


def judge_stock(world: World, companies: List[str], filename: str,
                artifact_path: Optional[str],
                artifact: Optional[str]) -> Score:
    attrs = {k: 0.0 for k in STOCK_WEIGHTS}
    if not artifact or not artifact.startswith("PNG"):
        return Score(attrs, STOCK_WEIGHTS)
    try:
        state = json.loads(artifact[4:])
    except ValueError:
        return Score(attrs, STOCK_WEIGHTS)
    series = state.get("series", [])
    truth = {world.stocks.resolve(c): world.stocks.series[world.stocks.resolve(c)]
             for c in companies}
    # Data Accuracy: plotted values must be a suffix/subset of true closes
    per = []
    for s in series:
        vals = s.get("y", [])
        best = 0.0
        for tic, tr in truth.items():
            trset = {round(v, 2) for v in tr}
            if vals:
                frac = sum(1 for v in vals if round(v, 2) in trset) / len(vals)
                best = max(best, frac)
        per.append(best)
    attrs["Data Accuracy"] = 100.0 * (sum(per) / len(per)) if per else 0.0
    # Query Adherence
    adher = 0.0
    if len(series) >= len(companies):
        adher += 50.0
    if artifact_path and filename in artifact_path:
        adher += 50.0
    attrs["Query Adherence"] = adher
    # Plot Quality
    q = 0.0
    q += 30.0 if state.get("title") else 0.0
    q += 30.0 if state.get("legend") else 0.0
    q += 20.0 if state.get("xlabel") or state.get("ylabel") else 0.0
    q += 20.0 if state.get("grid") else 0.0
    attrs["Plot Quality"] = q
    # Data Quantity
    npts = min((s.get("n", 0) for s in series), default=0)
    attrs["Data Quantity"] = min(100.0, 100.0 * npts / 200.0)
    return Score(attrs, STOCK_WEIGHTS)
