"""Storage substrates: local workspace / Lambda ephemeral /tmp / S3.

All are in-memory KV stores with different lifecycles:
  - LocalWorkspace: lives for a whole application run (the paper's local
    filesystem).
  - EphemeralTmp: per FaaS *container instance*; wiped on container
    recycle — the reason the paper needs S3 + DynamoDB sessions.
  - S3Store: global object store addressed by s3:// URIs.
"""
from __future__ import annotations

from typing import Dict, List, Optional


class KVStore:
    def __init__(self, capacity_mb: Optional[int] = None):
        self._data: Dict[str, str] = {}
        self.capacity_mb = capacity_mb

    def write(self, path: str, content: str) -> None:
        if self.capacity_mb is not None:
            used = sum(len(v) for v in self._data.values()) + len(content)
            if used > self.capacity_mb * 1024 * 1024:
                raise IOError(f"storage full ({self.capacity_mb} MB)")
        self._data[path] = content

    def read(self, path: str) -> str:
        if path not in self._data:
            raise FileNotFoundError(path)
        return self._data[path]

    def exists(self, path: str) -> bool:
        return path in self._data

    def list(self, prefix: str = "") -> List[str]:
        return sorted(p for p in self._data if p.startswith(prefix))

    def delete(self, path: str) -> None:
        self._data.pop(path, None)

    def clear(self) -> None:
        self._data.clear()


class LocalWorkspace(KVStore):
    pass


class EphemeralTmp(KVStore):
    def __init__(self, capacity_mb: int = 512):
        super().__init__(capacity_mb)


class S3Store(KVStore):
    """Addressed by s3://bucket/key URIs."""

    @staticmethod
    def parse_uri(uri: str):
        if not uri.startswith("s3://"):
            raise ValueError(f"not an s3 uri: {uri!r}")
        rest = uri[5:]
        bucket, _, key = rest.partition("/")
        return bucket, key

    def put_object(self, uri: str, content: str):
        self.parse_uri(uri)
        self.write(uri, content)

    def get_object(self, uri: str) -> str:
        self.parse_uri(uri)
        return self.read(uri)

    def list_objects(self, prefix: str) -> List[str]:
        return self.list(prefix)


class DynamoTable:
    """DynamoDB-like session table (paper §4.2 statefulness)."""

    def __init__(self):
        self._items: Dict[str, Dict] = {}

    def put(self, key: str, item: Dict):
        self._items[key] = dict(item)

    def get(self, key: str) -> Optional[Dict]:
        item = self._items.get(key)
        return dict(item) if item is not None else None

    def delete(self, key: str):
        self._items.pop(key, None)

    def count(self) -> int:
        return len(self._items)
