"""Deployment backends: how MCP servers are hosted for a run.

The paper's central empirical axis (Fig. 2) is *where* tools live: on the
workstation (2a), in one monolithic Lambda (2b) or one Lambda per server
(2c).  Each architecture is a :class:`DeploymentBackend` registered under
a name with :func:`register_deployment` — ``RunSpec.deployment`` resolves
through this registry exactly like ``RunSpec.pattern`` resolves through
the pattern registry, and ``Session.execute`` never branches on a
deployment name:

    @register_deployment("faas", tags=("paper",))
    class FaaSDeployment(DeploymentBackend):
        default_capabilities = DeploymentCapabilities(remote=True, ...)

        def provision(self, world, server_names) -> Provisioned: ...

Lifecycle: ``provision(world, server_names)`` builds the MCP clients plus
the artifact stores and returns a :class:`Provisioned` bundle;
``teardown()`` closes the clients; ``cost()`` reports platform spend.
A :class:`DeploymentCapabilities` descriptor states what the backend does
(tool subsetting, description hints, artifact store, cost accounting) —
consumed by ``Session`` for prompt shaping and by the run cache
(``repro.apps.cache``) for fingerprinting.

Built-in backends: ``local`` (Fig. 2a), ``faas`` (distributed, Fig. 2c),
``faas-mono`` (monolithic, Fig. 2b — beyond-paper benchmark), and ``a2a``
(remote delegation: every MCP server hosted behind an A2A agent, §2.3).
The historical ``deploy_local`` / ``deploy_distributed`` /
``deploy_monolithic`` functions remain as the underlying implementations.

``deploy_run_service`` additionally ships a whole *orchestrator* into a
Lambda: a run-service function executes full RunSpecs remotely and
wire-streams the run's event stream back on the response envelope.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core.runtime import stable_fingerprint
from ..env.world import World
from ..mcp.a2a import A2AClient, A2AServer, AgentCard, AgentSkill
from ..mcp.client import A2ATransport, FaaSTransport, LocalTransport, McpClient
from ..mcp.protocol import McpRequest, McpResponse, RequestIdGenerator
from ..mcp.server import MCPServer, ToolContext
from ..mcp.servers.arxiv import ArxivServer
from ..mcp.servers.code_execution import CodeExecutionServer
from ..mcp.servers.fetch import FetchServer
from ..mcp.servers.filesystem import FileSystemServer, S3Server
from ..mcp.servers.rag import RagServer
from ..mcp.servers.serper import SerperServer
from ..mcp.servers.yfinance import YFinanceServer
from ..faas.platform import FaaSPlatform, LambdaFunction
from ..faas.storage import LocalWorkspace, S3Store

SERVER_FACTORIES: Dict[str, Callable[[], MCPServer]] = {
    "code-execution": CodeExecutionServer,
    "rag": RagServer,
    "yfinance": YFinanceServer,
    "serper": SerperServer,
    "arxiv": ArxivServer,
    "fetch": FetchServer,
    "filesystem": FileSystemServer,
    "s3": S3Server,
}

# FaaS hosts only the app-relevant tool subset (§5.2): multi-threaded or
# fs-dependent tools are dropped.
FAAS_TOOL_SUBSET: Dict[str, List[str]] = {
    "code-execution": ["execute_python", "list_packages"],
    "rag": ["document_retriever"],
    "yfinance": ["get_stock_history", "get_quote"],
    "serper": ["google_search"],
    "arxiv": ["search_arxiv", "download_article", "get_details",
              "get_article_url"],
    "fetch": ["fetch"],
    "s3": ["s3_write", "s3_read", "s3_list"],
}

# local-deployment tool-description hints (§5.2) — NOT applied on FaaS,
# which is what breaks fetch usage there (§5.4.2).
LOCAL_HINTS: List[Tuple[str, str, str]] = [
    ("fetch", "fetch", "Use this tool after using the Google Search tool, "
     "when you need more detailed information from a specific web page."),
    ("arxiv", "load_article_to_context",
     "This tool should never be used to load research papers since they "
     "are too long."),
]


def make_servers(names: List[str]) -> Dict[str, MCPServer]:
    return {n: SERVER_FACTORIES[n]() for n in names}


def _remote_server_names(server_names: List[str]) -> List[str]:
    """filesystem is not deployable off-workstation (§4.1): swap for s3,
    dedupe, preserve order."""
    names = ["s3" if n == "filesystem" else n for n in server_names]
    return list(dict.fromkeys(names))


def _make_remote_server(name: str) -> MCPServer:
    server = SERVER_FACTORIES[name]()
    if name in FAAS_TOOL_SUBSET:
        server.drop_tools(FAAS_TOOL_SUBSET[name])
    return server


# ---------------------------------------------------------------------------
# deployment functions (the underlying implementations)


def deploy_local(world: World, server_names: List[str]
                 ) -> Tuple[Dict[str, McpClient], LocalWorkspace]:
    """Paper Fig. 2a: servers in-process on the workstation."""
    workspace = LocalWorkspace()
    clients = {}
    for name in server_names:
        server = SERVER_FACTORIES[name]()
        for srv, tool, hint in LOCAL_HINTS:
            if srv == name and tool in server.tools:
                server.amend_description(tool, hint)
        client = McpClient(LocalTransport(server, world, workspace), name)
        client.initialize()
        clients[name] = client
    return clients, workspace


def deploy_distributed(world: World, platform: FaaSPlatform,
                       server_names: List[str]) -> Dict[str, McpClient]:
    """Paper Fig. 2c: one containerized Lambda per MCP server."""
    clients = {}
    for name in _remote_server_names(server_names):
        def factory(n=name):
            return _make_remote_server(n)
        proto = SERVER_FACTORIES[name]()
        fn = platform.deploy(f"mcp-{name}", factory,
                             memory_mb=max(proto.memory_mb, 128),
                             image_mb=2048)
        client = McpClient(FaaSTransport(platform, fn.url), name)
        client.initialize()
        clients[name] = client
    return clients


def deploy_monolithic(world: World, platform: FaaSPlatform,
                      server_names: List[str]) -> Dict[str, McpClient]:
    """Paper Fig. 2b: all MCP servers in ONE Lambda function.

    Memory = sum of per-server requirements (the paper's predicted higher
    cost per call); a single cold start covers every server.
    """
    names = _remote_server_names(server_names)

    def factory():
        return {n: _make_remote_server(n) for n in names}

    mem = sum(max(SERVER_FACTORIES[n]().memory_mb, 128) for n in names)
    fn = platform.deploy("mcp-monolith", factory, memory_mb=mem,
                         image_mb=min(len(names) * 1536, 10 * 1024))
    clients = {}
    for n in names:
        client = McpClient(FaaSTransport(platform, fn.url, server_name=n), n)
        client.initialize()
        clients[n] = client
    return clients


def expose_server_as_a2a_agent(world: World, name: str, server: MCPServer,
                               s3: S3Store, url: str) -> A2AServer:
    """Host one MCP server behind an A2A agent: JSON-RPC request in the
    task message, JSON-RPC response envelope in the task artifact."""
    workspace = LocalWorkspace()   # the remote agent's private filesystem
    skill = AgentSkill(
        id="mcp", name=f"{name} MCP",
        description=f"Executes MCP JSON-RPC requests against the hosted "
                    f"{name} server.")
    card = AgentCard(
        name=f"mcp-{name}-agent",
        description=f"A2A-hosted MCP server: {name}", url=url,
        skills=[skill])

    def handler(message: str) -> Dict:
        req = McpRequest.from_json(message)
        ctx = ToolContext(world=world, workspace=workspace, s3=s3, faas=True)
        resp = server.handle(req, ctx)
        return {"text": resp.to_json(), "success": resp.ok}

    return A2AServer(card, world, {"mcp": handler})


def deploy_a2a(world: World, server_names: List[str],
               on_event: Optional[Callable] = None
               ) -> Tuple[Dict[str, McpClient], S3Store]:
    """A2A remote delegation (§2.3): each MCP server hosted behind its own
    remote agent, reached via ``A2ATransport``. Artifacts land in a shared
    object store (remote agents have no common filesystem)."""
    s3 = S3Store()
    a2a_client = A2AClient(world, on_event=on_event)
    clients = {}
    for name in _remote_server_names(server_names):
        server = _make_remote_server(name)
        agent = expose_server_as_a2a_agent(
            world, name, server, s3, url=f"https://agents.local/mcp-{name}")
        a2a_client.discover(agent)
        # event replay happens once, at the A2AClient (it sees every task)
        client = McpClient(A2ATransport(a2a_client, agent.card.name, "mcp"),
                           name)
        client.initialize()
        clients[name] = client
    return clients, s3


# ---------------------------------------------------------------------------
# the deployment backend API


@dataclasses.dataclass(frozen=True)
class DeploymentCapabilities:
    """What a deployment backend does to the tool surface — consumed by
    ``Session`` (prompt shaping) and the run cache (fingerprinting)."""
    name: str = ""
    remote: bool = False           # tools live off-workstation
    tool_subset: bool = False      # FAAS_TOOL_SUBSET applied
    description_hints: bool = False   # LOCAL_HINTS applied
    artifact_store: str = "workspace"  # "workspace" | "s3"
    cost_accounting: bool = False  # per-invocation platform billing
    world_alias: str = ""          # seed the World as if deployed under
    #   this name ("" = own name).  Wrapper backends (fault injection,
    #   repro.traffic.faults) alias to the wrapped deployment so
    #   injecting faults never reshuffles the simulated environment —
    #   the invariant the recover-to-baseline contract rests on.
    tags: tuple = ()
    rank: int = 50                 # listing order

    def fingerprint(self) -> str:
        return stable_fingerprint(self)


@dataclasses.dataclass
class Provisioned:
    """What ``provision`` hands the orchestrator: per-server MCP clients
    plus the stores an artifact can land in."""
    clients: Dict[str, McpClient]
    workspace: Optional[LocalWorkspace] = None
    s3: Optional[S3Store] = None
    platform: Optional[FaaSPlatform] = None


class DeploymentBackend:
    """Base class: lifecycle ``provision`` -> run -> ``teardown`` +
    ``cost``, described by a :class:`DeploymentCapabilities`."""

    name = "base"
    default_capabilities = DeploymentCapabilities()

    def __init__(self, capabilities: Optional[DeploymentCapabilities] = None):
        self.capabilities = (capabilities if capabilities is not None
                             else type(self).default_capabilities)
        self.env: Optional[Provisioned] = None

    def provision(self, world: World,
                  server_names: List[str]) -> Provisioned:
        raise NotImplementedError

    def teardown(self) -> None:
        if self.env is not None:
            for client in self.env.clients.values():
                client.close()

    def cost(self) -> float:
        if self.env is not None and self.env.platform is not None:
            return self.env.platform.total_cost()
        return 0.0

    # -- crash injection hooks (overridden by fault-plan wrappers,
    #    repro.traffic.faults; no-ops for real deployments) -------------
    def crash_point(self, world: World, attempt: int = 0) -> Optional[int]:
        """Event index at which the platform kills this run mid-flight,
        or ``None`` for no crash.  ``attempt`` is the durable-execution
        restart counter (0 = first execution, k = k-th resume/rerun) —
        keying the draw on it keeps each restart's fate an independent
        sample instead of deterministically re-crashing forever."""
        return None

    def record_crash(self) -> None:
        """Count one fired crash (telemetry; see ``FaultStats``)."""


@dataclasses.dataclass(frozen=True)
class RegisteredDeployment:
    name: str
    backend_cls: type
    capabilities: DeploymentCapabilities


_DEPLOYMENTS: Dict[str, RegisteredDeployment] = {}
_DEPLOYMENTS_LOCK = threading.Lock()


def register_deployment(name: str, *, tags: tuple = (), **overrides):
    """Class decorator registering a backend class under ``name`` with
    :class:`DeploymentCapabilities` overrides. Stack for variants."""
    def deco(cls):
        caps = dataclasses.replace(cls.default_capabilities, name=name,
                                   tags=tuple(tags), **overrides)
        with _DEPLOYMENTS_LOCK:
            _DEPLOYMENTS[name] = RegisteredDeployment(name, cls, caps)
        return cls
    return deco


def unregister_deployment(name: str) -> bool:
    """Drop a registered deployment (tests; transient fault-injection
    twins from :mod:`repro.traffic.faults`).  Returns whether it was
    registered.  Built-ins re-register only on module import, so don't
    unregister those outside a snapshot/restore."""
    with _DEPLOYMENTS_LOCK:
        return _DEPLOYMENTS.pop(name, None) is not None


def resolve_deployment(name: str) -> RegisteredDeployment:
    try:
        return _DEPLOYMENTS[name]
    except KeyError:
        raise KeyError(f"unknown deployment {name!r}; registered: "
                       f"{sorted(_DEPLOYMENTS)}") from None


def deployment_names(tag: Optional[str] = None) -> List[str]:
    named = [(rd.capabilities.rank, n) for n, rd in _DEPLOYMENTS.items()
             if tag is None or tag in rd.capabilities.tags]
    return [n for _, n in sorted(named)]


def create_deployment(name: str) -> DeploymentBackend:
    rd = resolve_deployment(name)
    return rd.backend_cls(capabilities=rd.capabilities)


# ---------------------------------------------------------------------------
# built-in backends


@register_deployment("local", tags=("paper",), rank=10)
class LocalDeployment(DeploymentBackend):
    """Paper Fig. 2a: servers in-process on the workstation."""

    name = "local"
    default_capabilities = DeploymentCapabilities(
        description_hints=True, artifact_store="workspace")

    def provision(self, world: World,
                  server_names: List[str]) -> Provisioned:
        clients, workspace = deploy_local(world, server_names)
        self.env = Provisioned(clients, workspace=workspace)
        return self.env


class _FaaSBackendBase(DeploymentBackend):
    """Shared FaaS provisioning: build the platform, deploy, then zero the
    accounting/clock so deployment cold starts are not billed to the run."""

    default_capabilities = DeploymentCapabilities(
        remote=True, tool_subset=True, artifact_store="s3",
        cost_accounting=True)

    def _deploy(self, world: World, platform: FaaSPlatform,
                server_names: List[str]) -> Dict[str, McpClient]:
        raise NotImplementedError

    def provision(self, world: World,
                  server_names: List[str]) -> Provisioned:
        platform = FaaSPlatform(world)
        clients = self._deploy(world, platform, server_names)
        platform.reset_accounting()   # deployment cold-starts not billed
        world.clock.reset()
        self.env = Provisioned(clients, s3=platform.s3, platform=platform)
        return self.env


@register_deployment("faas", tags=("paper",), rank=20)
class FaaSDeployment(_FaaSBackendBase):
    """Paper Fig. 2c: one containerized Lambda per MCP server."""

    name = "faas"

    def _deploy(self, world, platform, server_names):
        return deploy_distributed(world, platform, server_names)


@register_deployment("faas-mono", rank=30)
class MonolithicFaaSDeployment(_FaaSBackendBase):
    """Paper Fig. 2b: all MCP servers in ONE Lambda function."""

    name = "faas-mono"

    def _deploy(self, world, platform, server_names):
        return deploy_monolithic(world, platform, server_names)


@register_deployment("a2a", rank=40)
class A2ADeployment(DeploymentBackend):
    """Remote delegation (§2.3): MCP servers hosted behind A2A agents."""

    name = "a2a"
    default_capabilities = DeploymentCapabilities(
        remote=True, tool_subset=True, artifact_store="s3")

    def provision(self, world: World,
                  server_names: List[str]) -> Provisioned:
        clients, s3 = deploy_a2a(world, server_names)
        world.clock.reset()   # discovery/initialize not billed to the run
        self.env = Provisioned(clients, s3=s3)
        return self.env


# ---------------------------------------------------------------------------
# remote orchestration: a whole run executed inside a Lambda


METHOD_EXECUTE_RUN = "run/execute"


class RunServiceHandler:
    """Orchestrator-in-Lambda: executes full RunSpecs and wire-streams the
    run's event stream back on the response envelope."""

    def handle(self, req: McpRequest, ctx: ToolContext) -> McpResponse:
        if req.method != METHOD_EXECUTE_RUN:
            return McpResponse(req.id, error={
                "code": -32601, "message": f"unknown method {req.method!r}"})
        # deferred: apps.session imports this module at package init
        from ..apps.session import RunSpec, Session
        from ..core.events import events_to_wire
        p = req.params
        try:
            spec = RunSpec(p["app"], p["instance"], p["pattern"],
                           p.get("deployment", "local"), p.get("seed", 0),
                           llm=p.get("llm", "oracle"))
            result = Session().execute(spec)
        except KeyError as e:   # bad params stay a JSON-RPC error envelope
            return McpResponse(req.id, error={
                "code": -32602, "message": f"invalid run spec: {e}"})
        # bill the remote run's virtual time on the caller's clock
        ctx.world.clock.sleep(result.total_latency)
        return McpResponse(req.id, result={
            "app": result.app, "instance": result.instance,
            "pattern": result.pattern, "deployment": result.deployment,
            "success": result.success,
            "total_latency": result.total_latency,
            "input_tokens": result.trace.input_tokens,
            "output_tokens": result.trace.output_tokens,
            "llm_cost": result.trace.llm_cost,
            "faas_cost": result.faas_cost,
            "artifact": result.artifact,
            "failure_reason": result.failure_reason,
        }, events=events_to_wire(result.extras["events"]))


def deploy_run_service(platform: FaaSPlatform,
                       memory_mb: int = 1024) -> LambdaFunction:
    """Deploy the orchestrator run service as a Lambda function."""
    return platform.deploy("agentx-run-service", RunServiceHandler,
                           memory_mb=memory_mb, image_mb=4096)


class RunServiceClient:
    """Local handle on a remote orchestrator: ``execute`` dispatches one
    RunSpec to the run-service Lambda; ``on_event`` observers see the
    remote run's event stream replayed through the transport."""

    def __init__(self, platform: FaaSPlatform,
                 on_event: Optional[Callable] = None):
        fn = deploy_run_service(platform)
        self.transport = FaaSTransport(platform, fn.url, on_event=on_event)
        self._ids = RequestIdGenerator()

    def execute(self, app: str, instance: str, pattern: str,
                deployment: str = "local", seed: int = 0,
                llm: str = "oracle") -> Dict[str, Any]:
        req = McpRequest(METHOD_EXECUTE_RUN,
                         {"app": app, "instance": instance,
                          "pattern": pattern, "deployment": deployment,
                          "seed": seed, "llm": llm}, id=self._ids.next())
        resp = self.transport.send(req)
        if not resp.ok:
            raise RuntimeError(f"run/execute failed: {resp.error}")
        return resp.result
