"""The two MCP-on-FaaS deployment architectures (paper Fig. 2b / 2c) plus
the local baseline (Fig. 2a).

``deploy_distributed`` — one Lambda function per MCP server (the variant the
paper evaluates). ``deploy_monolithic`` — a single function hosting all
servers, routed by a ``server`` request param (the variant the paper leaves
to future work; we implement and benchmark it as a beyond-paper extension).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from ..env.world import World
from ..mcp.client import FaaSTransport, LocalTransport, McpClient
from ..mcp.server import MCPServer
from ..mcp.servers.arxiv import ArxivServer
from ..mcp.servers.code_execution import CodeExecutionServer
from ..mcp.servers.fetch import FetchServer
from ..mcp.servers.filesystem import FileSystemServer, S3Server
from ..mcp.servers.rag import RagServer
from ..mcp.servers.serper import SerperServer
from ..mcp.servers.yfinance import YFinanceServer
from ..faas.platform import FaaSPlatform
from ..faas.storage import LocalWorkspace

SERVER_FACTORIES: Dict[str, Callable[[], MCPServer]] = {
    "code-execution": CodeExecutionServer,
    "rag": RagServer,
    "yfinance": YFinanceServer,
    "serper": SerperServer,
    "arxiv": ArxivServer,
    "fetch": FetchServer,
    "filesystem": FileSystemServer,
    "s3": S3Server,
}

# FaaS hosts only the app-relevant tool subset (§5.2): multi-threaded or
# fs-dependent tools are dropped.
FAAS_TOOL_SUBSET: Dict[str, List[str]] = {
    "code-execution": ["execute_python", "list_packages"],
    "rag": ["document_retriever"],
    "yfinance": ["get_stock_history", "get_quote"],
    "serper": ["google_search"],
    "arxiv": ["search_arxiv", "download_article", "get_details",
              "get_article_url"],
    "fetch": ["fetch"],
    "s3": ["s3_write", "s3_read", "s3_list"],
}

# local-deployment tool-description hints (§5.2) — NOT applied on FaaS,
# which is what breaks fetch usage there (§5.4.2).
LOCAL_HINTS: List[Tuple[str, str, str]] = [
    ("fetch", "fetch", "Use this tool after using the Google Search tool, "
     "when you need more detailed information from a specific web page."),
    ("arxiv", "load_article_to_context",
     "This tool should never be used to load research papers since they "
     "are too long."),
]


def make_servers(names: List[str]) -> Dict[str, MCPServer]:
    return {n: SERVER_FACTORIES[n]() for n in names}


def deploy_local(world: World, server_names: List[str]
                 ) -> Tuple[Dict[str, McpClient], LocalWorkspace]:
    """Paper Fig. 2a: servers in-process on the workstation."""
    workspace = LocalWorkspace()
    clients = {}
    for name in server_names:
        server = SERVER_FACTORIES[name]()
        for srv, tool, hint in LOCAL_HINTS:
            if srv == name and tool in server.tools:
                server.amend_description(tool, hint)
        client = McpClient(LocalTransport(server, world, workspace), name)
        client.initialize()
        clients[name] = client
    return clients, workspace


def deploy_distributed(world: World, platform: FaaSPlatform,
                       server_names: List[str]) -> Dict[str, McpClient]:
    """Paper Fig. 2c: one containerized Lambda per MCP server."""
    clients = {}
    for name in server_names:
        if name == "filesystem":       # not deployable on Lambda (§4.1)
            name = "s3"
        if name in clients:
            continue

        def factory(n=name):
            server = SERVER_FACTORIES[n]()
            if n in FAAS_TOOL_SUBSET:
                server.drop_tools(FAAS_TOOL_SUBSET[n])
            return server
        proto = SERVER_FACTORIES[name]()
        fn = platform.deploy(f"mcp-{name}", factory,
                             memory_mb=max(proto.memory_mb, 128),
                             image_mb=2048)
        client = McpClient(FaaSTransport(platform, fn.url), name)
        client.initialize()
        clients[name] = client
    return clients


def deploy_monolithic(world: World, platform: FaaSPlatform,
                      server_names: List[str]) -> Dict[str, McpClient]:
    """Paper Fig. 2b: all MCP servers in ONE Lambda function.

    Memory = sum of per-server requirements (the paper's predicted higher
    cost per call); a single cold start covers every server.
    """
    names = ["s3" if n == "filesystem" else n for n in server_names]
    names = list(dict.fromkeys(names))

    def factory():
        servers = {}
        for n in names:
            server = SERVER_FACTORIES[n]()
            if n in FAAS_TOOL_SUBSET:
                server.drop_tools(FAAS_TOOL_SUBSET[n])
            servers[n] = server
        return servers

    mem = sum(max(SERVER_FACTORIES[n]().memory_mb, 128) for n in names)
    fn = platform.deploy("mcp-monolith", factory, memory_mb=mem,
                         image_mb=min(len(names) * 1536, 10 * 1024))
    clients = {}
    for n in names:
        client = McpClient(FaaSTransport(platform, fn.url, server_name=n), n)
        client.initialize()
        clients[n] = client
    return clients
