"""AWS-Lambda-like FaaS platform simulation (paper §4.2, Eq. 2).

Models the parts that matter for the paper's measurements:
  - Function URLs: HTTP -> event -> mcp-lambda-handler -> JSON-RPC.
  - Containerized deployment (10 GB image limit), memory allocation per
    function, 512 MB ephemeral /tmp per *container instance*.
  - Cold starts: a new container instance boots when none is warm; warm
    instances are reused within ``KEEP_WARM_S`` of virtual time.
  - Billing: GB-seconds × $16.6667/1M (ap-south-1), per Eq. 2.
  - DynamoDB-backed session_id statefulness across invocations.
"""
from __future__ import annotations

import dataclasses
import json
import uuid
from typing import Callable, Dict, List, Optional

from ..env.latency import COLD_START, FAAS_RTT
from ..env.world import World
from ..mcp.protocol import McpRequest, McpResponse
from ..mcp.server import MCPServer, ToolContext
from .storage import DynamoTable, EphemeralTmp, S3Store

LAMBDA_GBS_USD = 16.6667 / 1e6          # $ per GB-second (Eq. 2)
REQUEST_USD = 0.20 / 1e6                # $ per request
KEEP_WARM_S = 900.0                      # container reuse window
IMAGE_LIMIT_MB = 10 * 1024


@dataclasses.dataclass
class Invocation:
    function: str
    tool: str
    duration_s: float
    billed_gb_s: float
    cost_usd: float
    cold_start: bool
    t_start: float


@dataclasses.dataclass
class _Container:
    instance_id: str
    tmp: EphemeralTmp
    last_used: float


class LambdaFunction:
    def __init__(self, name: str, handler_factory: Callable[[], object],
                 memory_mb: int, platform: "FaaSPlatform",
                 image_mb: int = 1024):
        if image_mb > IMAGE_LIMIT_MB:
            raise ValueError(f"container image {image_mb} MB exceeds 10 GB limit")
        self.name = name
        self.memory_mb = memory_mb
        self.platform = platform
        self.handler_factory = handler_factory
        self._containers: List[_Container] = []
        self._handler = None
        self.url = f"https://{uuid.uuid4().hex[:12]}.lambda-url.{platform.region}.on.aws/"

    def _acquire_container(self) -> tuple[_Container, bool]:
        now = self.platform.world.clock.now()
        for c in self._containers:
            if now - c.last_used < KEEP_WARM_S:
                return c, False
        c = _Container(uuid.uuid4().hex[:8], EphemeralTmp(512), now)
        self._containers.append(c)
        return c, True

    def invoke(self, raw_request: str) -> str:
        """HTTP Function-URL entry point: JSON body in, JSON body out."""
        world = self.platform.world
        clock = world.clock
        t0 = clock.now()
        container, cold = self._acquire_container()
        if cold:
            clock.sleep(world.latency.sample_spec(COLD_START))
            self._handler = self.handler_factory()
        req = McpRequest.from_json(raw_request)
        ctx = ToolContext(world=world, workspace=container.tmp,
                          s3=self.platform.s3, faas=True)
        resp = self._dispatch(req, ctx)
        # session persistence in DynamoDB
        if resp.session_id:
            self.platform.sessions.put(
                resp.session_id, {"function": self.name,
                                  "instance": container.instance_id})
        if req.method == "session/delete" and req.session_id:
            self.platform.sessions.delete(req.session_id)
        container.last_used = clock.now()
        duration = clock.now() - t0
        billed = max(duration, 0.001) * self.memory_mb / 1024.0
        cost = billed * LAMBDA_GBS_USD + REQUEST_USD
        tool = (req.params or {}).get("name", req.method)
        self.platform.invocations.append(Invocation(
            self.name, tool, duration, billed, cost, cold, t0))
        return resp.to_json()

    def _dispatch(self, req: McpRequest, ctx: ToolContext) -> McpResponse:
        handler = self._handler
        if isinstance(handler, dict):
            # monolithic deployment: handler is a dict of servers, routed
            # by the "server" param
            server_name = req.params.get("server")
            server = handler.get(server_name)
            if server is None:
                return McpResponse(req.id, error={
                    "code": -32602,
                    "message": f"unknown server {server_name!r}"})
            params = {k: v for k, v in req.params.items() if k != "server"}
            inner = McpRequest(method=req.method, params=params, id=req.id,
                               session_id=req.session_id)
            return server.handle(inner, ctx)
        # MCPServer or any handler object with handle(req, ctx) — e.g. the
        # run-service orchestrator (deploy_run_service)
        return handler.handle(req, ctx)


class FaaSPlatform:
    """One AWS region with Lambda + S3 + DynamoDB."""

    def __init__(self, world: World, region: str = "ap-south-1"):
        self.world = world
        self.region = region
        self.functions: Dict[str, LambdaFunction] = {}
        self._by_url: Dict[str, LambdaFunction] = {}   # O(1) URL routing
        self.s3 = S3Store()
        self.sessions = DynamoTable()
        self.invocations: List[Invocation] = []

    def deploy(self, name: str, handler_factory: Callable[[], object],
               memory_mb: int, image_mb: int = 1024) -> LambdaFunction:
        if name in self.functions:
            # redeploy: update code, keep the Function URL (AWS semantics)
            fn = self.functions[name]
            fn.handler_factory = handler_factory
            fn.memory_mb = memory_mb
            return fn
        fn = LambdaFunction(name, handler_factory, memory_mb, self, image_mb)
        self.functions[name] = fn
        self._by_url[fn.url] = fn
        return fn

    def invoke_url(self, url: str, raw_request: str) -> str:
        self.world.clock.sleep(self.world.latency.sample_spec(FAAS_RTT))
        fn = self._by_url.get(url)
        if fn is None:
            # a real Function-URL gateway answers with a JSON-RPC error
            # body, not a client-side crash
            req_id = json.loads(raw_request).get("id", 0)
            return McpResponse(req_id, error={
                "code": -32601, "message": f"no function at {url}"}).to_json()
        return fn.invoke(raw_request)

    # -- accounting --------------------------------------------------------
    def total_cost(self) -> float:
        return sum(i.cost_usd for i in self.invocations)

    def cost_by_function(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for i in self.invocations:
            out[i.function] = out.get(i.function, 0.0) + i.cost_usd
        return out

    def reset_accounting(self):
        self.invocations.clear()
