"""Virtual wall-clock.

Every latency in the framework (LLM inference, tool execution, FaaS cold
starts, network hops) is *simulated*: components call ``clock.sleep(dt)``
which advances virtual time instantly. Benchmarks therefore execute in
milliseconds while reporting realistic end-to-end seconds, and results are
fully deterministic under a fixed seed.
"""
from __future__ import annotations


class VirtualClock:
    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"negative sleep {dt}")
        self._t += dt

    def reset(self, t: float = 0.0) -> None:
        self._t = float(t)


class Stopwatch:
    """Measures virtual elapsed time around a block."""

    def __init__(self, clock: VirtualClock):
        self.clock = clock
        self.elapsed = 0.0

    def __enter__(self):
        self._t0 = self.clock.now()
        return self

    def __exit__(self, *exc):
        self.elapsed = self.clock.now() - self._t0
        return False
