"""Tool / network latency models, calibrated to the paper's Fig. 7 and §5.4.2.

Calibration anchors (seconds, local MCP unless noted):
  google search     ~1.7          get stock history ~1.6
  document retriever ~14.1 mean, heavy tail observed 0.77–795
  code executor      0.7 local, 3.4 FaaS (network + weaker Lambda vCPU)
  fetch/load-article/search: FaaS remote tools 13–35% slower than local
  LLM inference: dominated by output tokens (~30 tok/s for gpt-4o-mini)
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict


@dataclasses.dataclass
class LatencySpec:
    mean: float                 # lognormal mean (seconds)
    sigma: float = 0.25         # lognormal shape
    faas_factor: float = 1.0    # multiplier when served from FaaS
    tail_p: float = 0.0         # probability of a heavy-tail outlier
    tail_scale: float = 10.0    # outlier multiplier


TOOL_LATENCY: Dict[str, LatencySpec] = {
    "google_search": LatencySpec(1.7, 0.2, faas_factor=1.135),
    "fetch": LatencySpec(1.05, 0.3, faas_factor=1.348),
    "get_stock_history": LatencySpec(1.6, 0.25, faas_factor=0.735),
    "document_retriever": LatencySpec(9.0, 0.6, faas_factor=0.831,
                                      tail_p=0.04, tail_scale=14.0),
    "load_article": LatencySpec(2.2, 0.3, faas_factor=1.271),
    "download_article": LatencySpec(2.8, 0.3, faas_factor=1.1),
    "search_arxiv": LatencySpec(1.4, 0.25, faas_factor=1.1),
    "execute_python": LatencySpec(0.7, 0.2, faas_factor=4.857),
    "write_file": LatencySpec(0.02, 0.2, faas_factor=1.0),
    "read_file": LatencySpec(0.02, 0.2, faas_factor=1.0),
    "s3_write": LatencySpec(0.15, 0.2),
    "s3_read": LatencySpec(0.12, 0.2),
}

DEFAULT_SPEC = LatencySpec(0.25, 0.25, faas_factor=1.15)

# network round-trip for a Lambda Function URL call
FAAS_RTT = LatencySpec(0.09, 0.3)
# container cold start (dockerized lambda)
COLD_START = LatencySpec(1.9, 0.3)

# LLM inference: fit so app-level latencies land near Fig. 5
LLM_BASE = 0.45          # request overhead (s)
LLM_IN_TOK_PER_S = 9000  # prompt ingestion
LLM_OUT_TOK_PER_S = 31.0  # generation speed (gpt-4o-mini class)


class LatencySampler:
    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def sample(self, tool: str, faas: bool = False) -> float:
        spec = TOOL_LATENCY.get(tool, DEFAULT_SPEC)
        mu = math.log(spec.mean) - spec.sigma ** 2 / 2
        val = self.rng.lognormvariate(mu, spec.sigma)
        if spec.tail_p and self.rng.random() < spec.tail_p:
            val *= spec.tail_scale * (0.5 + self.rng.random())
        if faas:
            val *= spec.faas_factor
        return val

    def sample_spec(self, spec: LatencySpec) -> float:
        mu = math.log(spec.mean) - spec.sigma ** 2 / 2
        return self.rng.lognormvariate(mu, spec.sigma)

    def llm_latency(self, in_tokens: int, out_tokens: int) -> float:
        jitter = 0.9 + 0.2 * self.rng.random()
        return jitter * (LLM_BASE + in_tokens / LLM_IN_TOK_PER_S
                         + out_tokens / LLM_OUT_TOK_PER_S)
