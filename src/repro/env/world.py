"""Deterministic external-world simulation.

Replaces the paper's live services (Google Serper, the web, Yahoo Finance,
arXiv) with seeded corpora whose response *sizes* are calibrated so token
accounting lands in the paper's regimes (e.g. a search result ≈ 883 prompt
tokens, one fetch chunk ≈ 1063 tokens / 5000 chars).

Corpus synthesis is LAZY: a ``World`` is built per run, but text synthesis
(``_prose``) dominates construction cost, which matters once the traffic
subsystem (``repro.traffic``) replays thousands of runs per process.  Web
pages and arXiv papers derive their content from item-local string seeds
(``f"{topic}-{i}"``), NOT the world seed, so they are built on first
access into process-wide caches shared by every ``World``; stock series
DO depend on the world seed and are synthesized per ticker on demand.
Content is byte-identical to the historical eager construction.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import random
import re
import threading
import zlib
from typing import Dict, List, Tuple

# ---------------------------------------------------------------------------
# text synthesis helpers

_WORDS = ("system model data analysis method results network compute design "
          "research latency scaling cost energy device hardware software "
          "approach framework evaluation performance throughput memory state "
          "quantum packaging material edge inference market stock growth "
          "revenue capacity protocol agent workflow service cloud function "
          "deployment benchmark token context planning execution".split())


def _prose(seed: str, n_words: int) -> str:
    rng = random.Random(hashlib.md5(seed.encode()).hexdigest())
    out = []
    for i in range(n_words):
        w = rng.choice(_WORDS)
        if i == 0 or out[-1].endswith("."):
            w = w.capitalize()
        out.append(w + ("." if rng.random() < 0.08 else ""))
    return " ".join(out)


# ---------------------------------------------------------------------------
# Web corpus + search index


@dataclasses.dataclass
class WebPage:
    url: str
    title: str
    snippet: str
    content: str


# page content derives from item-local seeds only -> identical in every
# World; synthesized once per process, shared by all corpus instances
_PAGE_CACHE: Dict[str, WebPage] = {}


def _build_page(url: str) -> WebPage:
    m = re.match(r"https://example\.org/([a-z]+)/article-(\d+)$", url)
    if m is None or m.group(1) not in WebCorpus.TOPICS:
        raise KeyError(url)
    topic, i = m.group(1), int(m.group(2))
    query = WebCorpus.TOPICS[topic]
    title = f"{query.split(' and ')[0].title()} — Part {i + 1}"
    # ~2 fetch chunks of 5000 chars each (paper Fig. 10: ReAct
    # re-fetches each truncated page once -> ~2 calls/URL)
    content = (f"# {title}\n\n"
               + _prose(f"{topic}-{i}", 980 + 60 * (i % 4)))
    return WebPage(url, title, content[120:540], content)


class _PageMap(dict):
    """Lazy ``{url: WebPage}``: pages synthesize on first subscript (via
    the shared process-wide cache); URLs outside the corpus — foreign
    hosts OR article indices past ``pages_per_topic`` — raise
    ``KeyError`` exactly as the eager dict did (404 on fetch)."""

    def __init__(self, pages_per_topic: int):
        super().__init__()
        self._limit = pages_per_topic

    def __missing__(self, url: str) -> WebPage:
        m = re.match(r"https://example\.org/[a-z]+/article-(\d+)$", url)
        if m is not None and int(m.group(1)) >= self._limit:
            raise KeyError(url)   # past this corpus's page count
        page = _PAGE_CACHE.get(url)
        if page is None:
            page = _build_page(url)
            _PAGE_CACHE[url] = page
        self[url] = page
        return page


class WebCorpus:
    TOPICS = {
        "quantum": "Recent advancements in quantum computing hardware development",
        "edge": "Edge devices and their real-world use cases in 2025",
        "materials": "Latest trends in biodegradable materials for sustainable packaging",
    }

    def __init__(self, seed: int = 7, pages_per_topic: int = 10):
        self.pages: Dict[str, WebPage] = _PageMap(pages_per_topic)
        self.by_topic: Dict[str, List[str]] = {
            topic: [f"https://example.org/{topic}/article-{i}"
                    for i in range(pages_per_topic)]
            for topic in self.TOPICS}

    def topic_of(self, query: str) -> str:
        q = query.lower()
        if "quantum" in q:
            return "quantum"
        if "edge" in q:
            return "edge"
        if "material" in q or "packag" in q or "biodegrad" in q:
            return "materials"
        # deterministic fallback (crc32: builtin hash is per-process)
        return sorted(self.TOPICS)[zlib.crc32(q.encode()) % len(self.TOPICS)]

    def search(self, query: str, num_results: int = 8) -> List[WebPage]:
        topic = self.topic_of(query)
        urls = self.by_topic[topic][:num_results]
        return [self.pages[u] for u in urls]

    def fetch(self, url: str, start_index: int = 0,
              max_length: int = 5000) -> Tuple[str, bool]:
        """Returns (chunk, truncated)."""
        try:
            page = self.pages[url]   # dict.get would bypass lazy synthesis
        except KeyError:
            raise KeyError(f"404: {url}") from None
        chunk = page.content[start_index:start_index + max_length]
        truncated = start_index + max_length < len(page.content)
        return chunk, truncated


# ---------------------------------------------------------------------------
# Stock market


class _SeriesMap(dict):
    """Lazy ``{ticker: [close...]}``: a series synthesizes on first
    subscript with the identical per-ticker RNG the eager loop used
    (``Random(seed + sum(ord))``), so order of access never matters."""

    def __init__(self, seed: int, days: int):
        super().__init__()
        self._seed = seed
        self._days = days

    def __missing__(self, tic: str) -> List[float]:
        base = StockMarket._BASE.get(tic)
        if base is None:
            raise KeyError(tic)
        rng = random.Random(self._seed + sum(map(ord, tic)))
        px, out = base, []
        for _ in range(self._days):
            px *= math.exp(rng.gauss(0.0004, 0.015))
            out.append(round(px, 2))
        self[tic] = out
        return out


class StockMarket:
    TICKERS = {
        "apple": "AAPL", "alphabet": "GOOGL", "google": "GOOGL",
        "microsoft": "MSFT", "netflix": "NFLX", "disney": "DIS",
        "amazon": "AMZN", "coca-cola": "KO", "pepsico": "PEP",
        "mondelez": "MDLZ",
    }
    _BASE = {"AAPL": 190.0, "GOOGL": 165.0, "MSFT": 420.0, "NFLX": 640.0,
             "DIS": 101.0, "AMZN": 185.0, "KO": 62.0, "PEP": 172.0,
             "MDLZ": 67.0}

    def __init__(self, seed: int = 11, days: int = 160):
        self.days = days
        self.series: Dict[str, List[float]] = _SeriesMap(seed, days)

    def resolve(self, name: str) -> str:
        name = name.strip().lower()
        if name.upper() in self._BASE:
            return name.upper()
        for k, v in self.TICKERS.items():
            if k in name:
                return v
        raise KeyError(f"unknown ticker {name!r}")

    def history(self, ticker: str, days: int = 160) -> Dict:
        tic = self.resolve(ticker)
        days = min(days, self.days)
        return {"ticker": tic,
                "dates": [f"2025-{1 + i // 21:02d}-{1 + i % 21:02d}"
                          for i in range(days)],
                "close": self.series[tic][-days:]}


# ---------------------------------------------------------------------------
# arXiv corpus


@dataclasses.dataclass
class ArxivPaper:
    arxiv_id: str
    title: str
    abstract: str
    sections: Dict[str, str]

    def full_text(self) -> str:
        parts = [f"# {self.title}", self.abstract]
        for name, body in self.sections.items():
            parts.append(f"## {name}\n{body}")
        return "\n\n".join(parts)


class ArxivCorpus:
    TITLES = {
        "why": ("2503.13657", "Why Do Multi-Agent LLM Systems Fail?"),
        "flow": ("2501.07834", "Flow: Modularized Agentic Workflow Automation"),
        "magentic": ("2411.04468",
                     "Magentic-One: A Generalist Multi-Agent System for "
                     "Solving Complex Tasks"),
    }
    SECTIONS = ("Core Contributions", "Methodology", "Experimental Results",
                "Limitations")

    # paper content derives from key-local seeds only -> identical in
    # every World; synthesized once per process, shared by all instances.
    # Lock-guarded: concurrent World construction (execute_many workers)
    # must never observe a partially built corpus.
    _CACHE: Dict[str, ArxivPaper] = {}
    _CACHE_LOCK = threading.Lock()

    def __init__(self, seed: int = 13):
        with ArxivCorpus._CACHE_LOCK:
            if not ArxivCorpus._CACHE:
                built = {}
                for key, (aid, title) in self.TITLES.items():
                    sections = {}
                    for sec in self.SECTIONS:
                        # interleave the section name so RAG retrieval
                        # has signal
                        body_parts = []
                        for j in range(6):
                            body_parts.append(f"{sec} of this work include "
                                              f"the following aspects.")
                            body_parts.append(_prose(f"{key}-{sec}-{j}", 220))
                        sections[sec] = " ".join(body_parts)
                    abstract = _prose(f"{key}-abs", 180)
                    built[aid] = ArxivPaper(aid, title, abstract, sections)
                ArxivCorpus._CACHE.update(built)
        self.papers: Dict[str, ArxivPaper] = ArxivCorpus._CACHE

    def search(self, query: str, max_results: int = 5) -> List[ArxivPaper]:
        q = query.lower()
        hits = [p for p in self.papers.values()
                if any(w in p.title.lower() for w in q.split() if len(w) > 3)]
        return (hits or list(self.papers.values()))[:max_results]

    def get(self, arxiv_id: str) -> ArxivPaper:
        if arxiv_id not in self.papers:
            raise KeyError(f"arXiv {arxiv_id} not found")
        return self.papers[arxiv_id]


# ---------------------------------------------------------------------------


class World:
    """Bundle of all simulated external services + the virtual clock."""

    def __init__(self, seed: int = 0):
        from .clock import VirtualClock
        from .latency import LatencySampler
        self.seed = seed
        self.clock = VirtualClock()
        self.latency = LatencySampler(seed)
        self.web = WebCorpus(seed + 7)
        self.stocks = StockMarket(seed + 11)
        self.arxiv = ArxivCorpus(seed + 13)
