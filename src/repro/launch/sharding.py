"""Sharding rules: parameter PartitionSpecs (2D FSDP × TP) + activation
constraint policies + ShapeDtypeStruct input specs for every
(architecture × input shape × mesh) combination.

Scheme (DESIGN.md §5):
  - global batch over ("pod","data") — pure DP across pods;
  - "feature-in" matmul dims over "data" (FSDP-style weight sharding: the
    all-gathers amortize against layer compute);
  - "feature-out"/heads/experts/vocab over "model" (TP / expert parallel);
  - decode KV caches: batch over data; heads over model when divisible,
    otherwise the *sequence* axis shards over model and GSPMD turns the
    softmax reductions into all-reduces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import INPUT_SHAPES, InputShape, ModelConfig
from ..models.model import init_cache
from ..models.params import abstract_params
from ..training.optimizer import init_opt_state

# ---------------------------------------------------------------------------
# parameter rules: name -> spec for the TRAILING dims (leading layer-stack
# dims are padded with None)

_TRAILING_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": ("model", "data"),
    "lm_head": ("data", "model"),
    "final_norm": (None,),
    "attn_norm": (None,), "mlp_norm": (None,),
    "wq": ("data", "model"), "wk": ("data", "model"), "wv": ("data", "model"),
    "wo": ("model", "data"),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    "w_gate": ("data", "model"), "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    "w_router": ("data", None),
    "w_dq": ("data", "model"), "w_uq": ("data", "model"),
    "w_dkv": ("data", "model"), "w_kpe": ("data", None),
    "w_uk": ("data", "model"), "w_uv": ("data", "model"),
    "w_in": ("data", "model"), "w_conv": (None, "model"),
    "dt_bias": ("model",), "A_log": ("model",), "D": ("model",),
    "w_out": ("model", "data"),
    "m": None, "v": None, "step": None,   # containers, resolved recursively
}

_EXPERT_RULES = {   # leaves under an "experts" subtree: (E, d, ffe)-shaped
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_spec(path, leaf, mesh) -> P:
    names = _path_names(path)
    # optimizer state wraps params: strip the leading m/v
    core = tuple(n for n in names if n not in ("m", "v"))
    name = core[-1] if core else ""
    if name == "step":
        return P()
    in_experts = "experts" in core
    if in_experts and name in _EXPERT_RULES:
        trailing = _EXPERT_RULES[name]
    elif name == "norm":
        trailing = ("model",) if "ssm" in core else (None,)
    elif name in _TRAILING_RULES and _TRAILING_RULES[name] is not None:
        trailing = _TRAILING_RULES[name]
    else:
        trailing = (None,) * leaf.ndim
    ndim = leaf.ndim
    lead = (None,) * (ndim - len(trailing))
    spec = (lead + trailing)[:ndim]
    # drop axes that don't exist in the mesh (single-axis debug meshes)
    spec = tuple(s if (s is None or s in mesh.axis_names) else None
                 for s in spec)
    # never shard a dim its mesh axis doesn't divide evenly (pjit arg
    # shardings must tile exactly; GSPMD-internal padding is fine for
    # activations but not for argument shardings)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = tuple(
        s if s is None or (leaf.shape[i] % sizes[s] == 0
                           and leaf.shape[i] >= sizes[s]) else None
        for i, s in enumerate(spec))
    return P(*spec)


def param_shardings(abstract, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh)),
        abstract)


# ---------------------------------------------------------------------------
# activation policy


def make_activation_policy(cfg: ModelConfig, shape: InputShape, mesh,
                           overrides: Optional[Dict[str, P]] = None
                           ) -> Dict[str, P]:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in dp:
        dp_size *= sizes[a]
    batch_ax = dp if shape.global_batch >= dp_size else None
    model = "model" if "model" in mesh.axis_names else None
    kv_heads_divisible = (cfg.n_kv_heads and model
                          and cfg.n_kv_heads % sizes.get("model", 1) == 0)
    vocab_div = model and cfg.vocab_size % sizes.get("model", 1) == 0
    pol = {
        "tokens": P(batch_ax, None),
        "activations": P(batch_ax, None, None),
        "logits": P(batch_ax, None, model if vocab_div else None),
        "ffn_hidden": P(batch_ax, None, model),
        "attn_q": P(batch_ax, None, model, None),
        "attn_kv": P(batch_ax, None, model, None) if kv_heads_divisible
        else P(batch_ax, None, None, None),
        "kv_cache": (P(batch_ax, None, model, None) if kv_heads_divisible
                     else P(batch_ax, model, None, None)),
        "mla_cache": P(batch_ax, model, None),
        "moe_dispatch": P(model, None, None),
        "moe_hidden": P(model, None, None),
        "ssm_x": P(batch_ax, None, model, None),
    }
    if overrides:
        pol.update(overrides)
    return pol


def cache_spec(path, leaf, cfg: ModelConfig, shape: InputShape, mesh) -> P:
    names = _path_names(path)
    name = names[-1]
    pol = make_activation_policy(cfg, shape, mesh)
    dp = pol["tokens"][0]
    model = "model" if "model" in mesh.axis_names else None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    kv_div = cfg.n_kv_heads and model and cfg.n_kv_heads % sizes.get("model", 1) == 0
    lead = (None,) * (leaf.ndim - 4) if name in ("k", "v") else \
        (None,) * (leaf.ndim - 3)
    if name in ("k", "v"):       # (..., B, C, H, hd)
        tail = (dp, None, model, None) if kv_div else (dp, model, None, None)
        return P(*(lead + tail))
    if name in ("ckv", "kpe"):   # (..., B, C, r)
        return P(*((None,) * (leaf.ndim - 3) + (dp, model, None)))
    if name == "conv":           # (..., B, k-1, ch)
        return P(*((None,) * (leaf.ndim - 3) + (dp, None, model)))
    if name == "ssd":            # (..., B, nh, hd, n)
        return P(*((None,) * (leaf.ndim - 4) + (dp, model, None, None)))
    return P(*((None,) * leaf.ndim))


def cache_shardings(abstract_cache, cfg, shape, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(path, leaf, cfg, shape, mesh)),
        abstract_cache)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)


def effective_config(cfg: ModelConfig, shape: InputShape,
                     window: int = 8192) -> ModelConfig:
    """long_500k requires sub-quadratic attention: SSM/hybrid run as-is;
    attention archs get a sliding-window variant (DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.arch_type not in ("ssm",):
        if cfg.attention != "none" and cfg.sliding_window == 0:
            return cfg.with_sliding_window(window)
    return cfg


def input_specs(cfg: ModelConfig, shape: InputShape,
                param_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct batch for one step of the given kind."""
    B, S = shape.global_batch, shape.seq_len
    P_fe = cfg.frontend_positions if cfg.frontend else 0
    tok = jnp.int32
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, S - P_fe), tok)}
        if P_fe:
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, P_fe, cfg.d_model), param_dtype)
        return batch
    # decode: one new token against a cache of S
    window = cfg.sliding_window
    C = min(S, window) if window else S
    cache = jax.eval_shape(lambda: init_cache(cfg, B, C, dtype=param_dtype))
    return {"token": jax.ShapeDtypeStruct((B, 1), tok),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "cache": cache}
