"""Sharding variants for the §Perf hillclimbs.

Each variant transforms the baseline (paper-faithful 2D FSDP × TP) sharding
into an alternative; the probe harness re-lowers and re-measures so every
hypothesis→change→measure cycle is a one-line experiment.

Variants:
  baseline   — 2D FSDP×TP as in DESIGN.md §5.
  zero1      — ZeRO-1: parameters replicated across "data" (TP-sharded
               only); optimizer m/v shard their layer-stack dim across
               "data". Trades +param memory for removing per-layer weight
               all-gathers / activation all-reduces on the data axis.
  decode_mp  — serving: weights TP-only (replicated over "data"); decode
               batch stays on "data". Removes per-token weight collectives.
  seq_data   — sequence/context parallelism: activations shard the
               sequence dim over "data" as well (prefill).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from . import sharding as S


def _strip_data(spec: P) -> P:
    return P(*(None if s == "data" else s for s in spec))


def param_shardings_variant(abstract, mesh, variant: str):
    if variant in ("baseline", "seq_data"):
        return S.param_shardings(abstract, mesh)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_all = 1
    for a in ("data", "model"):
        n_all *= sizes.get(a, 1)

    def spec_fn(path, leaf):
        spec = S.param_spec(path, leaf, mesh)
        names = S._path_names(path)
        if variant == "zero1":
            spec = _strip_data(spec)
            # optimizer state: shard the leading layer-stack dim over data
            if names and names[0] in ("m", "v") and leaf.ndim >= 2 \
                    and leaf.shape[0] % sizes.get("data", 1) == 0 \
                    and leaf.shape[0] >= sizes.get("data", 1) \
                    and spec[0] is None:
                spec = P("data", *spec[1:])
        elif variant == "decode_mp":
            spec = _strip_data(spec)
        elif variant == "dp_only":
            # small models: no tensor parallelism at all — weights fully
            # replicated, parallelism comes from batch ("data") × sequence
            # ("model") on activations (see policy overrides).
            spec = P(*((None,) * leaf.ndim))
        elif variant == "moe_ep":
            core = tuple(n for n in names if n not in ("m", "v"))
            name = core[-1] if core else ""
            if "experts" in core and leaf.ndim >= 3 \
                    and leaf.shape[-3] % sizes.get("data", 1) == 0:
                # clean expert parallelism: experts over "data", weights
                # otherwise local so expert matmuls have NO collectives;
                # optimizer state additionally shards over "model"
                lead = (None,) * (leaf.ndim - 3)
                if names and names[0] in ("m", "v"):
                    spec = P(*(lead + ("data", None, "model")))
                else:
                    spec = P(*(lead + ("data", None, None)))
            else:
                # dense (MLA/shared/router) part: TP-only (strip data),
                # ZeRO-style m/v sharding on the layer-stack dim
                spec = _strip_data(spec)
                if names and names[0] in ("m", "v") and leaf.ndim >= 2 \
                        and leaf.shape[0] % sizes.get("data", 1) == 0 \
                        and leaf.shape[0] >= sizes.get("data", 1) \
                        and spec[0] is None:
                    spec = P("data", *spec[1:])
        elif variant == "moe_shardmap":
            # inference EP via shard_map (repro.models.moe_shardmap):
            # experts E over "model" ONLY (weights otherwise local);
            # router replicated; dense part keeps baseline
            core = tuple(n for n in names if n not in ("m", "v"))
            name = core[-1] if core else ""
            if "experts" in core and leaf.ndim >= 3:
                lead = (None,) * (leaf.ndim - 3)
                spec = P(*(lead + ("model", None, None)))
            elif name == "w_router":
                spec = P(*((None,) * leaf.ndim))
        elif variant == "dense_zero1":
            # deepseek iteration 5: the experts' FSDP sharding + gather
            # dispatch is fine; the residual collective is the DENSE part's
            # contraction-dim all-reduces -> replicate only the dense
            # (MLA/router/shared/embed) params over "data", ZeRO-shard
            # their m/v on the layer-stack dim.
            core = tuple(n for n in names if n not in ("m", "v"))
            if "experts" not in core:
                spec = _strip_data(spec)
                if names and names[0] in ("m", "v") and leaf.ndim >= 2 \
                        and leaf.shape[0] % sizes.get("data", 1) == 0 \
                        and leaf.shape[0] >= sizes.get("data", 1) \
                        and spec[0] is None:
                    spec = P("data", *spec[1:])
        elif variant == "decode_2d":
            # 2D OUTPUT-dim sharding: never shard a contraction dim (no
            # per-token weight all-gathers); the trailing dim shards over
            # ("data","model") jointly when divisible, else "model" only,
            # else replicate. Activations in decode are tiny, so the
            # resulting activation reshards are ~free.
            core = tuple(n for n in names if n not in ("m", "v"))
            name = core[-1] if core else ""
            if name == "step" or leaf.ndim == 0:
                return NamedSharding(mesh, P())
            last = leaf.shape[-1]
            if last % n_all == 0 and last >= n_all:
                tail = (("data", "model"),)
            elif last % sizes.get("model", 1) == 0 \
                    and last >= sizes.get("model", 1):
                tail = ("model",)
            else:
                tail = (None,)
            spec = P(*((None,) * (leaf.ndim - 1) + tail))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(spec_fn, abstract)


def policy_overrides_variant(cfg, shape, mesh, variant: str
                             ) -> Optional[Dict[str, P]]:
    if variant == "dp_only":
        # batch over "data", sequence over "model"
        return {"tokens": P("data", "model"),
                "activations": P("data", "model", None),
                "ssm_x": P("data", "model", None, None),
                "logits": P("data", "model", None),
                "ffn_hidden": P("data", "model", None)}
    if variant == "moe_ep":
        return {"moe_dispatch": P("data", "model", None),
                "moe_hidden": P("data", "model", None)}
    if variant == "seq_data":
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return {"activations": P(None, dp, None),
                "ffn_hidden": P(None, dp, "model"),
                "logits": P(None, dp, "model"
                            if cfg.vocab_size % dict(zip(
                                mesh.axis_names,
                                mesh.devices.shape)).get("model", 1) == 0
                            else None)}
    return None


VARIANTS = ("baseline", "zero1", "decode_mp", "decode_2d", "seq_data",
            "dp_only", "moe_ep", "dense_zero1", "moe_shardmap")
