"""Scan-trip-count-corrected roofline measurement.

XLA's ``cost_analysis`` counts a ``while`` (lax.scan) body ONCE, not
× trip-count — so the full-L dry-run proves compilability/memory, but its
FLOP/byte/collective numbers undercount the layer stack. We correct by
compiling two UNROLLED probe variants (L=1 and L=2 layers at the real
d_model / batch / seq / mesh), solving

    cost(L) = base + L * layer     =>   layer = cost(2) - cost(1)

and extrapolating to the true layer count. Exact for costs linear in L
(flops/bytes/collectives all are — every layer is identical).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import INPUT_SHAPES, ModelConfig
from ..models import model as model_mod
from ..models.sharding_ctx import activation_policy
from .dryrun import (HBM_BW, ICI_BW, PEAK_FLOPS, build_step,
                     collective_bytes_from_hlo)
from .mesh import make_debug_mesh, make_production_mesh


def _probe_cfg(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    """Same architecture, ``n_layers`` layers (hybrid: n groups)."""
    if cfg.arch_type == "hybrid":
        return dataclasses.replace(cfg,
                                   n_layers=n_layers * cfg.hybrid_attn_every)
    return dataclasses.replace(cfg, n_layers=n_layers)


def _layer_multiplier(cfg: ModelConfig) -> float:
    """How many probe-layer units the real model has."""
    if cfg.arch_type == "hybrid":
        # probe unit = one group (5 ssm + shared attn); remainder ssm layers
        # counted as fractional groups (attn ≈ small vs 5 ssm blocks)
        g = cfg.n_layers // cfg.hybrid_attn_every
        rem = cfg.n_layers - g * cfg.hybrid_attn_every
        return g + rem / (cfg.hybrid_attn_every - 1)
    return float(cfg.n_layers)


def _measure(cfg, shape, mesh, param_dtype,
             variant="baseline") -> Dict[str, float]:
    fn, args, pol = build_step(cfg, shape, mesh, param_dtype, variant=variant)
    with mesh:
        with activation_policy(pol):
            lowered = fn.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"])}


def corrected_roofline(arch_cfg: ModelConfig, shape_name: str,
                       multi_pod: bool = False, debug_mesh: bool = False,
                       param_dtype=jnp.bfloat16,
                       unroll_scan: bool = True,
                       variant: str = "baseline") -> Dict:
    """Probe-corrected per-chip roofline terms for the REAL layer count."""
    from .sharding import effective_config
    shape = INPUT_SHAPES[shape_name]
    cfg = effective_config(arch_cfg, shape)
    mesh = (make_debug_mesh(multi_pod=multi_pod) if debug_mesh
            else make_production_mesh(multi_pod=multi_pod))

    prev = model_mod.SCAN_UNROLL
    model_mod.SCAN_UNROLL = unroll_scan
    try:
        c1 = _measure(_probe_cfg(cfg, 1), shape, mesh, param_dtype, variant)
        c2 = _measure(_probe_cfg(cfg, 2), shape, mesh, param_dtype, variant)
    finally:
        model_mod.SCAN_UNROLL = prev

    L = _layer_multiplier(cfg)
    out: Dict[str, float] = {}
    for k in ("flops", "bytes", "coll"):
        layer = max(c2[k] - c1[k], 0.0)
        base = max(c1[k] - layer, 0.0)
        out[k] = base + L * layer
        out[f"{k}_base"] = base
        out[f"{k}_layer"] = layer

    terms = {"compute_s": out["flops"] / PEAK_FLOPS,
             "memory_s": out["bytes"] / HBM_BW,
             "collective_s": out["coll"] / ICI_BW}
    n_chips = mesh.devices.size
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        model_flops = 6 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2 * n_active * shape.global_batch
    return {
        "arch": arch_cfg.name, "shape": shape_name, "variant": variant,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "per_chip": out, "roofline": terms,
        "dominant": max(terms, key=terms.get),
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / max(out["flops"] * n_chips, 1.0),
    }
