"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        [--reduced] [--steps 100] [--batch 4] [--seq 256] [--ckpt DIR]

Full configs run through the production mesh shardings (requires real
devices or the dry-run's forced host-device count); --reduced runs the
smoke-scale variant on whatever devices exist.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config
from ..training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"# training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"params≈{cfg.n_params() / 1e6:.1f}M on {jax.device_count()} device(s)")
    out = train(cfg, steps=args.steps, batch=args.batch, seq_len=args.seq,
                seed=args.seed, lr=args.lr, log_every=args.log_every,
                checkpoint_dir=args.ckpt)
    for h in out["history"]:
        print(json.dumps(h))
    print(f"# done: final_loss={out['final_loss']:.4f} "
          f"wall={out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
