"""Serving launcher: batched requests through the continuous-batching
scheduler (one jitted decode step advances all live slots; admission is
bucketed batched prefill, optionally chunked via --prefill-chunk).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 8 --max-new 16 [--prefill-chunk 32] \
        [--high-priority-every 4]
"""
from __future__ import annotations

import argparse
import time

from ..configs import ARCHS, get_config
from ..serving import BatchScheduler, Engine, RunMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill budget (0 = whole-prompt)")
    ap.add_argument("--per-request-prefill", action="store_true",
                    help="v1 admission: one exact-length prefill per "
                         "request (disables length bucketing)")
    ap.add_argument("--high-priority-every", type=int, default=0,
                    help="submit every Nth request at priority 1 to "
                         "exercise queue jumping / preemption")
    ap.add_argument("--metrics-out", default="",
                    help="write the monitor registry's Prometheus text "
                         "export here (plus <path>.otlp.json)")
    ap.add_argument("--profile-jit", action="store_true",
                    help="wrap the engine's jitted hot paths and print "
                         "per-fn compile counts and call-time stats")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    engine = Engine(cfg, seed=args.seed, prefill_chunk=args.prefill_chunk)
    monitor = RunMonitor()
    profiler = None
    if args.profile_jit:
        from ..telemetry import JitProfiler
        profiler = JitProfiler()
        profiler.wrap_engine(engine)
    sched = BatchScheduler(engine, n_slots=args.slots, max_len=args.max_len,
                           on_event=monitor,
                           batched_prefill=not args.per_request_prefill)
    prompts = [f"request {i}: summarize the latest agentic workflow results"
               for i in range(args.requests)]
    t0 = time.time()
    for i, p in enumerate(prompts):
        pri = (1 if args.high_priority_every
               and i % args.high_priority_every == 0 else 0)
        sched.submit(p, max_new=args.max_new, priority=pri)
    results = sched.run()
    wall = time.time() - t0
    toks = monitor.engine_tokens + len(results)   # + first (prefill) tokens
    print(f"# served {len(results)} requests, {toks} new tokens in "
          f"{wall:.1f}s ({toks / wall:.1f} tok/s on CPU) — "
          f"{monitor.engine_steps} decode steps, peak occupancy "
          f"{monitor.engine_peak_live}/{args.slots}, "
          f"{monitor.engine_prefill_tokens} prompt tokens prefilled, "
          f"{monitor.engine_preemptions} preemptions")
    for rid in sorted(results)[:3]:
        print(f"req{rid}: {results[rid][:48]!r}")
    if profiler is not None:
        print("# jit profile (calls / compiles / wall time per fn):")
        for row in profiler.table():
            print(row)
    if args.metrics_out:
        from ..telemetry import export_otlp_metrics_json, render_prometheus
        otlp_path = args.metrics_out + ".otlp.json"
        with open(args.metrics_out, "w") as fh:
            fh.write(render_prometheus(monitor.registry))
        with open(otlp_path, "w") as fh:
            fh.write(export_otlp_metrics_json(monitor.registry))
        print(f"# wrote {args.metrics_out} + {otlp_path}")


if __name__ == "__main__":
    main()
