"""Traffic launcher: drive a seeded workload through the asyncio
virtual-clock driver (or the wall-clock real mode) and print per-scenario
SLO telemetry.

    # 500-request bursty day over the default mix, faults + retry:
    PYTHONPATH=src python -m repro.launch.traffic --requests 500 \
        --arrival bursty --rate 5 --transient-rate 0.2 --retry

    # closed loop, 16 users:
    PYTHONPATH=src python -m repro.launch.traffic --arrival closed \
        --users 16 --requests 64

    # real wall-clock mode against the batched JAX engine (CPU):
    PYTHONPATH=src python -m repro.launch.traffic --real \
        --llm jax-batched --requests 8 --rate 1 --time-scale 20

    # repeat-heavy agentx mix with the plan cache (prints hit/miss/
    # fallback telemetry; repeats replay compiled graphs planner-free):
    PYTHONPATH=src python -m repro.launch.traffic --plan-cache \
        --unique-seeds 4 --requests 60 \
        --scenario web_search:quantum:agentx \
        --scenario stock_correlation:netflix:agentx:faas

    # multi-tenant noisy neighbor: the mix replicated per tenant (noisy
    # offers 5x the load), fair-share admission at 8 slots, a token
    # budget on the noisy tenant, per-tenant telemetry at the end:
    PYTHONPATH=src python -m repro.launch.traffic --requests 105 \
        --rate 0.21 --concurrency 8 \
        --tenants steady-a,steady-b,noisy:5 \
        --tenant-weights steady-a:1,steady-b:1,noisy:1 \
        --budget noisy:500000
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from ..apps.session import Session
from ..core.policies import HedgePolicy, RetryPolicy
from ..traffic import (DEFAULT_MIX, FaultPlan, SLOTarget, Scenario,
                       TrafficDriver, Workload, aggregate_report,
                       register_fault_plan)
from ..traffic.faults import FaultStats


def _mix(args) -> tuple:
    if args.scenario:
        scenarios = []
        for i, raw in enumerate(args.scenario):
            parts = raw.split(":")
            if len(parts) < 3:
                raise SystemExit(f"--scenario {raw!r}: expected "
                                 f"app:instance:pattern[:deployment[:weight]]")
            app, inst, pat = parts[:3]
            dep = parts[3] if len(parts) > 3 else "local"
            weight = float(parts[4]) if len(parts) > 4 else 1.0
            scenarios.append(Scenario(f"{app}/{dep}/{pat}", app, inst, pat,
                                      dep, weight=weight))
        mix = tuple(scenarios)
    else:
        mix = DEFAULT_MIX
    if args.llm != "oracle":
        mix = tuple(dataclasses.replace(s, llm=args.llm) for s in mix)
    return mix


def _tenancy(args):
    """Parse the tenant knobs into (load multipliers, registry, Tenancy).

    ``--tenants a,b,noisy:5`` — tenant names with optional arrival-load
    multipliers; ``--tenant-weights a:1,noisy:0.5`` — fair-share
    weights; ``--budget noisy:500000`` or ``noisy:500000:0.25`` — token
    (and optional USD) caps.  Returns ``(None, None, None)`` when
    ``--tenants`` is absent — the tenancy-off path, bit-identical to
    the single-tenant launcher."""
    if not args.tenants:
        if args.tenant_weights or args.budget:
            raise SystemExit("--tenant-weights/--budget require --tenants")
        return None, None, None
    from ..tenancy import Tenancy, Tenant, TenantRegistry

    def pairs(raw, what):
        out = {}
        for part in raw.split(","):
            if not part:
                continue
            bits = part.split(":")
            try:
                out[bits[0]] = [float(b) for b in bits[1:]]
            except ValueError:
                raise SystemExit(f"bad {what} entry {part!r}")
        return out

    mults = {t: (v[0] if v else 1.0)
             for t, v in pairs(args.tenants, "--tenants").items()}
    weights = {t: (v[0] if v else 1.0)
               for t, v in pairs(args.tenant_weights or "",
                                 "--tenant-weights").items()}
    budgets = pairs(args.budget or "", "--budget")
    for t in list(weights) + list(budgets):
        if t not in mults:
            raise SystemExit(f"tenant {t!r} not listed in --tenants")
    registry = TenantRegistry(*(
        Tenant(t, weight=weights.get(t, 1.0),
               token_budget=(budgets[t][0] if t in budgets
                             else float("inf")),
               cost_budget_usd=(budgets[t][1]
                                if t in budgets and len(budgets[t]) > 1
                                else float("inf")))
        for t in mults))
    return mults, registry, Tenancy(registry)


def _export_metrics(args, report):
    """Fold the finished report into a fresh registry, write the
    Prometheus + OTLP exports, and return ``(registry, slo_monitor,
    paths)`` for the summary tables."""
    from ..telemetry import (EventMetricsBridge, MetricsRegistry,
                             SloMonitor, export_otlp_metrics_json,
                             fold_report, render_prometheus)
    registry = MetricsRegistry()
    fold_report(EventMetricsBridge(registry), report)
    slo_mon = SloMonitor(SLOTarget(), window_s=args.slo_window,
                         threshold=args.burn_threshold, registry=registry)
    slo_mon.observe_records(report.records)
    otlp_path = args.metrics_out + ".otlp.json"
    with open(args.metrics_out, "w") as fh:
        fh.write(render_prometheus(registry))
    with open(otlp_path, "w") as fh:
        fh.write(export_otlp_metrics_json(registry))
    return registry, slo_mon, (args.metrics_out, otlp_path)


def _print_telemetry(registry, slo_mon, paths) -> None:
    def t(name):
        return int(registry.total(name))

    def hit_rate(cache):
        g = registry.get("repro_cache_hit_rate")
        return g.value(cache=cache) if g is not None else 0.0

    print(f"# telemetry: {t('repro_events_total')} events folded into "
          f"{len(registry.names())} families | wrote {paths[0]} + "
          f"{paths[1]}")
    rows = [
        ("orchestration",
         f"runs={t('repro_runs_started_total')} "
         f"llm_calls={t('repro_llm_calls_total')} "
         f"tool_calls={t('repro_tool_calls_total')} "
         f"retries={t('repro_tool_retries_total')} "
         f"hedges={t('repro_hedges_total')}"),
        ("engine",
         f"steps={t('repro_engine_steps_total')} "
         f"decode_tokens={t('repro_engine_decode_tokens_total')} "
         f"prefill_tokens={t('repro_engine_prefill_tokens_total')} "
         f"prefix_hits={t('repro_engine_prefix_hits_total')}"),
        ("tenancy",
         f"spend_usd={registry.total('repro_tenant_spend_usd_total'):.5f} "
         f"degraded={t('repro_tenant_degraded_total')} "
         f"rejected={t('repro_tenant_rejected_total')}"),
        ("caches",
         f"plan_hit_rate={hit_rate('plan'):.0%} "
         f"lookups={t('repro_cache_lookups_total')} "
         f"plan_events={t('repro_plan_cache_events_total')}"),
        ("durability",
         f"crashes={t('repro_run_crashes_total')} "
         f"resumes={t('repro_run_resumes_total')}"),
        ("slo",
         f"alerts={len(slo_mon.alerts)} " + " ".join(
             f"{o}={n}" for o, n in
             slo_mon.summary()["by_objective"].items())),
    ]
    for layer, detail in rows:
        print(f"#   {layer:14s} {detail}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", action="append", default=[],
                    help="app:instance:pattern[:deployment[:weight]] "
                         "(repeatable; default: the built-in mix)")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty", "uniform", "closed"])
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--users", type=int, default=8,
                    help="closed-loop virtual users")
    ap.add_argument("--think", type=float, default=5.0,
                    help="closed-loop mean think time (virtual s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--concurrency", type=int, default=0,
                    help="in-flight run cap (0 = unbounded)")
    ap.add_argument("--llm", default="oracle")
    # multi-tenant serving (repro.tenancy)
    ap.add_argument("--tenants", default="",
                    help="comma list of tenant[:load-multiplier] — "
                         "replicate the mix per tenant (noisy neighbor: "
                         "'a,b,noisy:5') and admit fair-share")
    ap.add_argument("--tenant-weights", default="",
                    help="comma list of tenant:weight fair-share weights "
                         "(default 1.0 each)")
    ap.add_argument("--budget", default="",
                    help="comma list of tenant:tokens[:usd] budget caps "
                         "(soft 80%% degrades, hard cap rejects)")
    # plan compilation (repro.plans)
    ap.add_argument("--plan-cache", action="store_true",
                    help="compile successful agentx runs into plan graphs "
                         "and replay repeats planner-free")
    ap.add_argument("--unique-seeds", type=int, default=0,
                    help="cap distinct spec seeds (repeat-heavy mix; "
                         "0 = every request unique)")
    # fault injection + resilience
    ap.add_argument("--transient-rate", type=float, default=0.0)
    ap.add_argument("--throttle-rate", type=float, default=0.0)
    ap.add_argument("--cold-start-rate", type=float, default=0.0)
    ap.add_argument("--cold-start-s", type=float, default=2.5)
    ap.add_argument("--retry", action="store_true",
                    help="enable RetryPolicy on the session")
    ap.add_argument("--hedge-after", type=float, default=0.0,
                    help="enable HedgePolicy at this deadline (virtual s)")
    # durable execution (repro.durable)
    ap.add_argument("--crash-rate", type=float, default=0.0,
                    help="per-attempt platform-kill probability "
                         "(crashed runs restart; with --journal-dir they "
                         "resume from the journal)")
    ap.add_argument("--journal-dir", default="",
                    help="journal every run's event stream to this "
                         "directory and resume crashed runs from it")
    # real (wall-clock) mode
    ap.add_argument("--real", action="store_true",
                    help="wall-clock mode: thread-pool dispatch at scaled "
                         "arrival times (use with --llm jax-batched)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="real mode: compress arrival time by this factor")
    # unified telemetry (repro.telemetry)
    ap.add_argument("--metrics-out", default="",
                    help="fold the run into the metrics registry and "
                         "write the Prometheus text export here (plus "
                         "<path>.otlp.json), printing per-layer "
                         "telemetry tables")
    ap.add_argument("--slo-window", type=float, default=60.0,
                    help="SLO burn-rate window (virtual s)")
    ap.add_argument("--burn-threshold", type=float, default=2.0,
                    help="burn-rate multiple that fires an alert")
    ap.add_argument("--json", action="store_true",
                    help="print the full aggregate as JSON")
    args = ap.parse_args()

    mix = _mix(args)
    mults, registry, tenancy = _tenancy(args)
    if mults is not None:
        from ..traffic import tenant_mix
        mix = tenant_mix(mults, base=mix)
    stats = None
    if (args.transient_rate or args.throttle_rate or args.cold_start_rate
            or args.crash_rate):
        plan = FaultPlan(transient_rate=args.transient_rate,
                         throttle_rate=args.throttle_rate,
                         cold_start_rate=args.cold_start_rate,
                         cold_start_s=args.cold_start_s,
                         first_call_cold=False, seed=args.seed,
                         crash_rate=args.crash_rate)
        stats = FaultStats()
        faulty = []
        for s in mix:
            name = f"{s.deployment}+faults"
            register_fault_plan(name, s.deployment, plan, stats=stats)
            faulty.append(dataclasses.replace(s, deployment=name))
        mix = tuple(faulty)

    plan_cache = None
    if args.plan_cache:
        from ..plans import PlanCache
        plan_cache = PlanCache()
    journal = None
    if args.journal_dir:
        from ..durable import RunJournal
        journal = RunJournal(args.journal_dir)
    session = Session(
        retry=RetryPolicy(max_attempts=8, backoff_s=0.25)
        if args.retry else None,
        hedge=HedgePolicy(hedge_after_s=args.hedge_after)
        if args.hedge_after > 0 else None,
        plan_cache=plan_cache,
        journal=journal,
        tenancy=tenancy)
    wl = Workload(scenarios=mix, arrival=args.arrival, rate=args.rate,
                  n_requests=args.requests, seed=args.seed,
                  users=args.users, think_s=args.think,
                  unique_seeds=args.unique_seeds)
    restart = ("resume" if journal is not None
               else ("rerun" if args.crash_rate else "auto"))
    driver = TrafficDriver(session, max_concurrency=args.concurrency,
                           mode="real" if args.real else "virtual",
                           time_scale=args.time_scale,
                           restart=restart,
                           tenants=registry)
    report = driver.run(wl)
    agg = aggregate_report(report, SLOTarget())

    telemetry = None
    if args.metrics_out:
        telemetry = _export_metrics(args, report)

    if args.json:
        print(json.dumps(agg, indent=2))
        return
    rp = agg["replay"]
    print(f"# {len(report.records)} runs | virtual {rp['virtual_s']:.0f}s "
          f"in wall {rp['wall_s']:.2f}s ({rp['speedup']:.0f}x) | peak "
          f"{rp['peak_concurrency']} in flight | "
          f"{rp['throughput_rps']:.2f} runs/s")
    if stats is not None:
        print(f"# injected faults: {stats.snapshot()}")
    du = agg["overall"]["durability"]
    if du["crashes"]:
        print(f"# durability: {du['crashed_runs']} runs crashed "
              f"({du['crashes']} kills) | {du['resumes']} resumed from "
              f"journal | {du['replayed_events']} events replayed | "
              f"{du['recovered_tokens']} tokens "
              f"(${du['recovered_cost_usd']:.5f}) recovered | "
              f"${du['sunk_cost_usd']:.5f} sunk")
    if report.plan_cache is not None:
        p = report.plan_cache
        print(f"# plan cache: {p['hits']} hits / {p['misses']} misses / "
              f"{p['fallbacks']} fallbacks | hit rate {p['hit_rate']:.0%} | "
              f"{p['entries']} compiled graphs")
    hdr = (f"{'scenario':28s} {'n':>4s} {'ok%':>6s} {'p50':>7s} {'p95':>7s} "
           f"{'ttft95':>7s} {'qwait95':>8s} {'$/run':>9s} {'retry':>5s}")
    print(hdr)
    rows = list(agg["scenarios"].items()) + [("TOTAL", agg["overall"])]
    for name, a in rows:
        print(f"{name:28s} {a['n']:4d} {a['success_rate'] * 100:5.1f}% "
              f"{a['latency_s']['p50']:7.1f} {a['latency_s']['p95']:7.1f} "
              f"{a['ttft_s']['p95']:7.1f} {a['queue_wait_s']['p95']:8.1f} "
              f"{a['cost_usd']['total_mean']:9.5f} "
              f"{a['resilience']['retries']:5d}")
    if "tenants" in agg:
        print(f"{'tenant':28s} {'n':>4s} {'tokens':>9s} {'$total':>9s} "
              f"{'tok/s':>7s} {'qwait95':>8s} {'degr':>4s} {'rej':>4s}")
        for name, a in agg["tenants"].items():
            t = a["tenant"]
            print(f"{name:28s} {a['n']:4d} {t['tokens']:9.0f} "
                  f"{t['cost_usd']:9.5f} {t['token_throughput']:7.1f} "
                  f"{a['queue_wait_s']['p95']:8.1f} "
                  f"{t['degraded_runs']:4d} {t['rejected_runs']:4d}")
    if telemetry is not None:
        _print_telemetry(*telemetry)


if __name__ == "__main__":
    main()
