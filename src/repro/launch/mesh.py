"""Production meshes.

Target hardware: TPU v5e pods — 16×16 = 256 chips per pod; the multi-pod
configuration adds a leading "pod" axis over 2 pods (512 chips, ICI+DCN).

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked on first backend init — the dry-run sets
XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, *, multi_pod: bool = False):
    """Small mesh for CI (needs only data*model [*2] host devices)."""
    if multi_pod:
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
