"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers, compiles, and fits — without TPU hardware.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--debug-mesh] [--out artifacts/]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Must be imported fresh per device-count (jax locks device count on first
init) — hence the XLA_FLAGS lines below come before ANY other import.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCHS, INPUT_SHAPES, get_config            # noqa: E402
from ..models.model import decode_step, prefill                  # noqa: E402
from ..models.params import abstract_params                      # noqa: E402
from ..models.sharding_ctx import activation_policy              # noqa: E402
from ..training.optimizer import OptConfig, init_opt_state       # noqa: E402
from ..training.train_loop import make_train_step                # noqa: E402
from .mesh import make_debug_mesh, make_production_mesh          # noqa: E402
from .sharding import (cache_shardings, effective_config,        # noqa: E402
                       input_specs, make_activation_policy,
                       param_shardings)

# Combinations that die in NATIVE code (uncatchable abort, not a Python
# exception) on the emulated-host-device path.  --all sweeps write a
# {"skipped": ...} artifact instead of crashing the whole sweep; an
# explicit --arch/--shape request still runs them (reproducing the abort
# is the point then).  Tracked in ROADMAP "Open items".
KNOWN_BAD = {
    ("mamba2-370m", "long_500k"):
        "native XLA abort (free(): invalid pointer) while compiling the "
        "500k-token SSM scan on forced-host devices — pre-existing since "
        "the seed, unrelated to any PR; see ROADMAP open items",
}

# TPU v5e constants (roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|all-to-all|reduce-scatter|collective-permute)"
    r"(?:-start)?\(")


def collective_bytes_from_hlo(hlo: str):
    """Sum output bytes of every collective op in the (per-device) SPMD
    module, bucketed by op kind."""
    out = {}
    for m in _COLL_RE.finditer(hlo):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        nbytes = size * _DTYPE_BYTES.get(dtype, 4)
        out[kind] = out.get(kind, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def build_step(cfg, shape, mesh, param_dtype=jnp.bfloat16,
               variant="baseline"):
    """Returns (jitted_fn, example_args, policy) for the step kind."""
    from .variants import param_shardings_variant, policy_overrides_variant
    params_abs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, param_dtype if x.dtype == jnp.float32
            and x.ndim > 1 else x.dtype),
        abstract_params(cfg, dtype=param_dtype))
    p_sh = param_shardings_variant(params_abs, mesh, variant)
    batch = input_specs(cfg, shape, param_dtype)
    pol = make_activation_policy(
        cfg, shape, mesh,
        overrides=policy_overrides_variant(cfg, shape, mesh, variant))
    dp = pol["tokens"]

    from jax.sharding import NamedSharding, PartitionSpec as P
    ns = lambda spec: NamedSharding(mesh, spec)

    if shape.kind == "train":
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        o_sh = param_shardings_variant(opt_abs, mesh, variant)
        opt_cfg = OptConfig()
        step = make_train_step(cfg, opt_cfg)
        batch_sh = {"tokens": ns(dp)}
        if "frontend_embeds" in batch:
            batch_sh["frontend_embeds"] = ns(P(dp[0], None, None))
        fn = jax.jit(step,
                     in_shardings=(p_sh, o_sh, batch_sh),
                     out_shardings=(p_sh, o_sh, None))
        args = (params_abs, opt_abs, batch)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, cache = prefill(params, cfg, batch["tokens"],
                                    batch.get("frontend_embeds"))
            return logits, cache
        batch_sh = {"tokens": ns(dp)}
        if "frontend_embeds" in batch:
            batch_sh["frontend_embeds"] = ns(P(dp[0], None, None))
        fn = jax.jit(prefill_step, in_shardings=(p_sh, batch_sh))
        args = (params_abs, batch)
    else:   # decode
        def serve_step(params, cache, token, pos):
            return decode_step(params, cfg, cache, token, pos)
        c_sh = cache_shardings(batch["cache"], cfg, shape, mesh)
        # donate the cache: decode updates it in place (buffer aliasing),
        # halving the cache's contribution to peak memory (§Perf)
        fn = jax.jit(serve_step,
                     in_shardings=(p_sh, c_sh, ns(P(dp[0], None)), ns(P())),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
        args = (params_abs, batch["cache"], batch["token"], batch["pos"])
    return fn, args, pol


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False,
               debug_mesh: bool = False, param_dtype=jnp.bfloat16,
               policy_overrides=None, variant="baseline") -> dict:
    cfg0 = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg = effective_config(cfg0, shape)
    mesh = (make_debug_mesh(multi_pod=multi_pod) if debug_mesh
            else make_production_mesh(multi_pod=multi_pod))
    n_chips = mesh.devices.size

    t0 = time.time()
    fn, args, pol = build_step(cfg, shape, mesh, param_dtype, variant=variant)
    if policy_overrides:
        pol = dict(pol, **policy_overrides)
    with mesh:
        with activation_policy(pol):
            lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        # older jax (<= 0.4.x) returns a one-element list of dicts
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:   # CPU backend may not support it
        mem_info = {"error": str(e)}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    # roofline terms (per chip; the SPMD module is the per-device program)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll["total"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    # useful-FLOPs ratio: 6·N_active·D vs total HLO flops across chips
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        model_flops = 6 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2 * n_active * shape.global_batch  # one token
    ratio = model_flops / max(flops * n_chips, 1.0)

    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod, "n_chips": n_chips,
        "kind": shape.kind,
        "sliding_window": cfg.sliding_window,
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "collective_bytes_per_chip": coll,
        "roofline": terms, "dominant": dominant,
        "model_flops": model_flops, "useful_flops_ratio": ratio,
        "memory_analysis": mem_info,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in sorted(ARCHS):
            for s in INPUT_SHAPES:
                combos.append((a, s, args.multi_pod))
    else:
        combos.append((args.arch, args.shape, args.multi_pod))

    os.makedirs(args.out, exist_ok=True)
    for arch, shape, mp in combos:
        tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
        print(f"=== dry-run {tag} ===", flush=True)
        if args.all and (arch, shape) in KNOWN_BAD:
            res = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "skipped": KNOWN_BAD[(arch, shape)]}
            print("SKIPPED:", res["skipped"], flush=True)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(res, f, indent=2)
            continue
        try:
            res = dryrun_one(arch, shape, multi_pod=mp,
                             debug_mesh=args.debug_mesh)
        except Exception as e:
            res = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "error": f"{type(e).__name__}: {e}"}
            print("FAILED:", res["error"], flush=True)
        else:
            print(json.dumps({k: res[k] for k in
                              ("flops_per_chip", "bytes_per_chip",
                               "dominant", "useful_flops_ratio",
                               "compile_s")}, indent=None), flush=True)
            print("memory:", res["memory_analysis"], flush=True)
            print("collectives:", res["collective_bytes_per_chip"], flush=True)
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
