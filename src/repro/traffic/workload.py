"""Workload generators: seeded arrival processes over scenario mixes.

A :class:`Scenario` is a weighted RunSpec template — one cell of the
evaluation grid (app × pattern × deployment × llm × priority).  A
:class:`Workload` names a *mix* of scenarios plus an arrival process:

  * ``"poisson"`` — open-loop: exponential inter-arrivals at ``rate``
    requests per (virtual) second, the standard heavy-traffic model;
  * ``"bursty"`` — open-loop Markov-modulated Poisson: an on/off process
    alternates a quiet base rate with ``burst_factor``× bursts (FaaS
    workloads arrive in spikes — the regime where cold starts and
    queueing dominate, per "Optimizing FaaS Platforms for MCP-enabled
    Agentic Workflows");
  * ``"uniform"`` — open-loop fixed-interval arrivals (rate ``rate``);
  * ``"closed"`` — closed-loop: ``users`` virtual users think
    (exponential, mean ``think_s``) then submit, so offered load adapts
    to completion times.  Closed-loop arrivals depend on run latencies
    and are therefore produced by the driver, not precomputed here.

Everything is seeded and deterministic: the same ``Workload`` yields the
same arrival times, the same scenario draws, and the same per-run spec
seeds in every process — the property the bit-identical replay contract
of :mod:`repro.traffic.driver` rests on.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Tuple

from ..apps.session import RunSpec


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One weighted cell of the traffic mix.

    ``tenant`` stamps every spec this cell emits with its billing
    principal (multi-tenant serving, :mod:`repro.tenancy`); ``""`` is
    the single default tenant."""
    name: str
    app: str
    instance: str
    pattern: str
    deployment: str = "local"
    llm: str = "oracle"
    priority: int = 0
    weight: float = 1.0
    tenant: str = ""

    def spec(self, seed: int) -> RunSpec:
        return RunSpec(self.app, self.instance, self.pattern,
                       self.deployment, seed=seed, llm=self.llm,
                       priority=self.priority, tenant=self.tenant)


#: the default evaluation mix: the paper's three applications across the
#: three patterns and both paper deployments, skewed toward web search
#: (the cheapest, highest-volume app — a realistic traffic shape).
DEFAULT_MIX: Tuple[Scenario, ...] = (
    Scenario("web/local/agentx", "web_search", "quantum", "agentx",
             "local", weight=3.0),
    Scenario("web/faas/react", "web_search", "edge", "react",
             "faas", weight=3.0),
    Scenario("stock/local/react", "stock_correlation", "apple", "react",
             "local", weight=2.0),
    Scenario("stock/faas/agentx", "stock_correlation", "netflix", "agentx",
             "faas", weight=1.0),
    Scenario("research/local/magentic", "research_report", "flow",
             "magentic", "local", weight=1.0),
)


def tenant_mix(tenants: dict,
               base: Tuple[Scenario, ...] = DEFAULT_MIX
               ) -> Tuple[Scenario, ...]:
    """Replicate a scenario mix per tenant: ``tenants`` maps tenant name
    -> arrival-rate multiplier (1.0 = the base mix's share, 5.0 = a
    tenant offering 5× that load — the noisy-neighbor shape).  Each base
    scenario is copied per tenant as ``"<tenant>/<name>"`` with its
    arrival weight scaled; fair-share entitlement stays with the
    :class:`repro.tenancy.TenantRegistry` weights — this helper shapes
    *offered* load, not *admitted* share."""
    return tuple(
        dataclasses.replace(s, name=f"{tenant}/{s.name}", tenant=tenant,
                            weight=s.weight * mult)
        for tenant, mult in tenants.items()
        for s in base)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One generated request: arrival time (virtual seconds from the
    workload start), the drawn scenario, and the concrete spec."""
    index: int
    t: float
    scenario: Scenario
    spec: RunSpec


@dataclasses.dataclass(frozen=True)
class Workload:
    scenarios: Tuple[Scenario, ...] = DEFAULT_MIX
    arrival: str = "poisson"      # poisson | bursty | uniform | closed
    rate: float = 1.0             # mean arrivals / virtual second (open loop)
    n_requests: int = 100
    seed: int = 0
    # bursty (MMPP) knobs: mean sojourn in each phase, and the on-phase
    # rate multiplier (base rate is scaled so the LONG-RUN mean is `rate`)
    burst_factor: float = 8.0
    burst_fraction: float = 0.2   # fraction of time spent in the on phase
    phase_s: float = 20.0
    # closed-loop knobs
    users: int = 8
    think_s: float = 5.0
    # repeat-mix knob: cap the number of DISTINCT spec seeds.  0 keeps
    # the historical every-request-unique behavior; a small value makes
    # the stream repeat-heavy (the i-th request reuses seed i mod
    # unique_seeds) — the regime where the plan cache
    # (:mod:`repro.plans`) pays off, since same-template same-seed runs
    # replay compiled graphs planner-free.
    unique_seeds: int = 0

    # ------------------------------------------------------------------
    def _rng(self) -> random.Random:
        # string seeds hash via SHA-512 inside Random — process-stable
        # (tuple seeds would go through the randomized builtin hash)
        return random.Random(
            f"workload/{self.arrival}/{self.seed}/{self.n_requests}")

    def draw_scenario(self, rng: random.Random) -> Scenario:
        return rng.choices(self.scenarios,
                           weights=[s.weight for s in self.scenarios])[0]

    def spec_seed(self, i: int) -> int:
        """Spec seed for the i-th request (folded by ``unique_seeds``)."""
        if self.unique_seeds > 0:
            i = i % self.unique_seeds
        return self.seed * 100_000 + i

    def arrivals(self) -> List[Arrival]:
        """Materialize the open-loop arrival list (deterministic per
        seed).  Spec seeds are the arrival indices offset by the
        workload seed, so distinct workloads explore distinct worlds
        while the i-th request of a given workload is always the same
        run."""
        if self.arrival == "closed":
            raise ValueError("closed-loop arrivals are generated by the "
                             "driver (they depend on completion times)")
        rng = self._rng()
        out: List[Arrival] = []
        t = 0.0
        # bursty phase machinery (unused draws are NOT made for other
        # modes, so poisson/uniform streams stay stable if knobs change)
        if self.arrival == "bursty":
            base = self.rate / ((1.0 - self.burst_fraction)
                                + self.burst_fraction * self.burst_factor)
            on = rng.random() < self.burst_fraction
            phase_end = rng.expovariate(1.0 / self.phase_s)
        for i in range(self.n_requests):
            if self.arrival == "poisson":
                t += rng.expovariate(self.rate)
            elif self.arrival == "uniform":
                t += 1.0 / self.rate
            elif self.arrival == "bursty":
                r = base * self.burst_factor if on else base
                dt = rng.expovariate(r)
                while t + dt > phase_end:   # phase flips mid-gap: resample
                    dt = phase_end - t + rng.expovariate(
                        base if on else base * self.burst_factor)
                    on = not on
                    phase_end += rng.expovariate(1.0 / self.phase_s)
                t += dt
            else:
                raise ValueError(f"unknown arrival process "
                                 f"{self.arrival!r}")
            scenario = self.draw_scenario(rng)
            out.append(Arrival(i, t, scenario,
                               scenario.spec(self.spec_seed(i))))
        return out

    def describe(self) -> dict:
        return {"arrival": self.arrival, "rate": self.rate,
                "n_requests": self.n_requests, "seed": self.seed,
                "scenarios": [s.name for s in self.scenarios],
                **({"unique_seeds": self.unique_seeds}
                   if self.unique_seeds else {}),
                **({"users": self.users, "think_s": self.think_s}
                   if self.arrival == "closed" else {})}
