"""SLO aggregation over traffic records.

Turns a :class:`repro.traffic.driver.TrafficReport` into the summary the
paper's evaluation axes call for — per scenario: success rate under
load/faults, client-side latency and TTFT percentiles, queueing delay,
Eq. 1 LLM cost + Eq. 2 FaaS cost, and attainment against an
:class:`SLOTarget`.  ``benchmarks/traffic.py`` serializes this into
``artifacts/BENCH_traffic.json``; see ``docs/TRAFFIC.md`` for how to
read it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

from ..durable.resume import billed_cost, recovered_cost, recovered_tokens
from .driver import TrafficRecord, TrafficReport


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile on a sorted copy (no numpy dependency —
    matches ``benchmarks/serving.py``'s convention)."""
    if not values:
        return 0.0
    vals = sorted(values)
    i = min(len(vals) - 1, int(round(q * (len(vals) - 1))))
    return vals[i]


def _dist(values: List[float]) -> Dict[str, float]:
    if not values:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0,
                "max": 0.0}
    return {"p50": percentile(values, 0.50),
            "p95": percentile(values, 0.95),
            "p99": percentile(values, 0.99),
            "mean": sum(values) / len(values),
            "max": max(values)}


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """What "good" means for one scenario class."""
    latency_s: float = 120.0      # client-side completion deadline
    ttft_s: float = 30.0          # first LLM completion deadline
    success_rate: float = 0.90

    def describe(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _aggregate(records: List[TrafficRecord],
               slo: SLOTarget) -> Dict[str, object]:
    n = len(records)
    ok = [r for r in records if r.result.success]
    lat = [r.latency for r in records]
    ttft = [r.ttft for r in records if r.ttft is not None]
    success_rate = len(ok) / n if n else 0.0
    return {
        "n": n,
        "success_rate": success_rate,
        "latency_s": _dist(lat),
        "ttft_s": _dist(ttft),
        "queue_wait_s": _dist([r.queue_wait for r in records]),
        "cost_usd": {
            # paper Eq. 1 (LLM tokens) + Eq. 2 (FaaS GB-s + requests)
            "llm_mean": (sum(r.result.trace.llm_cost for r in records) / n
                         if n else 0.0),
            "faas_mean": (sum(r.result.faas_cost for r in records) / n
                          if n else 0.0),
            "total_mean": (sum(r.result.total_cost for r in records) / n
                           if n else 0.0),
            "total_sum": sum(r.result.total_cost for r in records),
        },
        "tokens": {
            "input_mean": (sum(r.result.trace.input_tokens
                               for r in records) / n if n else 0.0),
            "output_mean": (sum(r.result.trace.output_tokens
                                for r in records) / n if n else 0.0),
        },
        "resilience": {
            "retries": sum(r.retries for r in records),
            "hedges": sum(r.hedges for r in records),
        },
        "durability": {
            # crash-recovery economics (repro.durable): crashes absorbed,
            # journal resumes, and what recovery actually paid — sunk
            # billed cost of dead attempts + the final attempt's cost net
            # of the journal-recovered prefix
            "crashes": sum(r.crashes for r in records),
            "crashed_runs": sum(r.crashes > 0 for r in records),
            "resumes": sum(r.resumes for r in records),
            "replayed_events": sum(
                (r.result.extras.get("resume") or {}).get(
                    "replayed_events", 0) for r in records),
            "recovered_tokens": sum(recovered_tokens(r.result)
                                    for r in records),
            "recovered_cost_usd": sum(recovered_cost(r.result)
                                      for r in records),
            "sunk_cost_usd": sum(r.sunk_cost for r in records),
            "billed_cost_usd": sum(r.sunk_cost + billed_cost(r.result)
                                   for r in records),
        },
        "slo": {
            "target": slo.describe(),
            "latency_attainment": (sum(v <= slo.latency_s for v in lat) / n
                                   if n else 0.0),
            # None (not 0.0) when unmeasured — real mode records no TTFT,
            # which must not read as "every request missed the deadline"
            "ttft_attainment": (sum(v <= slo.ttft_s for v in ttft)
                                / len(ttft) if ttft else None),
            "meets_success_rate": success_rate >= slo.success_rate,
        },
    }


def _tenant_section(records: List[TrafficRecord], virtual_s: float,
                    slo: SLOTarget) -> Dict[str, object]:
    """Per-tenant rollup: the scenario aggregate plus billing telemetry
    — tokens, cost, degraded/rejected run counts (from the admission
    events on each run's stream) and fair-share token throughput
    (tokens per virtual second over the workload span)."""
    from ..core.events import BudgetExceeded, RunDegraded
    by_tenant: Dict[str, List[TrafficRecord]] = {}
    for r in records:
        by_tenant.setdefault(getattr(r.spec, "tenant", ""), []).append(r)

    out: Dict[str, object] = {}
    for tenant, recs in sorted(by_tenant.items()):
        agg = _aggregate(recs, slo)
        tokens = sum(r.result.trace.input_tokens
                     + r.result.trace.output_tokens for r in recs)
        events = [e for r in recs
                  for e in r.result.extras.get("events", ())]
        agg["tenant"] = {
            "tokens": tokens,
            "token_throughput": tokens / virtual_s if virtual_s else 0.0,
            "cost_usd": sum(r.result.total_cost for r in recs),
            "degraded_runs": sum(isinstance(e, RunDegraded)
                                 for e in events),
            "rejected_runs": sum(isinstance(e, BudgetExceeded)
                                 for e in events),
        }
        out[tenant or "<default>"] = agg
    return out


def aggregate_report(report: TrafficReport,
                     slo: Optional[SLOTarget] = None) -> Dict[str, object]:
    """The full summary: one section per scenario + an overall rollup +
    the replay economics (virtual seconds simulated per wall second).
    When any record carries a non-default tenant, a ``tenants`` section
    breaks the same aggregate down per billing principal."""
    slo = slo if slo is not None else SLOTarget()
    by_scenario: Dict[str, List[TrafficRecord]] = {}
    for r in report.records:
        by_scenario.setdefault(r.scenario, []).append(r)
    out: Dict[str, object] = {
        "scenarios": {name: _aggregate(recs, slo)
                      for name, recs in sorted(by_scenario.items())},
        "overall": _aggregate(report.records, slo),
        "replay": {
            "virtual_s": report.virtual_s,
            "wall_s": report.wall_s,
            "speedup": report.replay_speedup,
            "peak_concurrency": report.peak_concurrency(),
            "throughput_rps": (len(report.records) / report.virtual_s
                               if report.virtual_s else 0.0),
        },
    }
    if any(getattr(r.spec, "tenant", "") for r in report.records):
        out["tenants"] = _tenant_section(report.records, report.virtual_s,
                                         slo)
    if report.plan_cache is not None:
        out["plan_cache"] = report.plan_cache
    return out
