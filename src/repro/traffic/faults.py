"""Fault injection for deployment backends.

A :class:`FaultPlan` wraps ANY registered ``DeploymentBackend`` in a
transport-level injector that perturbs ``tools/call`` requests with the
three failure modes that dominate FaaS-hosted MCP serving:

  * **cold starts** — extra virtual latency on a client's first call
    (scale-to-zero) and, at ``cold_start_rate``, on later calls
    (instance churn under load);
  * **transient errors** — at ``transient_rate`` the call fails with a
    ``transient:``-tagged JSON-RPC error before reaching the server
    (connection resets, function timeouts, 5xx);
  * **throttling** — at ``throttle_rate`` the platform rejects with a
    ``throttled:`` error after ``throttle_delay_s`` of queueing (429s).

The error tags are what :class:`repro.core.policies.RetryPolicy` keys
on, so an injected fault is retryable while a real tool error (unknown
tool, bad arguments) is not.  Injection draws come from a per-transport
RNG seeded by ``(plan seed, world seed, server)`` — deterministic per
run, independent of the world's own latency stream, so the *simulated
environment* under faults is identical to the fault-free run (the
``world_alias`` capability completes that guarantee on the seed side).

Register a faulty twin of any deployment and point ``RunSpec.deployment``
at it::

    stats = register_fault_plan("faas+faults", "faas",
                                FaultPlan(transient_rate=0.2))
    Session(retry=RetryPolicy()).execute(
        RunSpec("web_search", "quantum", "agentx", "faas+faults"))
    stats.snapshot()   # {"transient": ..., "throttled": ..., ...}

Shared :class:`FaultStats` count every injection across runs — the
ground truth the traffic tests reconcile ``ToolRetried`` events against.
"""
from __future__ import annotations

import dataclasses
import random
import threading
from typing import Dict, Optional

from ..core.runtime import stable_fingerprint
from ..env.world import World
from ..faas.deployments import (DeploymentBackend, create_deployment,
                                register_deployment, resolve_deployment)
from ..mcp.client import Transport
from ..mcp.protocol import METHOD_CALL_TOOL, McpRequest, McpResponse


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Rates and magnitudes of injected faults (per ``tools/call``).

    ``crash_rate`` is per *run attempt*, not per call: with probability
    ``crash_rate`` the platform kills the whole run mid-flight at a
    drawn event index in ``[crash_min_events, crash_max_events]``
    (uniform; a draw beyond the run's natural length means the crash
    was scheduled after completion — no crash).  The kill is a
    :class:`repro.core.runtime.RunAborted` raised after the event at
    that index has been emitted (and therefore journaled, when a
    durable journal observes the run), so a crashed run's journal
    segment ends exactly at its last committed event."""
    transient_rate: float = 0.0
    transient_delay_s: float = 0.1    # time burned before the failure surfaces
    throttle_rate: float = 0.0
    throttle_delay_s: float = 1.0
    cold_start_rate: float = 0.0
    cold_start_s: float = 2.5
    first_call_cold: bool = True      # deterministic scale-to-zero start
    crash_rate: float = 0.0           # per-attempt mid-run kill probability
    crash_min_events: int = 3         # drawn kill index lower bound
    crash_max_events: int = 40        # ... upper bound
    seed: int = 0

    def fingerprint(self) -> str:
        return stable_fingerprint(self)


class FaultStats:
    """Thread-safe injection counters shared across runs (and across
    ``execute_many`` workers / async drivers)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.transient = 0
        self.throttled = 0
        self.cold_starts = 0
        self.crashes = 0
        self.by_server: Dict[str, int] = {}

    def record(self, kind: str, server: Optional[str] = None) -> None:
        with self._lock:
            setattr(self, kind, getattr(self, kind) + 1)
            # per-server: tool-call errors only — what retries see
            # (cold starts are latency, crashes are run-level kills)
            if server is not None and kind in ("transient", "throttled"):
                self.by_server[server] = self.by_server.get(server, 0) + 1

    @property
    def errors(self) -> int:
        return self.transient + self.throttled

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {"transient": self.transient,
                    "throttled": self.throttled,
                    "cold_starts": self.cold_starts,
                    "crashes": self.crashes,
                    "errors": self.transient + self.throttled,
                    "by_server": dict(self.by_server)}

    def reset(self) -> None:
        with self._lock:
            self.transient = self.throttled = 0
            self.cold_starts = self.crashes = 0
            self.by_server.clear()


class FaultInjectingTransport(Transport):
    """Wraps any transport; perturbs only ``tools/call`` requests (the
    control plane — initialize, tools/list, session delete — stays
    clean, mirroring how FaaS failures concentrate on the data path)."""

    def __init__(self, inner: Transport, plan: FaultPlan, stats: FaultStats,
                 world: World, server: str):
        self.inner = inner
        self.plan = plan
        self.stats = stats
        self.world = world
        self.server = server
        self._rng = random.Random(
            f"faults/{plan.seed}/{world.seed}/{server}")
        self._cold = plan.first_call_cold

    def send(self, req: McpRequest) -> McpResponse:
        if req.method != METHOD_CALL_TOOL:
            return self.inner.send(req)
        plan, rng, clock = self.plan, self._rng, self.world.clock
        if self._cold or rng.random() < plan.cold_start_rate:
            self._cold = False
            clock.sleep(plan.cold_start_s)
            self.stats.record("cold_starts", self.server)
        if rng.random() < plan.transient_rate:
            clock.sleep(plan.transient_delay_s)
            self.stats.record("transient", self.server)
            return McpResponse(req.id, error={
                "code": -32050,
                "message": "transient: injected connection reset "
                           "before response"})
        if rng.random() < plan.throttle_rate:
            clock.sleep(plan.throttle_delay_s)
            self.stats.record("throttled", self.server)
            return McpResponse(req.id, error={
                "code": -32060,
                "message": "throttled: injected 429 rate limit exceeded"})
        return self.inner.send(req)


class FaultyDeployment(DeploymentBackend):
    """A registered deployment wrapped in fault injection.  Subclasses
    are synthesized by :func:`register_fault_plan`; ``inner_name`` /
    ``plan`` / ``stats`` are class attributes there."""

    inner_name = "local"
    plan = FaultPlan()
    stats: FaultStats = FaultStats()

    def __init__(self, capabilities=None):
        super().__init__(capabilities)
        self.inner = create_deployment(self.inner_name)

    def provision(self, world: World, server_names):
        env = self.inner.provision(world, server_names)
        for name, client in env.clients.items():
            client.transport = FaultInjectingTransport(
                client.transport, self.plan, self.stats, world, name)
        self.env = env
        return env

    def teardown(self) -> None:
        self.inner.teardown()

    def cost(self) -> float:
        return self.inner.cost()

    def crash_point(self, world: World, attempt: int = 0) -> Optional[int]:
        """Draw this attempt's mid-run kill: with probability
        ``plan.crash_rate``, the absolute event index at which the
        platform dies.  Seeded by (plan seed, world seed, attempt) —
        deterministic per run, independent of the transport fault
        streams, and fresh per restart so a resumed/rerun attempt
        doesn't deterministically re-crash at the same point."""
        plan = self.plan
        if plan.crash_rate <= 0:
            return None
        rng = random.Random(
            f"crash/{plan.seed}/{world.seed}/{attempt}")
        if rng.random() >= plan.crash_rate:
            return None
        return rng.randint(plan.crash_min_events, plan.crash_max_events)

    def record_crash(self) -> None:
        self.stats.record("crashes")


def register_fault_plan(name: str, inner: str, plan: FaultPlan,
                        stats: Optional[FaultStats] = None) -> FaultStats:
    """Register deployment ``name``: ``inner`` + ``plan`` injection.

    Capabilities are the inner backend's with ``world_alias=inner`` —
    prompts, tool subsetting, artifact stores AND the world seed all
    match the wrapped deployment, so a faulty run differs from its
    clean twin only by the injected perturbations.  Returns the shared
    :class:`FaultStats` (pass one in to aggregate across plans).
    Re-registering a name replaces it (same semantics as the underlying
    registry)."""
    stats = stats if stats is not None else FaultStats()
    inner_caps = resolve_deployment(inner).capabilities
    cls = type(f"Faulty{inner.title().replace('-', '')}Deployment",
               (FaultyDeployment,),
               {"name": name, "inner_name": inner, "plan": plan,
                "stats": stats, "default_capabilities": inner_caps})
    # tags deliberately NOT inherited: a faulty twin of "local" must not
    # show up in tag="paper" listings
    register_deployment(name, tags=("faulty",),
                        world_alias=inner, rank=90)(cls)
    return stats
