"""Traffic subsystem: load generation, fault injection, SLO benchmarking.

The layer that turns the per-run reproducer into a traffic-scale
evaluation system (ROADMAP "Traffic"):

  * :mod:`repro.traffic.workload` — seeded arrival processes (Poisson,
    bursty MMPP, uniform, closed-loop) over weighted scenario mixes;
  * :mod:`repro.traffic.driver` — the asyncio virtual-clock driver: one
    event loop interleaves thousands of in-flight runs on a shared
    deterministic timeline (``Session.execute_many_async`` wraps it);
  * :mod:`repro.traffic.faults` — transport-level fault injection (cold
    starts, transient errors, throttling) for any deployment backend,
    countered by ``Session(retry=..., hedge=...)``;
  * :mod:`repro.traffic.slo` — per-scenario success/latency/TTFT/cost
    aggregation against SLO targets (``benchmarks/traffic.py`` writes
    it to ``artifacts/BENCH_traffic.json``), with a per-tenant section
    when the mix is multi-tenant (:mod:`repro.tenancy`).
"""
from .driver import (TrafficDriver, TrafficRecord, TrafficReport,
                     VirtualSemaphore, VirtualTimeline, drive_specs)
from .faults import (FaultInjectingTransport, FaultPlan, FaultStats,
                     FaultyDeployment, register_fault_plan)
from .slo import SLOTarget, aggregate_report, percentile
from .workload import DEFAULT_MIX, Arrival, Scenario, Workload, tenant_mix

__all__ = [
    "Arrival", "DEFAULT_MIX", "FaultInjectingTransport", "FaultPlan",
    "FaultStats", "FaultyDeployment", "SLOTarget", "Scenario",
    "TrafficDriver", "TrafficRecord", "TrafficReport", "VirtualSemaphore",
    "VirtualTimeline", "Workload", "aggregate_report", "drive_specs",
    "percentile", "register_fault_plan", "tenant_mix",
]
