"""Asyncio virtual-clock traffic driver.

One event loop interleaves thousands of in-flight runs — no thread per
run.  The trick: runs are *virtually* timed already (every component
sleeps on a per-run ``VirtualClock``), so executing one is wall-cheap;
what the driver adds is a SHARED timeline.  Each run executes at its
arrival point and its recorded per-step latencies (the ``RunEvent``
timestamps) are then replayed as ``await timeline.sleep(dt)`` — so
concurrent runs interleave step-by-step on the global clock, capacity
limits introduce real queueing delay, and a million-request day replays
in seconds of wall time.

:class:`VirtualTimeline` is a deterministic discrete-event scheduler for
one asyncio loop: coroutines park in ``sleep`` and, when every live task
is parked (in the sleep heap or on a :class:`VirtualSemaphore`), virtual
time jumps to the earliest deadline.  No wall timers are involved, so a
workload's timeline is bit-reproducible run-to-run and process-to-process.

Two modes:

  * **virtual** (default) — replay recorded latencies as above; per-run
    results are bit-identical to serial ``Session.execute`` (each run
    still builds its own World/clients; tested).
  * **real** — wall-clock: runs dispatch into a bounded thread pool at
    (scaled) arrival times; with ``RunSpec.llm = "jax-batched"`` the
    pool's blocked workers cooperatively pump one continuous-batching
    engine (``EngineClient``), so the fan-out shares a decode batch.

Entry points: :func:`drive_specs` (what ``Session.execute_many_async``
wraps) and :class:`TrafficDriver` (workloads, fault stats, SLO records).
"""
from __future__ import annotations

import asyncio
import dataclasses
import heapq
import random
import time
from collections import deque
from typing import List, Optional

from ..core.events import LLMCompleted, RunHedged, ToolRetried
from ..core.metrics import RunResult
from ..durable.resume import billed_cost, resume_run
from .workload import Arrival, Scenario, Workload


# ---------------------------------------------------------------------------
# virtual time for one event loop


class VirtualTimeline:
    """Deterministic virtual clock shared by the tasks of one event loop.

    Tasks must be ``register``-ed (and ``unregister``-ed when done) so
    the timeline knows when *everyone* is parked; only then does time
    advance, to the earliest pending deadline.  A task parked anywhere
    else (a :class:`VirtualSemaphore` waiter) counts via ``_blocked``.
    Runnable-but-not-yet-run tasks keep time frozen — virtual time never
    advances past work that could still happen "now".
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._heap: list = []        # (deadline, seq, future)
        self._seq = 0
        self._live = 0               # registered, unfinished tasks
        self._blocked = 0            # parked outside the sleep heap

    def now(self) -> float:
        return self._t

    def register(self) -> None:
        self._live += 1

    def unregister(self) -> None:
        self._live -= 1
        self._maybe_fire()

    async def sleep(self, dt: float) -> None:
        """Park until virtual ``now() + dt`` (dt <= 0 still parks, at
        the current instant — a cooperative yield point)."""
        fut = asyncio.get_running_loop().create_future()
        heapq.heappush(self._heap, (self._t + max(dt, 0.0), self._seq, fut))
        self._seq += 1
        self._maybe_fire()
        await fut

    def semaphore(self, capacity: int) -> "VirtualSemaphore":
        return VirtualSemaphore(self, capacity)

    def _maybe_fire(self) -> None:
        """If every live task is parked, wake the earliest sleeper (one
        at a time: its continuation may park new work at the same
        instant)."""
        while (self._live > 0 and self._heap
               and len(self._heap) + self._blocked >= self._live):
            deadline, _, fut = heapq.heappop(self._heap)
            if fut.cancelled():
                continue
            self._t = max(self._t, deadline)
            fut.set_result(None)
            break


class VirtualSemaphore:
    """FIFO capacity gate cooperating with the timeline: a parked waiter
    counts as blocked, so virtual time keeps advancing for the runs that
    hold a slot — their elapsed virtual time becomes the waiter's
    queueing delay."""

    def __init__(self, timeline: VirtualTimeline, capacity: int):
        self._tl = timeline
        self._free = capacity
        self._waiters: deque = deque()

    async def acquire(self, tenant: str = "") -> None:
        """``tenant`` is accepted (and ignored) so the plain FIFO gate
        and the tenant-aware :class:`repro.tenancy.FairShareGate` stay
        interchangeable for the driver."""
        if self._free > 0:
            self._free -= 1
            return
        fut = asyncio.get_running_loop().create_future()
        self._waiters.append(fut)
        self._tl._blocked += 1
        self._tl._maybe_fire()
        await fut

    def release(self) -> None:
        if self._waiters:
            fut = self._waiters.popleft()
            self._tl._blocked -= 1   # runnable again, holding the slot
            fut.set_result(None)
        else:
            self._free += 1


# ---------------------------------------------------------------------------
# per-run records


@dataclasses.dataclass
class TrafficRecord:
    """One run on the shared timeline.  ``latency`` is the client-side
    view (arrival -> completion, queueing included); the run-side view is
    ``result.total_latency``."""
    index: int
    scenario: str
    spec: object                 # RunSpec
    arrival: float
    start: float
    end: float
    ttft: Optional[float]        # arrival -> first LLM completion
    result: RunResult            # the FINAL attempt (post restarts)
    crashes: int = 0             # injected platform deaths this run absorbed
    resumes: int = 0             # restarts served from the journal
    sunk_cost: float = 0.0       # billed cost of the dead attempts

    @property
    def queue_wait(self) -> float:
        return self.start - self.arrival

    @property
    def latency(self) -> float:
        return self.end - self.arrival

    @property
    def retries(self) -> int:
        return sum(isinstance(e, ToolRetried)
                   for e in self.result.extras.get("events", ()))

    @property
    def hedges(self) -> int:
        return sum(isinstance(e, RunHedged)
                   for e in self.result.extras.get("events", ()))


@dataclasses.dataclass
class TrafficReport:
    records: List[TrafficRecord]
    virtual_s: float             # timeline span of the whole workload
    wall_s: float                # wall seconds the replay took
    # plan-cache counter deltas for this workload (hits/misses/fallbacks/
    # hit_rate), when the session carries a repro.plans.PlanCache
    plan_cache: Optional[dict] = None

    @property
    def replay_speedup(self) -> float:
        return self.virtual_s / self.wall_s if self.wall_s > 0 else 0.0

    def peak_concurrency(self) -> int:
        edges = []
        for r in self.records:
            edges.append((r.start, 1))
            edges.append((r.end, -1))
        peak = live = 0
        for _, d in sorted(edges):
            live += d
            peak = max(peak, live)
        return peak


# ---------------------------------------------------------------------------
# replaying one run onto the timeline


async def _replay_run(timeline: VirtualTimeline, result: RunResult,
                      arrival: float, skip: int = 0) -> Optional[float]:
    """Advance the shared timeline through the run's recorded per-step
    latencies (event-timestamp deltas, plus the tail to
    ``total_latency``); returns the TTFT relative to ``arrival``.

    ``skip`` drops the first N events from the replay — a resumed run's
    journal-recovered prefix costs the client no time (the durable
    executor serves it from the log), so only the live suffix advances
    the timeline."""
    events = result.extras.get("events") or []
    ttft = None
    if events:
        skipped = 0 < skip <= len(events)
        t_prev = events[skip - 1].t if skipped else events[0].t
        for ev in (events[skip:] if skipped else events):
            dt = ev.t - t_prev
            t_prev = ev.t
            if dt > 0:
                await timeline.sleep(dt)
            if ttft is None and isinstance(ev, LLMCompleted):
                ttft = timeline.now() - arrival
        tail = result.total_latency - (events[-1].t - events[0].t)
    else:
        tail = result.total_latency
    if tail > 0:
        await timeline.sleep(tail)
    return ttft


async def _run_on_timeline(session, timeline: VirtualTimeline,
                           sem: Optional[VirtualSemaphore],
                           index: int, scenario_name: str,
                           spec, restart: str = "none",
                           max_restarts: int = 8,
                           restart_delay_s: float = 0.0) -> TrafficRecord:
    """The shared core of every virtual-mode run: acquire capacity,
    execute, replay the recording, record.  Arrival is the timeline's
    *now* — callers position it (arrival sleep / think time) first.

    ``restart`` is the recovery policy for journaled-but-dead runs
    (aborted results): ``"none"`` leaves the crash as a failed record,
    ``"rerun"`` re-executes from scratch (full re-bill, full re-replay),
    ``"resume"`` continues from the session journal (prefix recovered,
    only the live suffix re-plays on the timeline).  Each dead attempt's
    *billed* cost accumulates into ``sunk_cost``; ``max_restarts`` caps
    the loop."""
    t_arrive = timeline.now()
    if sem is not None:
        await sem.acquire(getattr(spec, "tenant", ""))
    crashes = resumes = 0
    sunk = 0.0
    try:
        t_start = timeline.now()
        result = session.execute(spec)
        ttft = await _replay_run(timeline, result, t_arrive)
        while (restart != "none" and result.extras.get("aborted")
               and crashes < max_restarts):
            crashes += 1
            sunk += billed_cost(result)
            if restart_delay_s > 0:
                await timeline.sleep(restart_delay_s)
            if restart == "resume":
                result = resume_run(session, spec, attempt=crashes)
            else:
                result = session.execute(spec, attempt=crashes)
            info = result.extras.get("resume")
            skip = info.get("replayed_events", 0) if info else 0
            if info:
                resumes += 1
            t = await _replay_run(timeline, result, t_arrive, skip=skip)
            if ttft is None:
                ttft = t
    finally:
        if sem is not None:
            sem.release()
    return TrafficRecord(index, scenario_name, spec, t_arrive, t_start,
                         timeline.now(), ttft, result,
                         crashes=crashes, resumes=resumes, sunk_cost=sunk)


async def _one(session, timeline: VirtualTimeline,
               sem: Optional[VirtualSemaphore],
               arrival: Arrival, restart: str = "none",
               max_restarts: int = 8,
               restart_delay_s: float = 0.0) -> TrafficRecord:
    try:
        await timeline.sleep(arrival.t - timeline.now())
        return await _run_on_timeline(session, timeline, sem, arrival.index,
                                      arrival.scenario.name, arrival.spec,
                                      restart=restart,
                                      max_restarts=max_restarts,
                                      restart_delay_s=restart_delay_s)
    finally:
        timeline.unregister()


async def drive_specs(session, specs: List, arrivals=None,
                      max_concurrency: int = 0,
                      scenario: str = "adhoc") -> List[TrafficRecord]:
    """Interleave ``specs`` on one fresh timeline (the
    ``Session.execute_many_async`` engine).  ``arrivals``: optional
    virtual arrival offsets, default all at t=0."""
    times = list(arrivals) if arrivals is not None else [0.0] * len(specs)
    if len(times) != len(specs):
        raise ValueError(f"{len(times)} arrival times for "
                         f"{len(specs)} specs")
    timeline = VirtualTimeline()
    sem = timeline.semaphore(max_concurrency) if max_concurrency > 0 else None
    wrapped = [Arrival(i, t, Scenario(scenario, s.app, s.instance,
                                      s.pattern, s.deployment, s.llm,
                                      s.priority,
                                      tenant=getattr(s, "tenant", "")), s)
               for i, (t, s) in enumerate(zip(times, specs))]
    for _ in wrapped:
        timeline.register()
    tasks = [asyncio.ensure_future(_one(session, timeline, sem, a))
             for a in wrapped]
    return list(await asyncio.gather(*tasks))


# ---------------------------------------------------------------------------
# the workload driver


class TrafficDriver:
    """Drives a :class:`repro.traffic.workload.Workload` through a
    ``Session``.

    ``mode="virtual"`` replays on a :class:`VirtualTimeline`;
    ``mode="real"`` dispatches into a thread pool at wall-clock arrival
    times compressed by ``time_scale`` (arrival t lands at t/time_scale
    wall seconds) — the mode that exercises the ``jax-batched`` engine
    for real.

    ``restart`` (virtual mode) is the crash-recovery policy applied to
    aborted runs — ``"auto"`` resolves to ``"resume"`` when the session
    carries a :class:`repro.durable.journal.RunJournal` and ``"none"``
    otherwise; ``"rerun"`` restarts crashed runs from scratch (the
    non-durable baseline the durability benchmark prices resume
    against).

    ``tenants`` (virtual mode) turns the capacity gate tenant-aware: a
    :class:`repro.tenancy.TenantRegistry` (or a plain ``{tenant:
    weight}`` dict) makes the driver admit queued runs in weighted
    deficit-round-robin order across tenants
    (:class:`repro.tenancy.FairShareGate`) instead of global FIFO —
    a tenant bursting past its weight queues behind its own backlog
    while other tenants keep their share.  Requires
    ``max_concurrency > 0`` (an unbounded driver has no admission point
    to arbitrate).  The gate of the most recent :meth:`run` is kept on
    ``last_gate`` for its admission log.
    """

    def __init__(self, session=None, max_concurrency: int = 0,
                 mode: str = "virtual", time_scale: float = 1.0,
                 restart: str = "auto", max_restarts: int = 8,
                 restart_delay_s: float = 0.0, tenants=None):
        if mode not in ("virtual", "real"):
            raise ValueError(f"unknown mode {mode!r}")
        # deferred: repro.apps.session imports this module lazily too
        from ..apps.session import Session
        self.session = session if session is not None else Session()
        self.max_concurrency = max_concurrency
        self.mode = mode
        self.time_scale = time_scale
        if restart == "auto":
            restart = ("resume"
                       if getattr(self.session, "journal", None) is not None
                       else "none")
        if restart not in ("none", "rerun", "resume"):
            raise ValueError(f"unknown restart policy {restart!r}")
        self.restart = restart
        self.max_restarts = max_restarts
        self.restart_delay_s = restart_delay_s
        self.tenants = tenants
        self.last_gate = None

    def _gate(self, timeline: VirtualTimeline):
        """Build this workload's capacity gate: tenant-aware fair share
        when ``tenants`` is configured, plain FIFO otherwise."""
        if self.max_concurrency <= 0:
            self.last_gate = None
            return None
        if self.tenants is not None:
            from ..tenancy.fair_share import FairShareGate
            gate = FairShareGate(timeline, self.max_concurrency,
                                 self.tenants)
        else:
            gate = timeline.semaphore(self.max_concurrency)
        self.last_gate = gate
        return gate

    # -- entry point --------------------------------------------------------
    def run(self, workload: Workload) -> TrafficReport:
        t0 = time.perf_counter()
        before = self._plan_stats()
        if self.mode == "real":
            records = asyncio.run(self._drive_real(workload))
            virtual_s = max((r.end for r in records), default=0.0)
        else:
            if workload.arrival == "closed":
                records = asyncio.run(self._drive_closed(workload))
            else:
                records = asyncio.run(self._drive_open(workload))
            virtual_s = max((r.end for r in records), default=0.0)
        return TrafficReport(records, virtual_s,
                             time.perf_counter() - t0,
                             plan_cache=self._plan_delta(before))

    def _plan_stats(self) -> Optional[dict]:
        pc = getattr(self.session, "plan_cache", None)
        return pc.stats() if pc is not None else None

    def _plan_delta(self, before: Optional[dict]) -> Optional[dict]:
        """Plan-cache counter deltas attributable to THIS workload (the
        cache may be shared across sweeps — warm passes report their own
        hit rate, not the lifetime average)."""
        after = self._plan_stats()
        if after is None or before is None:
            return None
        hits = after["hits"] - before["hits"]
        misses = after["misses"] - before["misses"]
        lookups = hits + misses
        return {"entries": after["entries"], "hits": hits,
                "misses": misses,
                "fallbacks": after["fallbacks"] - before["fallbacks"],
                "hit_rate": hits / lookups if lookups else 0.0}

    # -- virtual, open loop --------------------------------------------------
    async def _drive_open(self, workload: Workload) -> List[TrafficRecord]:
        timeline = VirtualTimeline()
        sem = self._gate(timeline)
        arrivals = workload.arrivals()
        for _ in arrivals:
            timeline.register()
        tasks = [asyncio.ensure_future(
                     _one(self.session, timeline, sem, a,
                          restart=self.restart,
                          max_restarts=self.max_restarts,
                          restart_delay_s=self.restart_delay_s))
                 for a in arrivals]
        return list(await asyncio.gather(*tasks))

    # -- virtual, closed loop ------------------------------------------------
    async def _drive_closed(self, workload: Workload) -> List[TrafficRecord]:
        """``users`` virtual users: think (exponential), submit, repeat —
        offered load adapts to observed latency, the classic saturation
        probe."""
        timeline = VirtualTimeline()
        sem = self._gate(timeline)
        # exactly n_requests total: early users absorb the remainder
        base, extra = divmod(workload.n_requests, workload.users)
        counts = [base + (1 if u < extra else 0)
                  for u in range(workload.users)]

        async def user(u: int) -> List[TrafficRecord]:
            rng = random.Random(f"closed/{workload.seed}/{u}")
            out = []
            try:
                for i in range(counts[u]):
                    await timeline.sleep(
                        rng.expovariate(1.0 / workload.think_s))
                    scenario = workload.draw_scenario(rng)
                    seed = workload.spec_seed(u * 1_000 + i)
                    out.append(await _run_on_timeline(
                        self.session, timeline, sem, sum(counts[:u]) + i,
                        scenario.name, scenario.spec(seed),
                        restart=self.restart,
                        max_restarts=self.max_restarts,
                        restart_delay_s=self.restart_delay_s))
            finally:
                timeline.unregister()
            return out

        for _ in range(workload.users):
            timeline.register()
        per_user_records = await asyncio.gather(
            *[asyncio.ensure_future(user(u))
              for u in range(workload.users)])
        return [r for recs in per_user_records for r in recs]

    # -- real (wall-clock) mode ----------------------------------------------
    async def _drive_real(self, workload: Workload) -> List[TrafficRecord]:
        from concurrent.futures import ThreadPoolExecutor
        loop = asyncio.get_running_loop()
        arrivals = workload.arrivals()
        width = self.max_concurrency or 8
        t0 = time.perf_counter()

        def pooled(spec):
            # stamp the start on the WORKER, so time queued for a pool
            # slot shows up as queue_wait, symmetric with virtual mode
            return time.perf_counter() - t0, self.session.execute(spec)

        async def one(pool, a: Arrival) -> TrafficRecord:
            delay = a.t / self.time_scale - (time.perf_counter() - t0)
            if delay > 0:
                await asyncio.sleep(delay)
            t_arrive = time.perf_counter() - t0
            t_start, result = await loop.run_in_executor(pool, pooled, a.spec)
            t_end = time.perf_counter() - t0
            return TrafficRecord(a.index, a.scenario.name, a.spec,
                                 t_arrive, t_start, t_end, None, result)

        with ThreadPoolExecutor(max_workers=width) as pool:
            return list(await asyncio.gather(
                *[one(pool, a) for a in arrivals]))
