"""Application policies for the oracle LLM backend, plus the
orchestrator-level *resilience* policies (retry / hedge).

Each app policy encodes how a gpt-4o-mini-class model *behaves* on one of
the paper's three applications under each of the three patterns — including
the anomalies catalogued in §6 (seeded, so success rates land in the
paper's regimes). The agent frameworks (agentx/react/magentic) stay fully
generic; everything app-specific lives here.

:class:`RetryPolicy` and :class:`HedgePolicy` are what makes the
orchestration *robust* under the fault injection of
:mod:`repro.traffic.faults`: ``Session(retry=..., hedge=...)`` hands them
to every runner, and :meth:`repro.core.runtime.AgentRuntime.invoke`
re-dispatches retryable tool failures (emitting
:class:`repro.core.events.ToolRetried`) and hedges slow calls (emitting
:class:`repro.core.events.RunHedged`).
"""
from __future__ import annotations

import dataclasses
import json
import random
import re
from typing import Dict, List, Optional, Tuple

from .llm import Decision, LLMRequest, ToolCall


# ===========================================================================
# Resilience policies (orchestrator-level, pattern-agnostic)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Re-dispatch tool invocations that fail with a *retryable* error.

    ``max_attempts`` counts the first dispatch: 3 means one call plus up
    to two retries.  Backoff is exponential in virtual time
    (``backoff_s * backoff_mult**(attempt-1)``), billed to the run like
    any other latency.  A result is retryable when it is a
    ``<tool-error ...>`` whose message contains one of ``retry_on`` —
    the markers the fault injector stamps on transient failures; real
    tool errors (unknown tool, bad arguments) never match and are
    surfaced to the agent unchanged, exactly as without a policy.
    """
    max_attempts: int = 3
    backoff_s: float = 0.5
    backoff_mult: float = 2.0
    retry_on: Tuple[str, ...] = ("transient:", "throttled:", "timeout:")

    def is_retryable(self, result: str) -> bool:
        return (result.startswith("<tool-error")
                and any(marker in result for marker in self.retry_on))

    def backoff(self, attempt: int) -> float:
        """Virtual-time backoff after the ``attempt``-th failure (1-based)."""
        return self.backoff_s * (self.backoff_mult ** (attempt - 1))


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """Tail-latency hedging for tool invocations.

    When a call's virtual latency exceeds ``hedge_after_s``, the runtime
    models a backup call issued at that deadline and completes with
    whichever copy finished first (the loser's tail is discarded from
    the clock, its cost is not — both invocations are billed).  Classic
    FaaS cold-start mitigation: the hedge usually lands on a warm
    instance.  ``min_saving_s`` suppresses the hedge when it would not
    shave at least that much off the primary's completion."""
    hedge_after_s: float = 8.0
    min_saving_s: float = 0.0


def _is_remote(deployment: str) -> bool:
    """Whether tools live off-workstation — from the deployment registry's
    capability descriptor when the name is registered, else the historical
    string heuristic (direct policy construction in tests)."""
    try:
        # deferred: the deployment registry lives above the core layer
        from ..faas.deployments import resolve_deployment
        return resolve_deployment(deployment).capabilities.remote
    except KeyError:
        return deployment != "local"


def _last(history: List[Dict], tool: str) -> Optional[str]:
    for h in reversed(history):
        if h["tool"] == tool:
            return h["result"]
    return None


def _all(history: List[Dict], tool: str) -> List[Dict]:
    return [h for h in history if h["tool"] == tool]


class BasePolicy:
    app = "base"

    def __init__(self, world, task: str, deployment: str, seed: int):
        self.world = world
        self.task = task
        self.deployment = deployment
        self.faas = _is_remote(deployment)
        self.seed = seed
        self.rng = random.Random(seed)
        self._anom: Dict[str, bool] = {}

    # -- anomaly sampling (one draw per run per key) ------------------------
    def chance(self, key: str, p: float) -> bool:
        if getattr(self, "saw_cot", False):
            # CoT pre-reasoning (paper §7 future work) makes plans "more
            # context-aware and logical": anomaly rates drop sharply
            p *= 0.2
        if key not in self._anom:
            # each key draws from its own (seed, key)-derived stream, so a
            # draw does not depend on how many OTHER chance() calls came
            # before it — a compiled-plan replay (repro.plans) skips the
            # stage/planner inferences yet must see identical anomalies
            draw = random.Random(
                f"anomaly/{self.app}/{self.seed}/{key}").random()
            self._anom[key] = draw < p
        return self._anom[key]

    # -- storage targets ----------------------------------------------------
    def out_target(self, name: str) -> str:
        return f"s3://dummy-bucket/agent/{name}" if self.faas else name

    def write_call(self, name: str, content: str) -> ToolCall:
        if self.faas:
            return ToolCall("s3", "s3_write",
                            {"uri": self.out_target(name), "content": content})
        return ToolCall("filesystem", "write_file",
                        {"path": self.out_target(name), "content": content})

    @property
    def write_tool_name(self) -> str:
        return "s3_write" if self.faas else "write_file"

    # -- dispatch -------------------------------------------------------
    def decide(self, req: LLMRequest) -> Decision:
        role = req.agent
        if role == "cot_reasoner":
            self.saw_cot = True
            return Decision(text=(
                "Goal: " + self.task[:120] + ". Required tools in order, "
                "with explicit parameters for each step; avoid splitting "
                "the final write into a separate stage; always pass the "
                "document path explicitly; finish by writing the output "
                "file."))
        if role == "stage_generator":
            return self.agentx_stages(req)
        if role == "planner":
            return self.agentx_plan(req)
        if role == "executor":
            return self.agentx_execute(req)
        if role == "react":
            return self.react(req)
        if role == "orchestrator":
            return self.magentic_orchestrate(req)
        if role.endswith("_agent"):
            return self.magentic_specialist(req)
        raise ValueError(f"unknown agent role {role!r}")

    # -- shared magentic orchestration ------------------------------------
    def magentic_orchestrate(self, req: LLMRequest) -> Decision:
        phase = req.meta["phase"]
        if phase in ("facts", "update-facts"):
            return Decision(structured={
                "given_facts": [self.task[:160]],
                "facts_to_lookup": self.facts_to_lookup(),
                "facts_to_derive": ["the final artifact content"],
                "guesses": ["the task is completable with the given team"]})
        if phase in ("plan", "replan"):
            return Decision(structured={"plan": self.magentic_plan(req)})
        if phase == "final":
            return Decision(text=self.final_answer(req))
        raise ValueError(phase)

    # -- overridables -------------------------------------------------------
    def facts_to_lookup(self) -> List[str]:
        return []

    def magentic_plan(self, req: LLMRequest) -> List[str]:
        raise NotImplementedError

    def final_answer(self, req: LLMRequest) -> str:
        return ("The task has been completed. " + self.task[:120])


# ===========================================================================
# Web Exploration (paper §5.3.1)


class WebSearchPolicy(BasePolicy):
    app = "web_search"

    def __init__(self, world, task, deployment, seed):
        super().__init__(world, task, deployment, seed)
        m = re.search(r"Search for (.+?) and summarize", task)
        self.query = m.group(1).strip("'\"") if m else task
        self.artifact = "web_summary.txt"

    # -- content helpers ----------------------------------------------------
    def _urls_from(self, text: str) -> List[str]:
        return re.findall(r"https?://\S+?(?=[\s,\"')\]]|$)", text)

    def _summary_from_chunks(self, chunks: List[str]) -> str:
        body = " ".join(c.replace("<error>", " ")[:620] for c in chunks)
        return (f"Summary of web findings on '{self.query}':\n" + body[:1750])

    def _summary_from_snippets(self, search_json: str) -> str:
        try:
            res = json.loads(search_json)["organic"]
        except Exception:
            res = []
        body = " ".join(f"{r['title']}: {r['snippet']}" for r in res)
        return f"Summary of search results for '{self.query}':\n{body[:1400]}"

    # -- AgentX ---------------------------------------------------------
    def agentx_stages(self, req: LLMRequest) -> Decision:
        if self.faas:
            subs = [f"Search the web for: {self.query}",
                    "Summarize the search results and write them to storage"]
            if self.chance("faas_split_write", 0.35):
                subs = [subs[0], "Summarize the search results",
                        "Write the summary to storage"]
        else:
            subs = [f"Search the web for: {self.query}",
                    "Fetch content from the most relevant URLs",
                    "Summarize the contents and write them into a text file"]
            if self.chance("split_write", 0.3):
                subs = subs[:2] + ["Summarize the fetched contents",
                                   "Write the summary into a text file"]
        return Decision(structured={"sub_tasks": subs})

    def agentx_plan(self, req: LLMRequest) -> Decision:
        stage = req.meta["stages"][req.meta["stage_idx"]].lower()
        summaries = req.meta["summaries"]
        if stage.startswith("search the web"):
            steps = [{"description": "search the web", "tool": "google_search",
                      "params": {"query": self.query, "num_results": 8}}]
            return Decision(structured={"steps": steps,
                                        "tools_needed": ["google_search"]})
        if "fetch" in stage:
            urls = self._urls_from(" ".join(summaries))
            top = 5 if self.chance("fetch_top5", 0.25) else 3
            steps = [{"description": f"fetch {u}", "tool": "fetch",
                      "params": {"url": u}} for u in urls[:top]]
            return Decision(structured={"steps": steps,
                                        "tools_needed": ["fetch"]})
        if "write" in stage and "summar" not in stage:
            steps = [{"description": "write the summary",
                      "tool": self.write_tool_name, "params": {}}]
            return Decision(structured={"steps": steps,
                                        "tools_needed": [self.write_tool_name]})
        # summarize (+maybe write)
        steps = [{"description": "summarize and save",
                  "tool": self.write_tool_name, "params": {}}]
        return Decision(structured={"steps": steps,
                                    "tools_needed": [self.write_tool_name]})

    def agentx_execute(self, req: LLMRequest) -> Decision:
        stage = req.meta["stage"].lower()
        hist = req.meta["stage_history"]
        plan = req.meta["plan"]
        summaries = req.meta["summaries"]
        if stage.startswith("search the web"):
            if not hist:
                return Decision(tool_call=ToolCall(
                    "serper", "google_search",
                    {"query": self.query, "num_results": 8}))
            try:
                res = json.loads(hist[0]["result"])["organic"]
            except Exception:
                res = []
            listing = "; ".join(f"{r['link']} — {r['snippet'][:300]}"
                                for r in res[:8])
            return Decision(structured={
                "execution_results": "Search returned these relevant URLs: "
                + listing, "success": True})
        if "fetch" in stage:
            steps = plan["steps"]
            if len(hist) < len(steps):
                url = steps[len(hist)]["params"]["url"]
                return Decision(tool_call=ToolCall("fetch", "fetch",
                                                   {"url": url}))
            chunks = [h["result"] for h in hist]
            return Decision(structured={
                "execution_results": self._summary_from_chunks(chunks),
                "success": True})
        # summarize / write stages
        summary = next((s for s in reversed(summaries)
                        if "Summary of" in s), None)
        if summary is None:
            src = next((s for s in summaries if "URLs" in s), "")
            body = src.split("URLs:", 1)[-1]
            summary = (f"Summary of web findings on '{self.query}':\n"
                       + body[:1500])
        if "summar" in stage and "write" not in stage:
            # separate-write anomaly: write tool is visible, executor writes
            # anyway; the later write stage duplicates it (paper §6.1)
            if not hist:
                return Decision(tool_call=self.write_call(self.artifact, summary))
            return Decision(structured={"execution_results": summary,
                                        "success": True})
        if self.chance("forget_write", 0.10):
            return Decision(structured={
                "execution_results": "Summarized the findings.",
                "success": True})   # but never wrote the file -> failed run
        if not hist:
            return Decision(tool_call=self.write_call(self.artifact, summary))
        return Decision(structured={
            "execution_results": f"Wrote summary to {self.out_target(self.artifact)}",
            "success": True})

    # -- ReAct ----------------------------------------------------------
    def react(self, req: LLMRequest) -> Decision:
        hist = req.meta["history"]
        search = _last(hist, "google_search")
        if search is None:
            return Decision(tool_call=ToolCall(
                "serper", "google_search",
                {"query": self.query, "num_results": 5}))
        if not self.faas:
            urls = self._urls_from(search)[:5]
            fetches = _all(hist, "fetch")
            per_url: Dict[str, List[Dict]] = {}
            for f in fetches:
                per_url.setdefault(f["args"]["url"], []).append(f)
            for u in urls:
                done = per_url.get(u, [])
                if not done:
                    return Decision(tool_call=ToolCall("fetch", "fetch",
                                                       {"url": u}))
                if "Content truncated" in done[-1]["result"]:
                    return Decision(tool_call=ToolCall(
                        "fetch", "fetch",
                        {"url": u, "start_index": 5000 * len(done)}))
            chunks = [f["result"] for f in fetches]
            summary = self._summary_from_chunks(chunks)
        else:
            # FaaS: default fetch description -> never used (§5.4.2)
            summary = self._summary_from_snippets(search)
        if _last(hist, self.write_tool_name) is None:
            return Decision(tool_call=self.write_call(self.artifact, summary))
        return Decision(text="Final Answer: wrote the summary to "
                        + self.out_target(self.artifact))

    # -- Magentic-One -----------------------------------------------------
    def facts_to_lookup(self) -> List[str]:
        return [f"web content about {self.query}"]

    def magentic_plan(self, req: LLMRequest) -> List[str]:
        fs = "s3" if self.faas else "filesystem"
        plan = [f"serper: search the web for {self.query}",
                "fetch: fetch the most relevant content from the search "
                "result URLs",
                f"{fs}: write the summarized results to a text file"]
        if self.chance("skip_fetch", 0.25):
            plan.pop(1)   # completes without the fetch tool (§6.5)
        return plan

    def magentic_specialist(self, req: LLMRequest) -> Decision:
        server = req.meta["server"]
        hist = req.meta["history"]
        ctx = req.meta["shared_context"]
        if server == "serper":
            if not hist:
                return Decision(tool_call=ToolCall(
                    "serper", "google_search",
                    {"query": self.query, "num_results": 8}))
            # near-raw reflection (minimal summarization, §5.4.4)
            return Decision(structured={"result": hist[0]["result"][:3600],
                                        "done": True})
        if server == "fetch":
            n_target = self.rng.randint(4, 8)
            urls = self._urls_from(" ".join(ctx))[:n_target]
            fetched = {h["args"]["url"] for h in hist}
            for u in urls:
                if u not in fetched:
                    return Decision(tool_call=ToolCall("fetch", "fetch",
                                                       {"url": u}))
            body = " ".join(h["result"][:900] for h in hist)
            return Decision(structured={"result": body[:4200], "done": True})
        # file agent
        if self.chance("mag_no_write", 0.18):
            return Decision(structured={
                "result": "Here is the summary: "
                + self._summary_from_chunks(ctx)[:900],
                "done": True, "task_complete": True})
        if _last(hist, self.write_tool_name) is None:
            summary = self._summary_from_chunks(ctx)
            return Decision(tool_call=self.write_call(self.artifact, summary))
        return Decision(structured={"result": "Summary written to file.",
                                    "done": True, "task_complete": True})

    def final_answer(self, req: LLMRequest) -> str:
        return (f"I searched the web for '{self.query}', summarized the "
                f"findings and saved them to {self.out_target(self.artifact)}.")


# ===========================================================================
# Stock Correlation (paper §5.3.2)


class StockPolicy(BasePolicy):
    app = "stock_correlation"

    def __init__(self, world, task, deployment, seed):
        super().__init__(world, task, deployment, seed)
        m = re.search(r"stock prices of (.+?),? and save it as (\S+?\.png)",
                      task)
        names = m.group(1) if m else ""
        self.filename = m.group(2) if m else "plot.png"
        self.companies = [c.strip() for c in
                          re.split(r",| and ", names) if c.strip()]
        self.artifact = self.filename

    # -- code generation ------------------------------------------------
    def _plot_code(self, data: Dict[str, List[float]], broken: bool = False,
                   dummy: bool = False, no_save: bool = False) -> str:
        lines = ["import matplotlib.pyplot as plt", ""]
        if dummy:
            lines.append("# replace with actual data")
            for tic in (list(data) or ["A", "B", "C"]):
                lines.append(f"plt.plot([100, 101, 102], label='{tic}')")
        else:
            for tic, prices in data.items():
                lines.append(f"{tic} = {json.dumps(prices)}")
                lines.append(f"plt.plot({tic}, label='{tic}')")
        lines += ["plt.title('Historical stock prices')",
                  "plt.xlabel('day')", "plt.ylabel('close')",
                  "plt.legend()", "plt.grid(True)"]
        if not no_save:
            lines.append(f"plt.savefig('{self.out_target(self.filename)}')")
        code = "\n".join(lines)
        if broken:
            code = code.replace("plt.legend()", "plt.legend(")  # SyntaxError
        return code

    def _data_from(self, results: List[str], truncate: int = 0
                   ) -> Dict[str, List[float]]:
        out: Dict[str, List[float]] = {}
        for r in results:
            try:
                d = json.loads(r)
            except (ValueError, TypeError):
                continue
            if isinstance(d, dict) and "ticker" in d and "close" in d:
                close = d["close"]
                out[d["ticker"]] = close[:truncate] if truncate else close
            elif isinstance(d, dict):
                for k, v in d.items():
                    if (isinstance(v, list) and v
                            and all(isinstance(x, (int, float)) for x in v)):
                        out[k] = v[:truncate] if truncate else v
        return out

    # -- AgentX ---------------------------------------------------------
    def agentx_stages(self, req: LLMRequest) -> Decision:
        subs = [f"Get historical stock prices for "
                f"{', '.join(self.companies)}",
                f"Generate a plot of the prices and save it as {self.filename}"]
        if self.chance("extra_process_stage", 0.3):
            subs.insert(1, "Process and consolidate the stock data")
        if self.chance("extra_save_stage", 0.2):
            subs.append(f"Save the plot as {self.filename}")
        return Decision(structured={"sub_tasks": subs})

    def agentx_plan(self, req: LLMRequest) -> Decision:
        stage = req.meta["stages"][req.meta["stage_idx"]].lower()
        if "get historical" in stage:
            steps = [{"description": f"get history for {c}",
                      "tool": "get_stock_history",
                      "params": {"ticker": c}} for c in self.companies]
            return Decision(structured={"steps": steps,
                                        "tools_needed": ["get_stock_history"]})
        if "process" in stage:
            return Decision(structured={
                "steps": [{"description": "consolidate the data", "tool": "",
                           "params": {}}], "tools_needed": []})
        steps = [{"description": "generate and run plotting code",
                  "tool": "execute_python", "params": {}}]
        return Decision(structured={"steps": steps,
                                    "tools_needed": ["execute_python"]})

    def agentx_execute(self, req: LLMRequest) -> Decision:
        stage = req.meta["stage"].lower()
        hist = req.meta["stage_history"]
        summaries = req.meta["summaries"]
        if "get historical" in stage:
            if len(hist) < len(self.companies):
                c = self.companies[len(hist)]
                return Decision(tool_call=ToolCall(
                    "yfinance", "get_stock_history", {"ticker": c}))
            # execution results = the entire tool output (paper §6.1)
            return Decision(structured={
                "execution_results": "\n".join(h["result"] for h in hist),
                "success": True})
        if "process" in stage:
            return Decision(structured={
                "execution_results": "Consolidated the stock data: "
                + " ".join(summaries)[:3000], "success": True})
        if "save the plot" in stage and any("saved plot" in s.lower()
                                            for s in summaries):
            # duplicate save stage (§6.1): re-runs the save code
            if not hist:
                data = self._data_from(summaries[0].splitlines())
                return Decision(tool_call=ToolCall(
                    "code-execution", "execute_python",
                    {"code": self._plot_code(data)}))
            return Decision(structured={"execution_results":
                                        "Plot saved again.", "success": True})
        # plot stage
        data = self._data_from(
            [ln for s in summaries for ln in s.splitlines()])
        attempts = _all(hist, "execute_python")
        stuck = self.chance("stuck_error_loop", 0.18)
        first_broken = self.chance("syntax_error_first", 0.25)
        if not attempts:
            return Decision(tool_call=ToolCall(
                "code-execution", "execute_python",
                {"code": self._plot_code(data, broken=first_broken or stuck)}))
        last = attempts[-1]["result"]
        if '"status": "error"' in last:
            if stuck:
                if len(attempts) >= 4:   # no recovery system -> give up
                    return Decision(structured={
                        "execution_results": "Plot generation kept failing.",
                        "success": False})
                return Decision(tool_call=ToolCall(
                    "code-execution", "execute_python",
                    {"code": self._plot_code(data, broken=True)}))
            return Decision(tool_call=ToolCall(
                "code-execution", "execute_python",
                {"code": self._plot_code(data)}))
        return Decision(structured={
            "execution_results": f"Saved plot to "
            f"{self.out_target(self.filename)} using the full price history.",
            "success": True})

    # -- ReAct ------------------------------------------------------------
    def react(self, req: LLMRequest) -> Decision:
        hist = req.meta["history"]
        got = _all(hist, "get_stock_history")
        if len(got) < len(self.companies):
            return Decision(tool_call=ToolCall(
                "yfinance", "get_stock_history",
                {"ticker": self.companies[len(got)]}))
        data = self._data_from([h["result"] for h in got])
        runs = _all(hist, "execute_python")
        if not runs:
            broken = self.chance("react_syntax_error", 0.3)
            return Decision(tool_call=ToolCall(
                "code-execution", "execute_python",
                {"code": self._plot_code(data, broken=broken)}))
        if '"status": "error"' in runs[-1]["result"]:
            return Decision(tool_call=ToolCall(
                "code-execution", "execute_python",
                {"code": self._plot_code(data)}))
        return Decision(text=f"Final Answer: plotted "
                        f"{', '.join(self.companies)} and saved "
                        f"{self.out_target(self.filename)}")

    # -- Magentic-One ------------------------------------------------------
    def facts_to_lookup(self) -> List[str]:
        return [f"historical prices for {c}" for c in self.companies]

    def magentic_plan(self, req: LLMRequest) -> List[str]:
        return [f"yfinance: collect historical stock data for "
                f"{', '.join(self.companies)}",
                f"code-execution: generate a plot and save it as "
                f"{self.filename}"]

    def magentic_specialist(self, req: LLMRequest) -> Decision:
        server = req.meta["server"]
        hist = req.meta["history"]
        ctx = req.meta["shared_context"]
        if server == "yfinance":
            if len(hist) < len(self.companies):
                return Decision(tool_call=ToolCall(
                    "yfinance", "get_stock_history",
                    {"ticker": self.companies[len(hist)]}))
            if self.chance("mag_no_data", 0.35):
                return Decision(structured={
                    "result": "I have successfully retrieved the data for "
                              "the stocks.", "done": True})
            data = self._data_from([h["result"] for h in hist], truncate=18)
            return Decision(structured={
                "result": "Retrieved stock data (truncated): "
                + json.dumps(data), "done": True})
        if server == "code-execution":
            data = self._data_from(
                [ln for c in ctx for ln in
                 ([c[c.index("{"):]] if "{" in c else [])])
            dummy = not data
            no_save = self.chance("mag_code_no_save", 0.15)
            if not hist:
                return Decision(tool_call=ToolCall(
                    "code-execution", "execute_python",
                    {"code": self._plot_code(data, dummy=dummy,
                                             no_save=no_save)}))
            return Decision(structured={
                "result": ("Generated the plot with available data."
                           if not dummy else
                           "Generated the plot. # replace with actual data"),
                "done": True, "task_complete": True})
        return Decision(structured={"result": "nothing to do", "done": True})

    def final_answer(self, req: LLMRequest) -> str:
        return (f"Plotted the historical prices of "
                f"{', '.join(self.companies)}; saved as "
                f"{self.out_target(self.filename)}.")


# ===========================================================================
# Research Paper Summarization (paper §5.3.3)


class ResearchPolicy(BasePolicy):
    app = "research_report"

    SECTIONS = ("Core Contributions", "Methodology", "Experimental Results",
                "Limitations")

    def __init__(self, world, task, deployment, seed):
        super().__init__(world, task, deployment, seed)
        m = re.search(r"paper titled ['\"]?(.+?)['\"]? and save", task)
        self.title = m.group(1) if m else task
        self.artifact = "report.txt"

    # -- helpers ----------------------------------------------------------
    def _arxiv_id(self, results: List[str]) -> Optional[str]:
        for r in results:
            m = (re.search(r'"id":\s*"(\d{4}\.\d{4,5})"', r)
                 or re.search(r"(\d{4}\.\d{4,5})", r))
            if m:
                return m.group(1)
        return None

    def _saved_path(self, results: List[str]) -> Optional[str]:
        for r in results:
            m = re.search(r'"saved_to":\s*"([^"]+)"', r)
            if m:
                return m.group(1)
        return None

    def _report_from(self, retrievals: List[Dict]) -> str:
        parts = [f"Report on '{self.title}'"]
        for h in retrievals:
            q = h["args"].get("query", "")
            try:
                res = json.loads(h["result"])["results"]
                snip = res[0]["snippet"][:520] if res else "(no snippet)"
            except Exception:
                snip = "(retrieval failed)"
            parts.append(f"## {q}\n{snip}")
        return "\n\n".join(parts)

    def dl_dest(self) -> str:
        return (self.out_target("paper.pdf") if self.faas
                else "/workspace/paper.pdf")

    # -- AgentX -----------------------------------------------------------
    def agentx_stages(self, req: LLMRequest) -> Decision:
        return Decision(structured={"sub_tasks": [
            f"Retrieve the article metadata for '{self.title}'",
            "Download the article",
            "Query the downloaded document for the required sections",
            "Save the summary as a text file"]})

    def agentx_plan(self, req: LLMRequest) -> Decision:
        stage = req.meta["stages"][req.meta["stage_idx"]].lower()
        summaries = req.meta["summaries"]
        if "metadata" in stage:
            steps = [{"description": "search arxiv", "tool": "search_arxiv",
                      "params": {"query": self.title}}]
            tools = ["search_arxiv"]
            if self.chance("redundant_details", 0.4):
                steps.append({"description": "get details",
                              "tool": "get_details", "params": {}})
                tools.append("get_details")
            return Decision(structured={"steps": steps, "tools_needed": tools})
        if "quer" in stage:
            # anomaly (§6.1): tool parameters sometimes not explicitly
            # mentioned — the pdf path is omitted from the plan
            omit = self.chance("plan_omits_path", 0.15)
            path = "" if omit else (self._find_path(summaries) or "")
            steps = [{"description": f"query: {s}",
                      "tool": "document_retriever",
                      "params": ({"query": s} if omit else
                                 {"path": path, "query": s})}
                     for s in self.SECTIONS]
            return Decision(structured={"steps": steps,
                                        "tools_needed": ["document_retriever"]})
        if "download" in stage:
            aid = self._arxiv_id(summaries) or ""
            return Decision(structured={
                "steps": [{"description": "download the pdf",
                           "tool": "download_article",
                           "params": {"arxiv_id": aid,
                                      "dest": self.dl_dest()}}],
                "tools_needed": ["download_article"]})
        return Decision(structured={
            "steps": [{"description": "save the report",
                       "tool": self.write_tool_name, "params": {}}],
            "tools_needed": [self.write_tool_name]})

    def _find_path(self, summaries: List[str]) -> Optional[str]:
        for s in summaries:
            m = re.search(r"(s3://\S+\.pdf|/\S+\.pdf)", s)
            if m:
                return m.group(1)
        return None

    def agentx_execute(self, req: LLMRequest) -> Decision:
        stage = req.meta["stage"].lower()
        hist = req.meta["stage_history"]
        plan = req.meta["plan"]
        summaries = req.meta["summaries"]
        if "metadata" in stage:
            if len(hist) < len(plan["steps"]):
                step = plan["steps"][len(hist)]
                if step["tool"] == "search_arxiv":
                    return Decision(tool_call=ToolCall(
                        "arxiv", "search_arxiv", {"query": self.title}))
                aid = self._arxiv_id([h["result"] for h in hist]) or "0000.0000"
                return Decision(tool_call=ToolCall(
                    "arxiv", "get_details", {"arxiv_id": aid}))
            aid = self._arxiv_id([h["result"] for h in hist])
            return Decision(structured={
                "execution_results": f"The paper '{self.title}' has arXiv id "
                f"{aid}.", "success": True})
        if "quer" in stage:
            steps = plan["steps"]
            if len(hist) < len(steps):
                step = steps[len(hist)]
                params = dict(step["params"])
                if "path" not in params:
                    # executor falls back to a dummy value (§6.1)
                    params["path"] = "document.pdf"
                return Decision(tool_call=ToolCall(
                    "rag", "document_retriever", params))
            retrievals = _all(hist, "document_retriever")
            failed = all("<tool-error" in h["result"] or
                         "retrieval failed" in h["result"]
                         for h in retrievals)
            if failed:
                return Decision(structured={
                    "execution_results": "Could not query the document.",
                    "success": False})   # no recovery system -> run fails
            return Decision(structured={
                "execution_results": self._report_from(retrievals),
                "success": True})
        if "download" in stage:
            if not hist:
                aid = self._arxiv_id(summaries) or ""
                return Decision(tool_call=ToolCall(
                    "arxiv", "download_article",
                    {"arxiv_id": aid, "dest": self.dl_dest()}))
            path = self._saved_path([hist[0]["result"]])
            ok = path is not None
            return Decision(structured={
                "execution_results": (f"Downloaded the article to {path}."
                                      if ok else "Download failed."),
                "success": ok})
        # save stage
        if self.chance("forget_write", 0.08):
            return Decision(structured={
                "execution_results": "Report complete.", "success": True})
        if not hist:
            report = next((s for s in reversed(summaries)
                           if s.startswith("Report on")), "Report (empty)")
            return Decision(tool_call=self.write_call(self.artifact, report))
        return Decision(structured={
            "execution_results": f"Saved report to "
            f"{self.out_target(self.artifact)}.", "success": True})

    # -- ReAct --------------------------------------------------------------
    def react(self, req: LLMRequest) -> Decision:
        hist = req.meta["history"]
        if _last(hist, "search_arxiv") is None:
            return Decision(tool_call=ToolCall("arxiv", "search_arxiv",
                                               {"query": self.title}))
        aid = self._arxiv_id([h["result"] for h in hist]) or ""
        if self.chance("react_redundant_url", 0.3) and \
                _last(hist, "get_article_url") is None:
            return Decision(tool_call=ToolCall("arxiv", "get_article_url",
                                               {"arxiv_id": aid}))
        if _last(hist, "download_article") is None:
            return Decision(tool_call=ToolCall(
                "arxiv", "download_article",
                {"arxiv_id": aid, "dest": self.dl_dest()}))
        path = self._saved_path([h["result"] for h in hist]) or self.dl_dest()
        rets = _all(hist, "document_retriever")
        if len(rets) < len(self.SECTIONS):
            q = self.SECTIONS[len(rets)]
            return Decision(tool_call=ToolCall(
                "rag", "document_retriever", {"path": path, "query": q}))
        if _last(hist, self.write_tool_name) is None:
            report = self._report_from(rets)
            return Decision(tool_call=self.write_call(self.artifact, report))
        return Decision(text="Final Answer: report saved to "
                        + self.out_target(self.artifact))

    # -- Magentic-One --------------------------------------------------------
    def facts_to_lookup(self) -> List[str]:
        return [f"the arXiv entry for '{self.title}'",
                "the paper's key sections"]

    def magentic_plan(self, req: LLMRequest) -> List[str]:
        fs = "s3" if self.faas else "filesystem"
        return [f"arxiv: find and download the paper '{self.title}'",
                "rag: extract Core Contributions, Methodology, Experimental "
                "Results and Limitations",
                f"{fs}: save the summary into a text file",
                f"{fs}: verify the text file exists and has content"]

    def magentic_specialist(self, req: LLMRequest) -> Decision:
        server = req.meta["server"]
        hist = req.meta["history"]
        ctx = req.meta["shared_context"]
        replans = req.meta.get("replans", 0)
        if server == "arxiv":
            if _last(hist, "search_arxiv") is None:
                return Decision(tool_call=ToolCall(
                    "arxiv", "search_arxiv", {"query": self.title}))
            aid = self._arxiv_id([h["result"] for h in hist]) or ""
            premature = self.chance("mag_premature_handoff", 0.2) and replans == 0
            if premature:
                if _last(hist, "get_details") is None:
                    return Decision(tool_call=ToolCall(
                        "arxiv", "get_details", {"arxiv_id": aid}))
                return Decision(structured={
                    "result": f"Found the paper {aid}; details retrieved.",
                    "done": True})   # never downloaded!
            if _last(hist, "download_article") is None:
                return Decision(tool_call=ToolCall(
                    "arxiv", "download_article",
                    {"arxiv_id": aid, "dest": self.dl_dest()}))
            path = self._saved_path([h["result"] for h in hist])
            return Decision(structured={
                "result": f"Downloaded '{self.title}' to {path}.",
                "done": True})
        if server == "rag":
            path = None
            for c in ctx:
                m = re.search(r"(s3://\S+\.pdf|/\S+\.pdf)", c)
                if m:
                    path = m.group(1)
            if path is None:
                path = "C:\\papers\\paper.pdf" \
                    if self.chance("mag_backslash_path", 0.1) else "paper.pdf"
            rets = _all(hist, "document_retriever")
            if rets and "<tool-error" in rets[-1]["result"] \
                    or (rets and "retrieval failed" in rets[-1]["result"]):
                return Decision(structured={
                    "result": "Could not read the document at "
                    f"{path}; the file may not have been downloaded.",
                    "done": True, "replan": True})
            if len(rets) < len(self.SECTIONS):
                q = self.SECTIONS[len(rets)]
                return Decision(tool_call=ToolCall(
                    "rag", "document_retriever", {"path": path, "query": q}))
            return Decision(structured={"result": self._report_from(rets),
                                        "done": True})
        # file agent
        if "verify" in req.meta["subtask"]:
            # the verification step never executes (§6.4)
            return Decision(structured={"result": "Task already complete.",
                                        "done": True, "task_complete": True})
        if self.chance("mag_no_write", 0.15):
            return Decision(structured={
                "result": "The report is ready.", "done": True,
                "task_complete": True})
        if _last(hist, self.write_tool_name) is None:
            report = next((c for c in reversed(ctx)
                           if c.startswith("Report on")), "Report (empty)")
            return Decision(tool_call=self.write_call(self.artifact, report))
        return Decision(structured={"result": "Report saved.", "done": True,
                                    "task_complete": True})

    def final_answer(self, req: LLMRequest) -> str:
        return (f"Generated the report on '{self.title}' and saved it to "
                f"{self.out_target(self.artifact)}.")


class MultiTopicPolicy(BasePolicy):
    """Beyond-paper app: N independent topic searches merged into one
    digest — the independent stages run CONCURRENTLY under
    AgentXRunner(parallel_stages=True) (paper §7 future work)."""

    app = "multi_topic_digest"

    def __init__(self, world, task, deployment, seed):
        super().__init__(world, task, deployment, seed)
        m = re.search(r"Search for (.+?) and write", task)
        raw = m.group(1) if m else task
        self.topics = [t.strip(" '\"") for t in raw.split(";") if t.strip()]
        self.artifact = "digest.txt"

    def stage_groups(self, stages):
        # one stage per topic (independent) + the final merge/write
        return [list(range(len(stages) - 1)), [len(stages) - 1]]

    def agentx_stages(self, req):
        subs = [f"Search and summarize topic: {t}" for t in self.topics]
        subs.append("Merge the topic summaries and write the digest file")
        return Decision(structured={"sub_tasks": subs})

    def agentx_plan(self, req):
        idx = req.meta["stage_idx"]
        if idx < len(self.topics):
            t = self.topics[idx]
            return Decision(structured={
                "steps": [{"description": f"search {t}",
                           "tool": "google_search",
                           "params": {"query": t, "num_results": 6}}],
                "tools_needed": ["google_search"]})
        return Decision(structured={
            "steps": [{"description": "write the digest",
                       "tool": self.write_tool_name, "params": {}}],
            "tools_needed": [self.write_tool_name]})

    def agentx_execute(self, req):
        stage = req.meta["stage"]
        hist = req.meta["stage_history"]
        summaries = req.meta["summaries"]
        if stage.startswith("Search and summarize"):
            topic = stage.split(": ", 1)[1]
            if not hist:
                return Decision(tool_call=ToolCall(
                    "serper", "google_search",
                    {"query": topic, "num_results": 6}))
            try:
                res = json.loads(hist[0]["result"])["organic"]
            except Exception:
                res = []
            body = " ".join(f"{r['title']}: {r['snippet'][:250]}"
                            for r in res[:5])
            return Decision(structured={
                "execution_results": f"Digest section '{topic}': "
                + body[:1200], "success": True})
        if not hist:
            digest = "\n\n".join(s for s in summaries
                                  if s.startswith("Digest section"))
            return Decision(tool_call=self.write_call(self.artifact, digest))
        return Decision(structured={
            "execution_results": "Digest written.", "success": True})

    def react(self, req):
        hist = req.meta["history"]
        searches = _all(hist, "google_search")
        if len(searches) < len(self.topics):
            return Decision(tool_call=ToolCall(
                "serper", "google_search",
                {"query": self.topics[len(searches)], "num_results": 6}))
        if _last(hist, self.write_tool_name) is None:
            body = " ".join(h["result"][:600] for h in searches)
            return Decision(tool_call=self.write_call(
                self.artifact, f"Digest: {body[:2000]}"))
        return Decision(text="Final Answer: digest written")

    def magentic_plan(self, req):
        fs = "s3" if self.faas else "filesystem"
        return [f"serper: search each topic: {'; '.join(self.topics)}",
                f"{fs}: write the digest file"]

    def magentic_specialist(self, req):
        server = req.meta["server"]
        hist = req.meta["history"]
        ctx = req.meta["shared_context"]
        if server == "serper":
            if len(hist) < len(self.topics):
                return Decision(tool_call=ToolCall(
                    "serper", "google_search",
                    {"query": self.topics[len(hist)], "num_results": 6}))
            return Decision(structured={
                "result": " ".join(h["result"][:800] for h in hist)[:3000],
                "done": True})
        if _last(hist, self.write_tool_name) is None:
            return Decision(tool_call=self.write_call(
                self.artifact, "Digest: " + " ".join(ctx)[:2000]))
        return Decision(structured={"result": "written", "done": True,
                                    "task_complete": True})


POLICIES = {
    "web_search": WebSearchPolicy,
    "stock_correlation": StockPolicy,
    "research_report": ResearchPolicy,
    "multi_topic_digest": MultiTopicPolicy,
}
