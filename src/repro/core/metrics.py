"""Run tracing + cost accounting (paper Eq. 1, §5.4).

Every LLM inference and tool invocation is logged with virtual-time
latency and token counts; figures are derived from these traces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

# GPT-4o-mini pricing (paper Eq. 1)
IN_USD_PER_M = 0.15
OUT_USD_PER_M = 0.60


def llm_cost(tin: int, tout: int) -> float:
    return (tin * IN_USD_PER_M + tout * OUT_USD_PER_M) / 1e6


@dataclasses.dataclass
class LLMEvent:
    agent: str
    input_tokens: int
    output_tokens: int
    latency: float
    t: float

    @property
    def cost(self) -> float:
        return llm_cost(self.input_tokens, self.output_tokens)


@dataclasses.dataclass
class ToolEvent:
    server: str
    tool: str
    latency: float
    ok: bool
    t: float
    # call arguments and (truncated) result — optional so pre-plan wire
    # payloads still deserialize; populated by AgentRuntime.invoke so a
    # trace is self-contained for plan compilation (repro.plans)
    args: Optional[Dict[str, Any]] = None
    result: Optional[str] = None


@dataclasses.dataclass
class FrameworkEvent:
    what: str
    latency: float
    t: float


@dataclasses.dataclass
class Trace:
    llm_events: List[LLMEvent] = dataclasses.field(default_factory=list)
    tool_events: List[ToolEvent] = dataclasses.field(default_factory=list)
    framework_events: List[FrameworkEvent] = dataclasses.field(default_factory=list)

    # -- aggregates ---------------------------------------------------------
    @property
    def input_tokens(self) -> int:
        return sum(e.input_tokens for e in self.llm_events)

    @property
    def output_tokens(self) -> int:
        return sum(e.output_tokens for e in self.llm_events)

    @property
    def llm_cost(self) -> float:
        return llm_cost(self.input_tokens, self.output_tokens)

    @property
    def llm_latency(self) -> float:
        return sum(e.latency for e in self.llm_events)

    @property
    def tool_latency(self) -> float:
        return sum(e.latency for e in self.tool_events)

    @property
    def framework_latency(self) -> float:
        return sum(e.latency for e in self.framework_events)

    @property
    def agent_invocations(self) -> int:
        return len(self.llm_events)

    @property
    def tool_invocations(self) -> int:
        return len(self.tool_events)

    def agent_breakdown(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.llm_events:
            out[e.agent] = out.get(e.agent, 0) + 1
        return out

    def tool_breakdown(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.tool_events:
            out[e.tool] = out.get(e.tool, 0) + 1
        return out


@dataclasses.dataclass
class RunResult:
    app: str
    instance: str
    pattern: str
    deployment: str           # local | faas | faas-mono
    success: bool
    total_latency: float
    trace: Trace
    artifact_path: Optional[str] = None
    artifact: Optional[str] = None
    faas_cost: float = 0.0
    failure_reason: str = ""
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def total_cost(self) -> float:
        return self.trace.llm_cost + self.faas_cost
