"""LLM backend abstraction.

The paper's "brain" is OpenAI gpt-4o-mini. Offline we provide:

  - ``OracleLLMBackend``: a deterministic, seeded stand-in. The agent
    frameworks build *real prompt text* (system + history + tool
    descriptions) exactly as they would for an API model — that text drives
    token/cost/latency accounting — while the decision itself comes from an
    application policy (``repro.core.policies``) with seeded anomaly
    injection calibrated to §6 of the paper. The structured ``meta`` field
    carries the same information as the prompt text in parsed form so the
    policy does not have to NLP-parse its own prompt.

  - ``JaxLLMBackend``: wraps the real JAX serving engine
    (``repro.serving``): every completion actually runs prefill+decode for
    the accounted token counts on a ModelConfig from the zoo, while
    delegating decision content to the oracle policy. Its endpoint is
    anything exposing ``generate(prompt, max_new_tokens)`` — a plain
    ``Engine`` (one unbatched generate per call) or an ``EngineClient``
    (completions multiplexed onto the continuous-batching scheduler's
    slot batch).

Runs select their backend by *registry name*: ``RunSpec.llm`` resolves
through ``@register_llm_backend`` (:mod:`repro.serving.api`; built-ins
``oracle``, ``jax``, ``jax-batched``) — symmetric with the pattern and
deployment registries, so ``Session`` never branches on a backend name.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from ..data.tokenizer import CountTokenizer
from ..env.world import World
from .metrics import LLMEvent, Trace
from .schema import Schema


@dataclasses.dataclass
class ToolCall:
    server: str
    tool: str
    args: Dict[str, Any]


@dataclasses.dataclass
class Decision:
    """What the model 'decided': exactly one of the fields is set."""
    tool_call: Optional[ToolCall] = None
    structured: Optional[Dict[str, Any]] = None
    text: Optional[str] = None

    def render(self) -> str:
        if self.tool_call is not None:
            return json.dumps({"tool": self.tool_call.tool,
                               "arguments": self.tool_call.args})
        if self.structured is not None:
            return json.dumps(self.structured)
        return self.text or ""


@dataclasses.dataclass
class LLMRequest:
    agent: str
    system: str
    messages: List[Dict[str, str]]
    tools: List[Any] = dataclasses.field(default_factory=list)  # ToolHandle
    schema: Optional[Schema] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def prompt_tokens(self) -> int:
        n = CountTokenizer.count(self.system)
        for m in self.messages:
            n += CountTokenizer.count(m.get("content", "")) + 4
        for t in self.tools:
            n += CountTokenizer.count(t.describe()) + 6
        if self.schema is not None:
            n += CountTokenizer.count(self.schema.describe())
        return n


@dataclasses.dataclass
class LLMResponse:
    decision: Decision
    input_tokens: int
    output_tokens: int
    latency: float


class LLMBackend:
    def complete(self, request: LLMRequest) -> LLMResponse:
        raise NotImplementedError


class OracleLLMBackend(LLMBackend):
    def __init__(self, world: World, policy, trace: Optional[Trace] = None):
        self.world = world
        self.policy = policy
        self.trace = trace if trace is not None else Trace()

    def complete(self, request: LLMRequest) -> LLMResponse:
        tin = request.prompt_tokens()
        decision = self.policy.decide(request)
        out_text = decision.render()
        tout = max(CountTokenizer.count(out_text), 1)
        latency = self.world.latency.llm_latency(tin, tout)
        self.world.clock.sleep(latency)
        if decision.structured is not None and request.schema is not None:
            request.schema.validate(decision.structured)
        self.trace.llm_events.append(
            LLMEvent(request.agent, tin, tout, latency, self.world.clock.now()))
        return LLMResponse(decision, tin, tout, latency)


class JaxLLMBackend(LLMBackend):
    """Real JAX model in the loop: per completion, runs engine.generate for
    the same output-token budget the oracle decision implies.

    ``priority`` (from ``RunSpec.priority``) rides along on every
    completion: against an ``EngineClient`` endpoint it steers the
    continuous-batching scheduler's admission queue and slot preemption,
    so a latency-sensitive run's completions jump ahead of bulk
    traffic.  ``tenant`` (from ``RunSpec.tenant``) rides along the same
    way: under fair-share admission the scheduler queues the completion
    with its tenant's peers (:mod:`repro.tenancy.fair_share`)."""

    def __init__(self, world: World, policy, engine,
                 trace: Optional[Trace] = None, max_gen: int = 16,
                 priority: int = 0, tenant: str = ""):
        self.world = world
        self.policy = policy
        self.engine = engine
        self.max_gen = max_gen
        self.priority = priority
        self.tenant = tenant
        self.trace = trace if trace is not None else Trace()

    def complete(self, request: LLMRequest) -> LLMResponse:
        tin = request.prompt_tokens()
        decision = self.policy.decide(request)
        out_text = decision.render()
        tout = max(CountTokenizer.count(out_text), 1)
        prompt = request.system + "\n" + "\n".join(
            m.get("content", "") for m in request.messages)
        # real forward passes (prefill + decode) on the JAX engine
        self.engine.generate(prompt[-512:],
                             max_new_tokens=min(tout, self.max_gen),
                             priority=self.priority, tenant=self.tenant)
        latency = self.world.latency.llm_latency(tin, tout)
        self.world.clock.sleep(latency)
        self.trace.llm_events.append(
            LLMEvent(request.agent, tin, tout, latency, self.world.clock.now()))
        return LLMResponse(decision, tin, tout, latency)
