"""Structured-output schema system (paper §3.1).

The paper gives each agent "an output schema that defines the structure of
the output the agent should produce ... provided as a Python object that
includes attributes with a data type and description" (pydantic there; a
dependency-free equivalent here). Schemas ground LLM output to a
deterministic structure that the execution flow parses.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    type: str            # "str" | "bool" | "int" | "list[str]" | "list[dict]"
    description: str


@dataclasses.dataclass(frozen=True)
class Schema:
    name: str
    fields: tuple

    def describe(self) -> str:
        lines = [f"Respond with JSON matching schema {self.name}:"]
        for f in self.fields:
            lines.append(f"  {f.name} ({f.type}): {f.description}")
        return "\n".join(lines)

    def validate(self, obj: Dict[str, Any]) -> Dict[str, Any]:
        checkers = {
            "str": lambda v: isinstance(v, str),
            "bool": lambda v: isinstance(v, bool),
            "int": lambda v: isinstance(v, int) and not isinstance(v, bool),
            "list[str]": lambda v: isinstance(v, list)
            and all(isinstance(x, str) for x in v),
            "list[dict]": lambda v: isinstance(v, list)
            and all(isinstance(x, dict) for x in v),
        }
        for f in self.fields:
            if f.name not in obj:
                raise SchemaError(f"{self.name}: missing field {f.name!r}")
            if not checkers[f.type](obj[f.name]):
                raise SchemaError(
                    f"{self.name}: field {f.name!r} is not {f.type}: "
                    f"{obj[f.name]!r}")
        return obj

    def dumps(self, obj: Dict[str, Any]) -> str:
        return json.dumps(self.validate(obj))


class SchemaError(ValueError):
    pass


# --- the schemas used by the AgentX pattern (paper §3) ---------------------

STAGE_SCHEMA = Schema("StageList", (
    Field("sub_tasks", "list[str]", "The list of sub tasks for the task"),
))

PLAN_SCHEMA = Schema("Plan", (
    Field("steps", "list[dict]",
          "Ordered steps; each has description, tool, params"),
    Field("tools_needed", "list[str]",
          "Names of the only tools the executor should see"),
))

REFLECTION_SCHEMA = Schema("Reflection", (
    Field("execution_results", "str",
          "Summary of only the relevant information from this stage to be "
          "passed to future stages"),
    Field("success", "bool", "Whether the plan executed successfully"),
))

# Magentic-One orchestrator artifacts
FACT_SHEET_SCHEMA = Schema("FactSheet", (
    Field("given_facts", "list[str]", "Facts given in the task"),
    Field("facts_to_lookup", "list[str]", "Facts to look up"),
    Field("facts_to_derive", "list[str]", "Facts to derive"),
    Field("guesses", "list[str]", "Educated guesses"),
))

LEDGER_PLAN_SCHEMA = Schema("LedgerPlan", (
    Field("plan", "list[str]", "Ordered delegation plan across the team"),
))
