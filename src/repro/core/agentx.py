"""The AgentX workflow pattern (paper §3, Fig. 1c).

Stage Generation Agent -> per stage: Planner Agent (tool filtering) ->
Execution Agent (tool-call loop + reflection/summarization). Only the
consolidated ``execution_results`` summary crosses stage boundaries
(active context optimization, §3.5) — the raw tool outputs stay inside the
stage's context window.

Plumbing (tool registry, validated invocation, overhead accounting, event
stream) lives in :class:`repro.core.runtime.AgentRuntime`; this module is
control flow only.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from .llm import LLMRequest
from .events import PlanProduced, StageCompleted, StageStarted
from .runtime import (AgentRuntime, PatternConfig, RunOutcome,
                      register_pattern)
from .schema import PLAN_SCHEMA, REFLECTION_SCHEMA, STAGE_SCHEMA

STAGE_SYSTEM = (
    "You decompose a user task into the least number of sub-tasks required "
    "for an LLM agent with access to MCP tools to complete it. Combine "
    "similar or related sub-tasks into a single sub-task when possible, "
    "while ensuring each sub-task succeeds. You are given the MCP tool "
    "descriptions of the environment; create the sequence of stages that "
    "achieves the objective with least effort.")

PLANNER_SYSTEM = (
    "You create a detailed plan for the given stage: a sequence of steps "
    "with their description and the exact tool and tool parameters to use. "
    "Avoid redundancy: do not plan work for already-completed or future "
    "stages. Expose only the necessary tools to the execution agent.")

EXECUTOR_SYSTEM = "Execute the following plan:"

COT_SYSTEM = (
    "Think step by step about the task before acting: restate the goal, "
    "identify the required tools and their order, and note pitfalls "
    "(missing parameters, redundant stages, forgotten writes).")


@register_pattern("agentx-cot-parallel", cot=True, parallel_stages=True,
                  rank=23)
@register_pattern("agentx-parallel", parallel_stages=True, rank=22)
@register_pattern("agentx-cot", cot=True, rank=21)
@register_pattern("agentx", tags=("paper",), rank=20)
class AgentXRunner(AgentRuntime):
    """Framework-independent AgentX implementation (paper: 'a Python
    framework consisting of modules for the different agent types and an
    orchestrator between the agents').

    ``cot`` / ``parallel_stages`` knobs implement the paper's §7
    future-work items: a CoT reasoning inference before stage generation
    and planning, and concurrent execution of independent stages."""

    pattern = "agentx"
    default_config = PatternConfig(max_steps=14, overhead_local_s=0.18,
                                   overhead_faas_s=0.16)

    # ------------------------------------------------------------------
    def _cot(self, task: str, about: str) -> str:
        resp = self.complete(LLMRequest(
            agent="cot_reasoner", system=COT_SYSTEM,
            messages=[{"role": "user", "content": f"Task: {task}\n"
                       f"About to: {about}"}],
            meta={"task": task, "about": about}))
        return resp.decision.text or ""

    def _run(self, task: str) -> RunOutcome:
        cot = self.config.cot
        tool_text = "\n".join(t.describe() for t in self.tools)
        cot_note = self._cot(task, "decompose the task into stages") \
            if cot else ""
        self.overhead("stage-dispatch")
        stage_resp = self.complete(LLMRequest(
            agent="stage_generator", system=STAGE_SYSTEM,
            messages=[{"role": "user",
                       "content": (f"Reasoning: {cot_note}\n" if cot_note
                                   else "")
                       + f"Task: {task}\nAvailable tools:\n{tool_text}"}],
            tools=self.tools, schema=STAGE_SCHEMA,
            meta={"task": task, "cot": cot}))
        stages = stage_resp.decision.structured["sub_tasks"]
        groups = self._stage_groups(stages)

        summaries: List[str] = []
        stage_success = True
        for group in groups:
            t0 = self.world.clock.now()
            durations = []
            for idx in group:
                self.world.clock.reset(t0)
                ok = self._run_stage(task, stages, idx, summaries)
                durations.append(self.world.clock.now() - t0)
                if not ok:
                    stage_success = False
            # independent stages within a group execute concurrently:
            # wall time is the max branch, not the sum
            self.world.clock.reset(t0 + max(durations))
            if not stage_success:
                break

        return RunOutcome(completed=stage_success, data={
            "stages": stages, "summaries": summaries,
            "parallel_groups": [list(g) for g in groups]})

    def _stage_groups(self, stages):
        if self.config.parallel_stages:
            grouper = getattr(self.backend, "policy", None)
            grouper = getattr(grouper, "stage_groups", None)
            if grouper is not None:
                return grouper(stages)
        return [[i] for i in range(len(stages))]

    def _run_stage(self, task, stages, idx, summaries) -> bool:
        cot = self.config.cot
        stage = stages[idx]
        self.emit(StageStarted(t=self.now(), index=idx, name=stage))
        cot_note = self._cot(task, f"plan the stage: {stage}") if cot else ""
        self.overhead("plan-dispatch")
        plan_resp = self.complete(LLMRequest(
            agent="planner", system=PLANNER_SYSTEM,
            messages=[
                {"role": "user", "content":
                 (f"Reasoning: {cot_note}\n" if cot_note else "")
                 + f"Task: {task}\nCompleted stages: "
                 f"{json.dumps(stages[:idx])}\nCurrent stage: {stage}\n"
                 f"Future stages: {json.dumps(stages[idx + 1:])}\n"
                 f"Context from completed stages:\n"
                 + "\n".join(summaries)},
            ],
            tools=self.tools, schema=PLAN_SCHEMA,
            meta={"task": task, "stages": stages, "stage_idx": idx,
                  "summaries": summaries, "cot": cot}))
        plan = plan_resp.decision.structured
        self.emit(PlanProduced(t=self.now(), index=idx, plan=plan))
        filtered = [t for t in self.tools if t.name in plan["tools_needed"]]

        stage_history: List[Dict] = []
        reflection: Optional[Dict] = None
        for _ in range(self.config.max_steps):
            history_text = "\n".join(
                f"[{h['tool']}] -> {h['result'][:2000]}"
                for h in stage_history)
            exec_resp = self.complete(LLMRequest(
                agent="executor", system=EXECUTOR_SYSTEM,
                messages=[
                    {"role": "user", "content":
                     f"{json.dumps(plan['steps'])}\n"
                     f"Context: {' '.join(summaries)}\n"
                     f"Tool results so far:\n{history_text}"},
                ],
                tools=filtered, schema=REFLECTION_SCHEMA,
                meta={"task": task, "stage": stage, "stage_idx": idx,
                      "plan": plan, "stage_history": stage_history,
                      "summaries": summaries, "cot": cot}))
            d = exec_resp.decision
            if d.tool_call is not None:
                result = self.invoke(d.tool_call)
                stage_history.append({"tool": d.tool_call.tool,
                                      "args": d.tool_call.args,
                                      "result": result})
            else:
                reflection = d.structured
                break
        if reflection is None:
            # executor never produced a reflection: stuck in a loop —
            # AgentX has no dedicated recovery system (paper §6.1)
            self.emit(StageCompleted(t=self.now(), index=idx, success=False))
            return False
        self.reflect(idx, reflection)
        summaries.append(reflection["execution_results"])
        success = bool(reflection["success"])
        self.emit(StageCompleted(t=self.now(), index=idx, success=success))
        return success
