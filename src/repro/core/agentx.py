"""The AgentX workflow pattern (paper §3, Fig. 1c).

Stage Generation Agent -> per stage: Planner Agent (tool filtering) ->
Execution Agent (tool-call loop + reflection/summarization). Only the
consolidated ``execution_results`` summary crosses stage boundaries
(active context optimization, §3.5) — the raw tool outputs stay inside the
stage's context window.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from ..env.clock import Stopwatch
from ..env.world import World
from ..mcp.client import McpClient, ToolHandle
from .llm import Decision, LLMBackend, LLMRequest, ToolCall
from .metrics import FrameworkEvent, ToolEvent, Trace
from .schema import PLAN_SCHEMA, REFLECTION_SCHEMA, STAGE_SCHEMA

STAGE_SYSTEM = (
    "You decompose a user task into the least number of sub-tasks required "
    "for an LLM agent with access to MCP tools to complete it. Combine "
    "similar or related sub-tasks into a single sub-task when possible, "
    "while ensuring each sub-task succeeds. You are given the MCP tool "
    "descriptions of the environment; create the sequence of stages that "
    "achieves the objective with least effort.")

PLANNER_SYSTEM = (
    "You create a detailed plan for the given stage: a sequence of steps "
    "with their description and the exact tool and tool parameters to use. "
    "Avoid redundancy: do not plan work for already-completed or future "
    "stages. Expose only the necessary tools to the execution agent.")

EXECUTOR_SYSTEM = "Execute the following plan:"

COT_SYSTEM = (
    "Think step by step about the task before acting: restate the goal, "
    "identify the required tools and their order, and note pitfalls "
    "(missing parameters, redundant stages, forgotten writes).")

MAX_STEPS_PER_STAGE = 14
FRAMEWORK_OVERHEAD_S = {"local": 0.18, "faas": 0.16}


class AgentXRunner:
    """Framework-independent AgentX implementation (paper: 'a Python
    framework consisting of modules for the different agent types and an
    orchestrator between the agents')."""

    pattern = "agentx"

    def __init__(self, backend: LLMBackend, clients: Dict[str, McpClient],
                 world: World, trace: Trace, deployment: str = "local",
                 cot: bool = False, parallel_stages: bool = False):
        """cot / parallel_stages implement the paper's §7 future-work items:
        a CoT reasoning inference before stage generation and planning, and
        concurrent execution of independent stages."""
        self.backend = backend
        self.clients = clients
        self.world = world
        self.trace = trace
        self.deployment = deployment
        self.cot = cot
        self.parallel_stages = parallel_stages
        self.tools: List[ToolHandle] = []
        self.tool_server: Dict[str, str] = {}
        for server, client in clients.items():
            for h in client.list_tools():
                self.tools.append(h)
                self.tool_server[h.name] = server

    # ------------------------------------------------------------------
    def _overhead(self, what: str):
        dt = FRAMEWORK_OVERHEAD_S["faas" if self.deployment != "local" else "local"]
        self.world.clock.sleep(dt)
        self.trace.framework_events.append(
            FrameworkEvent(what, dt, self.world.clock.now()))

    def _invoke(self, call: ToolCall) -> str:
        server = call.server or self.tool_server.get(call.tool, "")
        client = self.clients.get(server)
        with Stopwatch(self.world.clock) as sw:
            if client is None or call.tool not in {h.name for h in self.tools}:
                result = f"<tool-error unknown tool {call.tool!r}>"
            else:
                result = client.call_tool(call.tool, call.args)
        ok = not result.startswith("<tool-error")
        self.trace.tool_events.append(ToolEvent(server, call.tool, sw.elapsed,
                                                ok, self.world.clock.now()))
        return result

    # ------------------------------------------------------------------
    def _cot(self, task: str, about: str) -> str:
        resp = self.backend.complete(LLMRequest(
            agent="cot_reasoner", system=COT_SYSTEM,
            messages=[{"role": "user", "content": f"Task: {task}\n"
                       f"About to: {about}"}],
            meta={"task": task, "about": about}))
        return resp.decision.text or ""

    def run(self, task: str) -> Dict:
        tool_text = "\n".join(t.describe() for t in self.tools)
        cot_note = self._cot(task, "decompose the task into stages") \
            if self.cot else ""
        self._overhead("stage-dispatch")
        stage_resp = self.backend.complete(LLMRequest(
            agent="stage_generator", system=STAGE_SYSTEM,
            messages=[{"role": "user",
                       "content": (f"Reasoning: {cot_note}\n" if cot_note
                                   else "")
                       + f"Task: {task}\nAvailable tools:\n{tool_text}"}],
            tools=self.tools, schema=STAGE_SCHEMA,
            meta={"task": task, "cot": self.cot}))
        stages = stage_resp.decision.structured["sub_tasks"]
        groups = self._stage_groups(stages)

        summaries: List[str] = []
        stage_success = True
        for group in groups:
            t0 = self.world.clock.now()
            durations = []
            for idx in group:
                self.world.clock.reset(t0)
                ok = self._run_stage(task, stages, idx, summaries)
                durations.append(self.world.clock.now() - t0)
                if not ok:
                    stage_success = False
            # independent stages within a group execute concurrently:
            # wall time is the max branch, not the sum
            self.world.clock.reset(t0 + max(durations))
            if not stage_success:
                break

        return {"stages": stages, "summaries": summaries,
                "completed": stage_success,
                "parallel_groups": [list(g) for g in groups]}

    def _stage_groups(self, stages):
        if self.parallel_stages:
            grouper = getattr(self.backend, "policy", None)
            grouper = getattr(grouper, "stage_groups", None)
            if grouper is not None:
                return grouper(stages)
        return [[i] for i in range(len(stages))]

    def _run_stage(self, task, stages, idx, summaries) -> bool:
        stage = stages[idx]
        if True:
            cot_note = self._cot(task, f"plan the stage: {stage}") \
                if self.cot else ""
            self._overhead("plan-dispatch")
            plan_resp = self.backend.complete(LLMRequest(
                agent="planner", system=PLANNER_SYSTEM,
                messages=[
                    {"role": "user", "content":
                     (f"Reasoning: {cot_note}\n" if cot_note else "")
                     + f"Task: {task}\nCompleted stages: "
                     f"{json.dumps(stages[:idx])}\nCurrent stage: {stage}\n"
                     f"Future stages: {json.dumps(stages[idx + 1:])}\n"
                     f"Context from completed stages:\n"
                     + "\n".join(summaries)},
                ],
                tools=self.tools, schema=PLAN_SCHEMA,
                meta={"task": task, "stages": stages, "stage_idx": idx,
                      "summaries": summaries, "cot": self.cot}))
            plan = plan_resp.decision.structured
            filtered = [t for t in self.tools if t.name in plan["tools_needed"]]

            stage_history: List[Dict] = []
            reflection: Optional[Dict] = None
            for _ in range(MAX_STEPS_PER_STAGE):
                history_text = "\n".join(
                    f"[{h['tool']}] -> {h['result'][:2000]}"
                    for h in stage_history)
                exec_resp = self.backend.complete(LLMRequest(
                    agent="executor", system=EXECUTOR_SYSTEM,
                    messages=[
                        {"role": "user", "content":
                         f"{json.dumps(plan['steps'])}\n"
                         f"Context: {' '.join(summaries)}\n"
                         f"Tool results so far:\n{history_text}"},
                    ],
                    tools=filtered, schema=REFLECTION_SCHEMA,
                    meta={"task": task, "stage": stage, "stage_idx": idx,
                          "plan": plan, "stage_history": stage_history,
                          "summaries": summaries, "cot": self.cot}))
                d = exec_resp.decision
                if d.tool_call is not None:
                    result = self._invoke(d.tool_call)
                    stage_history.append({"tool": d.tool_call.tool,
                                          "args": d.tool_call.args,
                                          "result": result})
                else:
                    reflection = d.structured
                    break
            if reflection is None:
                # executor never produced a reflection: stuck in a loop —
                # AgentX has no dedicated recovery system (paper §6.1)
                return False
            summaries.append(reflection["execution_results"])
            return bool(reflection["success"])
