"""Typed run-event stream emitted by every workflow pattern.

All patterns execute through :class:`repro.core.runtime.AgentRuntime`,
which emits one :class:`RunEvent` per orchestration step (stage dispatch,
plan, tool invocation, reflection, ...). Observers — the experiment
harness, ``benchmarks/figures.py``, the serving-side
:class:`repro.serving.engine.RunMonitor` — subscribe via
``Session(on_event=...)`` or ``AgentRuntime.subscribe`` and see runs
*live* instead of post-hoc.

``Trace`` is derived from the stream: :func:`derive_trace` rebuilds the
full accounting trace (LLM / tool / framework events) from an event list,
and the runtime keeps its ``Trace`` in sync by reducing every emitted
event into it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from .metrics import FrameworkEvent, LLMEvent, ToolEvent, Trace


@dataclasses.dataclass(frozen=True)
class RunEvent:
    """Base class: ``t`` is the virtual-clock timestamp of emission."""
    t: float


@dataclasses.dataclass(frozen=True)
class RunStarted(RunEvent):
    pattern: str
    task: str


@dataclasses.dataclass(frozen=True)
class StageStarted(RunEvent):
    index: int
    name: str


@dataclasses.dataclass(frozen=True)
class PlanProduced(RunEvent):
    index: int
    plan: Any


@dataclasses.dataclass(frozen=True)
class LLMCompleted(RunEvent):
    event: LLMEvent


@dataclasses.dataclass(frozen=True)
class ToolInvoked(RunEvent):
    event: ToolEvent


@dataclasses.dataclass(frozen=True)
class OverheadIncurred(RunEvent):
    event: FrameworkEvent


@dataclasses.dataclass(frozen=True)
class ReflectionEmitted(RunEvent):
    index: int
    reflection: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class StageCompleted(RunEvent):
    index: int
    success: bool


@dataclasses.dataclass(frozen=True)
class RunCompleted(RunEvent):
    completed: bool
    data: Dict[str, Any]


def reduce_into_trace(event: RunEvent, trace: Trace) -> None:
    """Fold one event into a Trace. ``LLMCompleted`` is a no-op because the
    LLM backend appends to the shared Trace itself (it also serves callers
    that bypass the runtime)."""
    if isinstance(event, ToolInvoked):
        trace.tool_events.append(event.event)
    elif isinstance(event, OverheadIncurred):
        trace.framework_events.append(event.event)


def derive_trace(events: List[RunEvent]) -> Trace:
    """Rebuild the full accounting Trace from an event stream."""
    trace = Trace()
    for ev in events:
        if isinstance(ev, LLMCompleted):
            trace.llm_events.append(ev.event)
        else:
            reduce_into_trace(ev, trace)
    return trace
