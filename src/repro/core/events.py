"""Typed run-event stream emitted by every workflow pattern.

All patterns execute through :class:`repro.core.runtime.AgentRuntime`,
which emits one :class:`RunEvent` per orchestration step (stage dispatch,
plan, tool invocation, reflection, ...). Observers — the experiment
harness, ``benchmarks/figures.py``, the serving-side
:class:`repro.serving.engine.RunMonitor` — subscribe via
``Session(on_event=...)`` or ``AgentRuntime.subscribe`` and see runs
*live* instead of post-hoc.

``Trace`` is derived from the stream: :func:`derive_trace` rebuilds the
full accounting trace (LLM / tool / framework events) from an event list,
and the runtime keeps its ``Trace`` in sync by reducing every emitted
event into it.

Events also cross process boundaries: :func:`to_wire` / :func:`from_wire`
serialize any event to a JSON-safe dict and back, so FaaS / A2A response
envelopes can carry the full event stream of a remotely executed run and
a local observer (e.g. ``RunMonitor``) sees exactly what an in-process
subscriber would.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from .metrics import FrameworkEvent, LLMEvent, ToolEvent, Trace


@dataclasses.dataclass(frozen=True)
class RunEvent:
    """Base class: ``t`` is the virtual-clock timestamp of emission."""
    t: float


@dataclasses.dataclass(frozen=True)
class RunStarted(RunEvent):
    """``tenant`` is the principal the run is billed to (multi-tenant
    serving, :mod:`repro.tenancy`); ``""`` is the single default tenant
    — pre-tenancy wire payloads deserialize to it."""
    pattern: str
    task: str
    tenant: str = ""


@dataclasses.dataclass(frozen=True)
class StageStarted(RunEvent):
    index: int
    name: str


@dataclasses.dataclass(frozen=True)
class PlanProduced(RunEvent):
    index: int
    plan: Any


@dataclasses.dataclass(frozen=True)
class LLMCompleted(RunEvent):
    event: LLMEvent


@dataclasses.dataclass(frozen=True)
class ToolInvoked(RunEvent):
    event: ToolEvent


@dataclasses.dataclass(frozen=True)
class OverheadIncurred(RunEvent):
    event: FrameworkEvent


@dataclasses.dataclass(frozen=True)
class ReflectionEmitted(RunEvent):
    index: int
    reflection: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class StageCompleted(RunEvent):
    index: int
    success: bool


@dataclasses.dataclass(frozen=True)
class RunCompleted(RunEvent):
    completed: bool
    data: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ToolRetried(RunEvent):
    """A tool invocation failed with a *retryable* error (fault-injected
    transient failure, throttling — see :mod:`repro.traffic.faults`) and
    the runtime's :class:`repro.core.policies.RetryPolicy` re-dispatched
    it after ``backoff_s`` of virtual time.  ``attempt`` is the 1-based
    index of the attempt that FAILED, so a call that succeeds on its
    third try emits two ``ToolRetried`` events (attempts 1 and 2)."""
    server: str
    tool: str
    attempt: int
    error: str
    backoff_s: float


@dataclasses.dataclass(frozen=True)
class RunHedged(RunEvent):
    """A slow tool invocation was hedged: the runtime's
    :class:`repro.core.policies.HedgePolicy` issued a backup call at
    ``hedge_after_s`` into the primary's flight and took whichever
    finished first.  ``winner`` is ``"primary"`` or ``"hedge"``;
    ``saved_s`` is the virtual latency the hedge shaved off the
    primary's completion time (0.0 when the primary won)."""
    server: str
    tool: str
    winner: str
    primary_s: float
    hedge_s: float
    saved_s: float


@dataclasses.dataclass(frozen=True)
class PlanCompiled(RunEvent):
    """A successful run's trace was compiled into a :class:`PlanGraph`
    (:mod:`repro.plans.compile`) and stored in the session's plan cache
    under ``key`` (the app/task-template fingerprint).  ``stages`` /
    ``nodes`` describe the graph; ``dyn_nodes`` counts the nodes whose
    arguments could not be bound statically and still need an executor
    LLM call on replay."""
    key: str
    template: str
    stages: int
    nodes: int
    dyn_nodes: int = 0


@dataclasses.dataclass(frozen=True)
class PlanCacheMiss(RunEvent):
    """The session looked for a compiled plan under ``key`` and found
    none — this run executes with full AgentX planning (and compiles a
    graph on success)."""
    key: str


@dataclasses.dataclass(frozen=True)
class PlanFallback(RunEvent):
    """A compiled-plan replay deviated (node failure, tool mismatch,
    template mismatch — ``reason``) at stage ``stage`` and the session
    fell back to full AgentX re-planning.  Emitted on the FALLBACK run's
    stream, before its ``RunStarted``."""
    key: str
    reason: str
    stage: int = -1


@dataclasses.dataclass(frozen=True)
class RunDegraded(RunEvent):
    """A tenant's soft budget exhaustion downgraded this run to a cheaper
    configuration before execution (:class:`repro.tenancy.DegradePolicy`):
    ``from_pattern``/``to_pattern`` and ``from_deployment``/
    ``to_deployment`` describe the swap (equal when that axis kept its
    value).  Emitted on the degraded run's stream BEFORE its
    ``RunStarted`` — the decision is part of the run's billed history."""
    tenant: str
    reason: str
    from_pattern: str
    to_pattern: str
    from_deployment: str
    to_deployment: str


@dataclasses.dataclass(frozen=True)
class BudgetExceeded(RunEvent):
    """A tenant's hard budget exhaustion rejected this run outright —
    no world is built, nothing executes, nothing is billed.  ``kind`` is
    the exhausted axis (``"tokens"`` | ``"cost"``), ``used``/``budget``
    the meter reading at rejection time."""
    tenant: str
    kind: str
    used: float
    budget: float


@dataclasses.dataclass(frozen=True)
class SloAlertFired(RunEvent):
    """Telemetry-side alert: an :class:`repro.telemetry.SloMonitor`
    window burned error budget faster than its threshold.  ``slo`` names
    the objective (``"success"`` | ``"latency"`` | ``"ttft"``),
    ``burn_rate`` the window's error rate divided by the SLO's error
    budget (1.0 = burning exactly at budget), ``bad``/``total`` the
    window's violating/observed run counts, and ``target`` the SLO value
    the objective was checked against.  ``t`` is the end of the
    (virtual-clock-aligned) window, so replaying a workload re-fires the
    identical alerts at the identical instants."""
    slo: str
    window_start: float
    window_s: float
    burn_rate: float
    threshold: float
    bad: int
    total: int
    target: float


@dataclasses.dataclass(frozen=True)
class EngineStepped(RunEvent):
    """Serving-side event: the continuous-batching scheduler advanced all
    live decode slots by one step.  Emitted by the *engine*, not a run —
    ``t`` carries the scheduler's monotonic step counter (the engine has
    no virtual clock; it serves many runs/worlds at once).  ``live`` is
    the decode-batch occupancy during the step, ``queued`` the number of
    requests still waiting for a slot, and ``generated`` how many tokens
    this step produced (== ``live``).

    Scheduler-v2 admission gauges (default 0, so pre-v2 wire payloads
    still deserialize): ``prefilled`` counts the prompt tokens prefilled
    during the step's admission phase (bucketed batches, one chunk of a
    chunked admission, or a preemption-resume replay), ``preempted`` the
    number of live slots evicted for a higher-priority request.

    Paged-KV gauges (default 0, so pre-paging wire payloads still
    deserialize — and the contiguous-cache scheduler emits exactly the
    pre-paging payload): ``blocks_in_use`` is the block allocator's
    occupancy after the step, ``prefix_hits`` how many admissions this
    step reused cached prefix blocks."""
    live: int
    queued: int
    generated: int
    prefilled: int = 0
    preempted: int = 0
    blocks_in_use: int = 0
    prefix_hits: int = 0


# ---------------------------------------------------------------------------
# wire protocol

# Explicit wire-schema version, stamped on every ``to_wire`` payload as
# ``"v"``.  Bump WIRE_VERSION on any *semantic* change to event payloads
# (a renamed field, changed units, changed truncation); raise
# MIN_WIRE_VERSION when the change is incompatible enough that older
# stamped payloads must be REJECTED rather than parsed-with-defaults.
# Durable journal segments (:mod:`repro.durable.journal`) additionally
# carry the version in their header, so a whole segment from an older
# schema is detected up front instead of mis-parsed event by event.
#
# v2 == the schema as of the plan-compiler PR (ToolEvent carries
# args/result); unstamped payloads (written before versioning existed)
# are treated as v-unknown and parsed with the historical tolerant
# behavior.
WIRE_VERSION = 2
MIN_WIRE_VERSION = 2


class WireVersionError(ValueError):
    """A stamped wire payload predates :data:`MIN_WIRE_VERSION` — its
    field semantics can no longer be trusted, so it must be rejected
    (detected), not silently parsed with defaults."""


_EVENT_TYPES: Dict[str, type] = {
    cls.__name__: cls
    for cls in (RunStarted, StageStarted, PlanProduced, LLMCompleted,
                ToolInvoked, OverheadIncurred, ReflectionEmitted,
                StageCompleted, RunCompleted, ToolRetried, RunHedged,
                PlanCompiled, PlanCacheMiss, PlanFallback, EngineStepped,
                RunDegraded, BudgetExceeded, SloAlertFired)
}

# events whose ``event`` field is a nested metrics dataclass
_NESTED_EVENT: Dict[str, type] = {
    "LLMCompleted": LLMEvent,
    "ToolInvoked": ToolEvent,
    "OverheadIncurred": FrameworkEvent,
}


def _jsonable(value: Any) -> Any:
    """Best-effort JSON sanitization: payloads (plans, outcome data) are
    JSON-shaped in practice; anything exotic degrades to ``repr``."""
    if isinstance(value, dict):
        return {str(k): _jsonable(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    return repr(value)


def to_wire(event: RunEvent) -> Dict[str, Any]:
    """Serialize one event to a JSON-safe dict (``type`` + ``v`` +
    fields)."""
    d = _jsonable(dataclasses.asdict(event))
    d["type"] = type(event).__name__
    d["v"] = WIRE_VERSION
    return d


def _known_fields(cls: type, d: Dict[str, Any]) -> Dict[str, Any]:
    """Drop wire fields the local dataclass doesn't know: a NEWER peer
    (remote orchestrator Lambda, disk cache written by a later version)
    may attach extra gauges; tolerating them keeps the wire protocol
    forward-compatible (missing fields still need defaults, as with
    ``EngineStepped``'s v2 gauges)."""
    known = {f.name for f in dataclasses.fields(cls)}
    return {k: v for k, v in d.items() if k in known}


def from_wire(d: Dict[str, Any]) -> RunEvent:
    """Inverse of :func:`to_wire`.

    Raises ``KeyError`` on unknown type and :class:`WireVersionError`
    on a payload stamped with a schema older than
    :data:`MIN_WIRE_VERSION`; unknown *fields* of a known type are
    ignored (forward compat — a NEWER peer's extra gauges parse fine),
    and unstamped payloads (pre-versioning) keep the historical
    tolerant behavior."""
    d = dict(d)
    v = d.pop("v", None)
    if v is not None and v < MIN_WIRE_VERSION:
        raise WireVersionError(
            f"wire payload schema v{v} predates the oldest supported "
            f"schema v{MIN_WIRE_VERSION} (current v{WIRE_VERSION})")
    name = d.pop("type")
    try:
        cls = _EVENT_TYPES[name]
    except KeyError:
        raise KeyError(f"unknown RunEvent type {name!r}; known: "
                       f"{sorted(_EVENT_TYPES)}") from None
    d = _known_fields(cls, d)
    nested = _NESTED_EVENT.get(name)
    if nested is not None:
        d["event"] = nested(**_known_fields(nested, d["event"]))
    return cls(**d)


def events_to_wire(events: List[RunEvent]) -> List[Dict[str, Any]]:
    return [to_wire(e) for e in events]


def events_from_wire(wire: List[Dict[str, Any]]) -> List[RunEvent]:
    return [from_wire(d) for d in wire]


def reduce_into_trace(event: RunEvent, trace: Trace) -> None:
    """Fold one event into a Trace. ``LLMCompleted`` is a no-op because the
    LLM backend appends to the shared Trace itself (it also serves callers
    that bypass the runtime)."""
    if isinstance(event, ToolInvoked):
        trace.tool_events.append(event.event)
    elif isinstance(event, OverheadIncurred):
        trace.framework_events.append(event.event)


def derive_trace(events: List[RunEvent]) -> Trace:
    """Rebuild the full accounting Trace from an event stream."""
    trace = Trace()
    for ev in events:
        if isinstance(ev, LLMCompleted):
            trace.llm_events.append(ev.event)
        else:
            reduce_into_trace(ev, trace)
    return trace
