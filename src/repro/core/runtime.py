"""Shared agent runtime + workflow-pattern registry (paper §3's
"orchestrator between the agents", factored out of the patterns).

Every workflow pattern (AgentX, ReAct, Magentic-One, and their variants)
subclasses :class:`AgentRuntime`, which owns the pieces the paper's
orchestrator provides to all of them:

  * the tool registry built from ``Dict[str, McpClient]`` (flat handle
    list, tool -> server index, per-server tool sets),
  * a single validated :meth:`AgentRuntime.invoke` path — virtual-time
    Stopwatch, ``ToolEvent`` accounting, and identical unknown-server /
    unknown-tool errors for every pattern,
  * framework-overhead accounting (:meth:`AgentRuntime.overhead`) driven
    by the pattern's :class:`PatternConfig`,
  * the typed :class:`RunEvent` stream (``emit`` / ``subscribe``) with the
    ``Trace`` kept in sync by reduction,
  * the :class:`RunOutcome` return contract of :meth:`AgentRuntime.run`.

Subclasses implement only ``_run(task)`` — their control flow.

Patterns self-register under a name with knob overrides; a new variant is
one decorator instead of a runner-table edit::

    from repro.core.runtime import (AgentRuntime, PatternConfig,
                                    register_pattern, resolve_pattern)

    @register_pattern("agentx-cot", cot=True)
    @register_pattern("agentx", tags=("paper",))
    class AgentXRunner(AgentRuntime):
        pattern = "agentx"
        default_config = PatternConfig(max_steps=14,
                                       overhead_local_s=0.18,
                                       overhead_faas_s=0.16)

        def _run(self, task):
            ...
            return RunOutcome(completed=True, data={...})

Driving a run end-to-end goes through the Session API::

    from repro.apps.session import RunSpec, Session

    session = Session()
    result = session.execute(RunSpec("web_search", "quantum", "agentx"))
    batch = session.execute_many(
        [RunSpec("web_search", "quantum", "agentx", seed=s)
         for s in range(8)], max_workers=4)
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional

from ..env.clock import Stopwatch
from ..env.world import World
from ..mcp.client import McpClient, ToolHandle
from .events import (LLMCompleted, OverheadIncurred, ReflectionEmitted,
                     RunCompleted, RunEvent, RunHedged, RunStarted,
                     ToolInvoked, ToolRetried, reduce_into_trace)
from .llm import LLMBackend, LLMRequest, LLMResponse, ToolCall
from .metrics import FrameworkEvent, LLMEvent, ToolEvent, Trace
from .policies import HedgePolicy, RetryPolicy


# ---------------------------------------------------------------------------
# configuration + outcome contract

# ToolEvent.result truncation: keeps event streams (and the disk caches
# built on them) bounded while leaving enough text for the plan compiler's
# data-flow extractors (URLs, arxiv ids, saved paths all appear early)
TOOL_RESULT_WIRE_LIMIT = 6000


class RunAborted(RuntimeError):
    """The simulated platform died mid-run (injected crash — see
    ``FaultPlan.crash_rate`` in :mod:`repro.traffic.faults`).

    Unlike an ordinary pattern failure, an aborted run emits NO
    terminating ``RunCompleted``: a dead process writes nothing.  That
    is what lets the durable run journal
    (:mod:`repro.durable.journal`) distinguish an interrupted segment
    (resumable) from a completed-but-failed one (not resumable —
    deterministic failures would fail again)."""


def stable_fingerprint(config, exclude: tuple = ()) -> str:
    """Stable digest of a config dataclass (sorted-JSON SHA-256, 16 hex
    chars) — the cache-invalidation primitive shared by ``PatternConfig``
    and ``DeploymentCapabilities``: any knob change changes the digest.

    ``exclude`` drops fields from the payload before hashing — the
    back-compat hatch for fields added AFTER runs were cached under the
    digest: excluding a new field while it holds its default keeps every
    pre-existing address valid (callers exclude conditionally, so a
    non-default value still changes the digest)."""
    payload_dict = dataclasses.asdict(config)
    for name in exclude:
        payload_dict.pop(name, None)
    payload = json.dumps(payload_dict, sort_keys=True, default=repr)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class PatternConfig:
    """The knobs a workflow pattern exposes (previously per-module magic
    constants)."""
    name: str = ""
    max_steps: int = 14          # tool-loop cap (per stage / specialist / run)
    overhead_local_s: float = 0.0
    overhead_faas_s: float = 0.0
    overhead_jitter: bool = False   # multiplicative jitter on overhead
    max_replans: int = 0            # recovery budget (Magentic-One)
    cot: bool = False               # CoT pre-reasoning (§7 future work)
    parallel_stages: bool = False   # concurrent independent stages (§7)
    tags: tuple = ()
    rank: int = 50                  # listing order (import-order independent)

    def overhead_s(self, deployment: str,
                   remote: Optional[bool] = None) -> float:
        if remote is None:
            remote = deployment != "local"
        return self.overhead_faas_s if remote else self.overhead_local_s

    def fingerprint(self) -> str:
        return stable_fingerprint(self)


class RunOutcome(Mapping):
    """Typed return contract of ``AgentRuntime.run``.

    Mapping access is kept for back-compat with the historical
    ``run(task) -> Dict`` contract: ``outcome["summaries"]``,
    ``outcome.get("completed")`` etc. keep working.
    """

    def __init__(self, completed: bool, data: Optional[Dict[str, Any]] = None):
        self.completed = bool(completed)
        self.data: Dict[str, Any] = dict(data or {})

    def __getitem__(self, key: str) -> Any:
        if key == "completed":
            return self.completed
        return self.data[key]

    def __iter__(self) -> Iterator[str]:
        yield "completed"
        yield from self.data

    def __len__(self) -> int:
        return 1 + len(self.data)

    def __repr__(self) -> str:
        return f"RunOutcome(completed={self.completed}, data={self.data!r})"


# ---------------------------------------------------------------------------
# the shared runtime


class AgentRuntime:
    """Base class for workflow patterns: owns tools, invocation, overhead
    accounting and the event stream; subclasses implement ``_run``."""

    pattern = "base"
    default_config = PatternConfig()

    def __init__(self, backend: LLMBackend, clients: Dict[str, McpClient],
                 world: World, trace: Trace, deployment: str = "local",
                 config: Optional[PatternConfig] = None,
                 on_event: Optional[Callable[[RunEvent], None]] = None,
                 remote: Optional[bool] = None,
                 retry: Optional[RetryPolicy] = None,
                 hedge: Optional[HedgePolicy] = None,
                 tenant: str = "",
                 **overrides):
        cfg = config if config is not None else type(self).default_config
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.config = cfg
        self.backend = backend
        self.clients = clients
        self.world = world
        self.trace = trace
        self.deployment = deployment
        self.retry = retry
        self.hedge = hedge
        # billing principal stamped on RunStarted (multi-tenant serving,
        # :mod:`repro.tenancy`); "" = the single default tenant
        self.tenant = tenant
        # off-workstation tooling: from the deployment backend's capability
        # descriptor when driven through Session, else the string heuristic
        self.remote = (deployment != "local") if remote is None else remote
        self.events: List[RunEvent] = []
        self._subscribers: List[Callable[[RunEvent], None]] = []
        if on_event is not None:
            self._subscribers.append(on_event)

        # tool registry: flat handles, tool -> server, per-server tool names
        self.tools: List[ToolHandle] = []
        self.tool_server: Dict[str, str] = {}
        self.server_tools: Dict[str, List[ToolHandle]] = {}
        for server, client in clients.items():
            handles = client.list_tools()
            self.server_tools[server] = handles
            for h in handles:
                self.tools.append(h)
                self.tool_server[h.name] = server

    # -- events ------------------------------------------------------------
    def subscribe(self, fn: Callable[[RunEvent], None]) -> None:
        self._subscribers.append(fn)

    def emit(self, event: RunEvent) -> None:
        self.events.append(event)
        reduce_into_trace(event, self.trace)
        for fn in self._subscribers:
            fn(event)

    def now(self) -> float:
        return self.world.clock.now()

    # -- LLM completion through the runtime (event-emitting) ---------------
    def complete(self, request: LLMRequest) -> LLMResponse:
        n0 = len(self.trace.llm_events)
        resp = self.backend.complete(request)
        if len(self.trace.llm_events) > n0:
            ev = self.trace.llm_events[-1]
        else:  # backend that does not log to the shared trace
            ev = LLMEvent(request.agent, resp.input_tokens,
                          resp.output_tokens, resp.latency, self.now())
            self.trace.llm_events.append(ev)
        self.emit(LLMCompleted(t=self.now(), event=ev))
        return resp

    # -- framework-overhead accounting --------------------------------------
    def overhead(self, what: str) -> None:
        dt = self.config.overhead_s(self.deployment, remote=self.remote)
        if self.config.overhead_jitter:
            dt *= 0.6 + 0.8 * self.world.latency.rng.random()
        self.world.clock.sleep(dt)
        self.emit(OverheadIncurred(
            t=self.now(), event=FrameworkEvent(what, dt, self.now())))

    # -- the single validated tool-invocation path ---------------------------
    def invoke(self, call: ToolCall) -> str:
        """Validate server AND tool name identically for every pattern,
        then dispatch with virtual-time accounting.

        Resilience (``retry`` / ``hedge`` policies, when set) lives
        HERE, below the pattern: a retried or hedged call returns one
        result string, so the agent's history — and therefore every
        policy decision — is identical to a fault-free run.  The single
        ``ToolInvoked`` event carries the end-to-end latency (backoffs
        and losing hedges included) and the final ok flag; per-attempt
        detail rides on ``ToolRetried`` / ``RunHedged`` events."""
        server = call.server or self.tool_server.get(call.tool, "")
        client = self.clients.get(server)
        with Stopwatch(self.world.clock) as sw:
            if client is None:
                result = (f"<tool-error unknown server {server!r} for tool "
                          f"{call.tool!r}>")
            elif not any(h.name == call.tool
                         for h in self.server_tools.get(server, [])):
                result = f"<tool-error unknown tool {call.tool!r}>"
            else:
                result = self._dispatch(client, server, call)
        ok = not result.startswith("<tool-error")
        self.emit(ToolInvoked(
            t=self.now(),
            event=ToolEvent(server, call.tool, sw.elapsed, ok, self.now(),
                            args=dict(call.args),
                            result=result[:TOOL_RESULT_WIRE_LIMIT])))
        return result

    def _dispatch(self, client: McpClient, server: str, call: ToolCall) -> str:
        """One validated dispatch: hedged call inside a retry loop."""
        attempt = 1
        while True:
            result = self._call_hedged(client, server, call)
            if (self.retry is None
                    or not self.retry.is_retryable(result)
                    or attempt >= self.retry.max_attempts):
                return result
            backoff = self.retry.backoff(attempt)
            self.emit(ToolRetried(t=self.now(), server=server, tool=call.tool,
                                  attempt=attempt, error=result[:200],
                                  backoff_s=backoff))
            self.world.clock.sleep(backoff)
            attempt += 1

    def _call_hedged(self, client: McpClient, server: str,
                     call: ToolCall) -> str:
        """Call the tool; when a hedge policy is set and the primary ran
        past the hedge deadline, model a backup call fired AT the
        deadline and complete with whichever copy finished first.  Both
        calls' latency draws and platform billing happen for real; the
        loser's *tail* is then discarded from the clock (virtual time
        rewinds to the winner's completion — the paid-but-wasted work
        stays on the bill, which is exactly how hedging prices out)."""
        clock = self.world.clock
        t0 = clock.now()
        result = client.call_tool(call.tool, call.args)
        primary_s = clock.now() - t0
        h = self.hedge
        if h is None or primary_s <= h.hedge_after_s:
            return result
        backup = client.call_tool(call.tool, call.args)
        hedge_s = clock.now() - t0 - primary_s
        backup_done = h.hedge_after_s + hedge_s
        # a fast *failure* must not beat a slow success: the client keeps
        # waiting for the other copy when one errors out, so the race is
        # decided among successful responses first, by latency only when
        # both succeeded (or both failed)
        primary_ok = not result.startswith("<tool-error")
        backup_ok = not backup.startswith("<tool-error")
        if primary_ok and not backup_ok:
            effective = primary_s
        elif backup_ok and not primary_ok:
            effective = backup_done
        else:
            effective = min(primary_s, backup_done)
        if primary_ok >= backup_ok and primary_s - effective < h.min_saving_s:
            effective = primary_s
        winner = "primary" if effective == primary_s else "hedge"
        clock.reset(t0 + effective)
        self.emit(RunHedged(t=self.now(), server=server, tool=call.tool,
                            winner=winner, primary_s=primary_s,
                            hedge_s=hedge_s,
                            saved_s=max(primary_s - effective, 0.0)))
        return backup if winner == "hedge" else result

    # -- run contract --------------------------------------------------------
    def run(self, task: str) -> RunOutcome:
        self.emit(RunStarted(t=self.now(), pattern=self.config.name
                             or self.pattern, task=task,
                             tenant=self.tenant))
        try:
            outcome = self._run(task)
        except RunAborted:
            # simulated platform death: the event stream just STOPS —
            # no termination event, exactly like a real dead process
            # (the journal's interrupted-segment detection rests on it)
            raise
        except Exception:
            # pattern-level crash: still terminate the event stream so
            # live observers (RunMonitor) don't leak in-flight runs
            self.emit(RunCompleted(t=self.now(), completed=False, data={}))
            raise
        self.emit(RunCompleted(t=self.now(), completed=outcome.completed,
                               data=outcome.data))
        return outcome

    def _run(self, task: str) -> RunOutcome:
        raise NotImplementedError

    # -- small conveniences shared by patterns -------------------------------
    def reflect(self, index: int, reflection: Dict[str, Any]) -> None:
        self.emit(ReflectionEmitted(t=self.now(), index=index,
                                    reflection=reflection))


# ---------------------------------------------------------------------------
# pattern registry


@dataclasses.dataclass(frozen=True)
class RegisteredPattern:
    name: str
    runner_cls: type
    config: PatternConfig


_REGISTRY: Dict[str, RegisteredPattern] = {}
_BUILTINS_LOADED = False
_BUILTINS_LOCK = threading.Lock()


def register_pattern(name: str, *, tags: tuple = (), **overrides):
    """Class decorator registering a runner class under ``name`` with
    ``PatternConfig`` overrides. Stack decorators for variants."""
    def deco(cls):
        cfg = dataclasses.replace(cls.default_config, name=name,
                                  tags=tuple(tags), **overrides)
        _REGISTRY[name] = RegisteredPattern(name, cls, cfg)
        return cls
    return deco


def _ensure_builtins() -> None:
    """Import the built-in pattern modules (registration side effect).
    Listing order comes from ``PatternConfig.rank``, so it is independent
    of which pattern module gets imported first. Lock-guarded: the first
    resolve may happen concurrently from ``execute_many`` workers."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    with _BUILTINS_LOCK:
        if _BUILTINS_LOADED:
            return
        from . import react, agentx, magentic  # noqa: F401
        from ..plans import execute  # noqa: F401  (agentx-compiled)
        _BUILTINS_LOADED = True


def resolve_pattern(name: str) -> RegisteredPattern:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown pattern {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def pattern_names(tag: Optional[str] = None) -> List[str]:
    _ensure_builtins()
    named = [(rp.config.rank, n) for n, rp in _REGISTRY.items()
             if tag is None or tag in rp.config.tags]
    return [n for _, n in sorted(named)]


def create_runner(name: str, backend: LLMBackend,
                  clients: Dict[str, McpClient], world: World, trace: Trace,
                  deployment: str = "local",
                  on_event: Optional[Callable[[RunEvent], None]] = None,
                  remote: Optional[bool] = None,
                  retry: Optional[RetryPolicy] = None,
                  hedge: Optional[HedgePolicy] = None,
                  tenant: str = "") -> AgentRuntime:
    rp = resolve_pattern(name)
    return rp.runner_cls(backend, clients, world, trace,
                         deployment=deployment, config=rp.config,
                         on_event=on_event, remote=remote,
                         retry=retry, hedge=hedge, tenant=tenant)
