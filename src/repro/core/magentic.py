"""Magentic-One baseline (paper §5.1, §6.3): an Orchestrator with a fact
sheet + ledger plan delegating to per-MCP-server specialist agents (the
paper replaces the stock WebSurfer/FileSurfer/Coder/Terminal team with one
agent per MCP server, each with a hand-written description).

Specialists receive the fact sheet + plan, call their server's tools, and
pass only a *reflection* of the tool outputs onward (§6.4 — the source of
the stock-data truncation anomaly). On specialist failure the Orchestrator
updates the fact sheet and re-plans (2 extra inferences), capped at
``PatternConfig.max_replans``.

Plumbing lives in :class:`repro.core.runtime.AgentRuntime`; the per-server
team view is the runtime's ``server_tools`` registry.
"""
from __future__ import annotations

import json
from typing import Dict, List

from .llm import LLMRequest, ToolCall
from .events import PlanProduced, StageStarted
from .runtime import (AgentRuntime, PatternConfig, RunOutcome,
                      register_pattern)
from .schema import FACT_SHEET_SCHEMA, LEDGER_PLAN_SCHEMA

ORCH_SYSTEM = ("You are the Orchestrator of a team of specialized agents. "
               "Maintain a fact sheet, create a plan delegating sub-tasks "
               "to team members, track progress and re-plan on failure.")

AGENT_DESCRIPTIONS = {
    "arxiv": ("Agent for interacting with the arXiv API to retrieve article "
              "URLs, download research papers as PDFs, load articles into "
              "context, get article metadata, and perform search queries on "
              "arXiv.org."),
    "serper": ("Agent for web search via the Google Serper API: organic "
               "search, news, scholar and more."),
    "fetch": ("Remote AWS LAMBDA function MCP server for fetching web "
              "content in various formats, including HTML, JSON, plain "
              "text, and Markdown."),
    "rag": ("Agent for retrieving relevant text snippets from ingested PDF "
            "documents using embedding similarity search."),
    "yfinance": ("Agent for Yahoo Finance market data: historical prices, "
                 "quotes, fundamentals."),
    "code-execution": ("Agent that writes and executes Python code in a "
                       "sandbox with matplotlib/pandas preinstalled."),
    "filesystem": ("Agent for reading and writing files on the local "
                   "filesystem."),
    "s3": ("Agent for reading and writing objects in S3."),
}


@register_pattern("magentic", tags=("paper",), rank=30)
class MagenticOneRunner(AgentRuntime):
    pattern = "magentic"
    # AutoGen + AgentOps observability overhead (paper: mean 30.1 s local,
    # ~15 s FaaS, with occasional network outliers)
    default_config = PatternConfig(max_steps=10, max_replans=3,
                                   overhead_local_s=2.6,
                                   overhead_faas_s=1.35,
                                   overhead_jitter=True)

    @property
    def team(self) -> Dict[str, List]:
        return self.server_tools

    def _orchestrate(self, task: str, phase: str, fact_sheet, plan, progress,
                     replans: int, schema=None):
        team_text = "\n".join(f"{s}: {AGENT_DESCRIPTIONS.get(s, s)}"
                              for s in self.team)
        self.overhead(f"orchestrator-{phase}")
        return self.complete(LLMRequest(
            agent="orchestrator", system=ORCH_SYSTEM,
            messages=[{"role": "user", "content":
                       f"Task: {task}\nTeam:\n{team_text}\n"
                       f"Fact sheet: {json.dumps(fact_sheet)}\n"
                       f"Plan: {json.dumps(plan)}\n"
                       f"Progress ledger: {json.dumps(progress)}\n"
                       f"Team context:\n" + "\n".join(self._shared)}],
            schema=schema,
            meta={"task": task, "phase": phase, "team": list(self.team),
                  "fact_sheet": fact_sheet, "plan": plan,
                  "progress": progress, "replans": replans}))

    def _run(self, task: str) -> RunOutcome:
        progress: List[Dict] = []
        self._shared: List[str] = []
        facts = self._orchestrate(task, "facts", None, None, progress, 0,
                                  schema=FACT_SHEET_SCHEMA).decision.structured
        plan = self._orchestrate(task, "plan", facts, None, progress, 0,
                                 schema=LEDGER_PLAN_SCHEMA
                                 ).decision.structured["plan"]
        self.emit(PlanProduced(t=self.now(), index=0, plan=plan))

        replans = 0
        step_idx = 0
        shared_context = self._shared
        while step_idx < len(plan):
            step = plan[step_idx]
            server = step.split(":", 1)[0].strip()
            if server not in self.team:
                step_idx += 1
                continue
            self.emit(StageStarted(t=self.now(), index=step_idx, name=step))
            history: List[Dict] = []
            outcome = None
            for _ in range(self.config.max_steps):
                self.overhead(f"{server}-dispatch")
                resp = self.complete(LLMRequest(
                    agent=f"{server}_agent",
                    system=AGENT_DESCRIPTIONS.get(server, server),
                    messages=[{"role": "user", "content":
                               f"Fact sheet: {json.dumps(facts)}\n"
                               f"Plan: {json.dumps(plan)}\n"
                               f"Your sub-task: {step}\n"
                               f"Context from team:\n"
                               + "\n".join(shared_context)
                               + "\nYour tool results:\n"
                               + "\n".join(h["result"][:4500] for h in history)}],
                    tools=self.team[server],
                    meta={"task": task, "server": server, "subtask": step,
                          "history": history, "fact_sheet": facts,
                          "shared_context": shared_context,
                          "replans": replans}))
                d = resp.decision
                if d.tool_call is not None:
                    # specialists are confined to their own server: the
                    # call routes there regardless of what the decision
                    # names (then through the unified validation path)
                    call = ToolCall(server, d.tool_call.tool,
                                    d.tool_call.args)
                    result = self.invoke(call)
                    history.append({"tool": d.tool_call.tool,
                                    "args": d.tool_call.args,
                                    "result": result})
                else:
                    outcome = d.structured or {"result": d.text, "done": True}
                    break
            if outcome:
                self.reflect(step_idx, outcome)
            progress.append({"step": step, "outcome":
                             (outcome or {}).get("result", "")[:500]})
            if outcome and outcome.get("result"):
                shared_context.append(outcome["result"])
            if outcome and outcome.get("task_complete"):
                # the orchestrator marks the task complete immediately —
                # later plan steps (e.g. verification) never execute (§6.4)
                break
            if outcome and outcome.get("replan") \
                    and replans < self.config.max_replans:
                replans += 1
                facts = self._orchestrate(task, "update-facts", facts, plan,
                                          progress, replans,
                                          schema=FACT_SHEET_SCHEMA
                                          ).decision.structured
                plan = self._orchestrate(task, "replan", facts, plan,
                                         progress, replans,
                                         schema=LEDGER_PLAN_SCHEMA
                                         ).decision.structured["plan"]
                self.emit(PlanProduced(t=self.now(), index=replans,
                                       plan=plan))
                step_idx = 0
                continue
            step_idx += 1

        final = self._orchestrate(task, "final", facts, plan, progress,
                                  replans).decision.text
        return RunOutcome(completed=final is not None, data={
            "plan": plan, "final": final, "replans": replans})
