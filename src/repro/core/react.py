"""ReAct baseline (paper §5.1): LangGraph-style ``create_react_agent``
variant — action + observation loop over a single shared context window
(the paper's implementation omits the explicit thought step), with the
default try-until-success recovery capped at 25 iterations.

Plumbing lives in :class:`repro.core.runtime.AgentRuntime`; this module is
the loop only.
"""
from __future__ import annotations

from typing import Dict, List

from .llm import LLMRequest
from .runtime import (AgentRuntime, PatternConfig, RunOutcome,
                      register_pattern)

REACT_SYSTEM = (
    "You are a helpful agent. Use the available tools to complete the "
    "user's task. When the task is complete, respond with the Final Answer.")


@register_pattern("react", tags=("paper",), rank=10)
class ReActRunner(AgentRuntime):
    pattern = "react"
    default_config = PatternConfig(max_steps=25, overhead_local_s=0.012,
                                   overhead_faas_s=0.012)

    def _run(self, task: str) -> RunOutcome:
        # single ever-growing message history: every raw tool output is
        # appended and re-sent on every inference (the paper's input-token
        # blowup, §5.4.3)
        messages: List[Dict[str, str]] = [{"role": "user", "content": task}]
        history: List[Dict] = []
        final = None
        for it in range(self.config.max_steps):
            self.overhead("graph-step")
            resp = self.complete(LLMRequest(
                agent="react", system=REACT_SYSTEM, messages=messages,
                tools=self.tools,
                meta={"task": task, "history": history, "iteration": it}))
            d = resp.decision
            if d.tool_call is not None:
                result = self.invoke(d.tool_call)
                history.append({"tool": d.tool_call.tool,
                                "args": d.tool_call.args, "result": result})
                messages.append({"role": "assistant",
                                 "content": d.render()})
                messages.append({"role": "tool", "content": result})
            else:
                final = d.text
                break
        return RunOutcome(completed=final is not None, data={
            "final": final, "iterations": len(history)})
