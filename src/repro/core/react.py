"""ReAct baseline (paper §5.1): LangGraph-style ``create_react_agent``
variant — action + observation loop over a single shared context window
(the paper's implementation omits the explicit thought step), with the
default try-until-success recovery capped at 25 iterations.
"""
from __future__ import annotations

from typing import Dict, List

from ..env.clock import Stopwatch
from ..env.world import World
from ..mcp.client import McpClient, ToolHandle
from .llm import LLMBackend, LLMRequest, ToolCall
from .metrics import FrameworkEvent, ToolEvent, Trace

REACT_SYSTEM = (
    "You are a helpful agent. Use the available tools to complete the "
    "user's task. When the task is complete, respond with the Final Answer.")

MAX_ITERATIONS = 25
FRAMEWORK_OVERHEAD_S = 0.012


class ReActRunner:
    pattern = "react"

    def __init__(self, backend: LLMBackend, clients: Dict[str, McpClient],
                 world: World, trace: Trace, deployment: str = "local"):
        self.backend = backend
        self.clients = clients
        self.world = world
        self.trace = trace
        self.deployment = deployment
        self.tools: List[ToolHandle] = []
        self.tool_server: Dict[str, str] = {}
        for server, client in clients.items():
            for h in client.list_tools():
                self.tools.append(h)
                self.tool_server[h.name] = server

    def _invoke(self, call: ToolCall) -> str:
        server = call.server or self.tool_server.get(call.tool, "")
        client = self.clients.get(server)
        with Stopwatch(self.world.clock) as sw:
            if client is None:
                result = f"<tool-error unknown tool {call.tool!r}>"
            else:
                result = client.call_tool(call.tool, call.args)
        ok = not result.startswith("<tool-error")
        self.trace.tool_events.append(ToolEvent(server, call.tool, sw.elapsed,
                                                ok, self.world.clock.now()))
        return result

    def run(self, task: str) -> Dict:
        # single ever-growing message history: every raw tool output is
        # appended and re-sent on every inference (the paper's input-token
        # blowup, §5.4.3)
        messages: List[Dict[str, str]] = [{"role": "user", "content": task}]
        history: List[Dict] = []
        final = None
        for it in range(MAX_ITERATIONS):
            self.world.clock.sleep(FRAMEWORK_OVERHEAD_S)
            self.trace.framework_events.append(
                FrameworkEvent("graph-step", FRAMEWORK_OVERHEAD_S,
                               self.world.clock.now()))
            resp = self.backend.complete(LLMRequest(
                agent="react", system=REACT_SYSTEM, messages=messages,
                tools=self.tools,
                meta={"task": task, "history": history, "iteration": it}))
            d = resp.decision
            if d.tool_call is not None:
                result = self._invoke(d.tool_call)
                history.append({"tool": d.tool_call.tool,
                                "args": d.tool_call.args, "result": result})
                messages.append({"role": "assistant",
                                 "content": d.render()})
                messages.append({"role": "tool", "content": result})
            else:
                final = d.text
                break
        return {"final": final, "iterations": len(history),
                "completed": final is not None}
