"""Shared disk-persistence primitives.

Every disk-backed store in the repo follows the same conventions — the
run cache (:mod:`repro.apps.cache`), the plan cache
(:mod:`repro.plans.cache`) and the durable run journal
(:mod:`repro.durable.journal`):

  * **atomic writes** — serialize to a sibling temp file, then
    ``os.replace`` so readers never observe a partial entry;
  * **corrupt-entry skip** — a corrupt, foreign or schema-drifted file
    is treated as a miss on load, never an error (``TypeError`` covers
    dataclass kwargs that changed across versions);
  * **best-effort mode** — persistence is an optimization for the
    caches: a full disk must not fail a completed run.

This module is that convention, factored out so three copies cannot
drift.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Callable, Dict, Tuple

# The exception set that means "this disk entry cannot be trusted":
# OSError (I/O), ValueError (bad JSON / bad payload values), KeyError
# (missing payload fields), TypeError (dataclass kwargs drifted across
# schema versions).  Loaders skip entries raising any of these.
CORRUPT_ENTRY_ERRORS = (OSError, KeyError, ValueError, TypeError)


def atomic_write_text(path: str, text: str,
                      best_effort: bool = False) -> bool:
    """Write ``text`` to ``path`` atomically (temp file + ``os.replace``
    — no reader ever sees a partial file).  The temp name carries the
    thread ident so concurrent writers of the same key don't collide;
    last writer wins.

    ``best_effort=True`` swallows ``OSError`` and returns ``False``
    instead (cache-style persistence must not fail the caller);
    otherwise the error propagates.  Returns ``True`` on success."""
    tmp = f"{path}.tmp.{threading.get_ident()}"
    try:
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)   # atomic: no partial reads
        return True
    except OSError:
        try:
            os.remove(tmp)
        except OSError:
            pass
        if not best_effort:
            raise
        return False


def atomic_write_json(path: str, payload: Any,
                      best_effort: bool = False) -> bool:
    """:func:`atomic_write_text` for a JSON payload."""
    return atomic_write_text(path, json.dumps(payload),
                             best_effort=best_effort)


def load_json_dir(cache_dir: str,
                  decode: Callable[[str, Any], Tuple[str, Any]],
                  prefix: str = "", suffix: str = ".json"
                  ) -> Dict[str, Any]:
    """Load every ``prefix*suffix`` JSON file under ``cache_dir`` through
    ``decode(stem, payload) -> (key, value)``, skipping entries that
    raise any :data:`CORRUPT_ENTRY_ERRORS` (corrupt, foreign, or written
    by a different schema version).  Deterministic order (sorted names);
    later files win on key collision."""
    out: Dict[str, Any] = {}
    for name in sorted(os.listdir(cache_dir)):
        if not (name.startswith(prefix) and name.endswith(suffix)):
            continue
        stem = name[len(prefix):len(name) - len(suffix)]
        try:
            with open(os.path.join(cache_dir, name)) as f:
                payload = json.load(f)
            key, value = decode(stem, payload)
            out[key] = value
        except CORRUPT_ENTRY_ERRORS:
            continue
    return out
