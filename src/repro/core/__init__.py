"""Core orchestration layer: the shared AgentRuntime, the pattern
registry, the typed run-event stream, and the built-in workflow patterns
(AgentX, ReAct, Magentic-One)."""
from .events import (LLMCompleted, OverheadIncurred, PlanProduced,
                     ReflectionEmitted, RunCompleted, RunEvent, RunStarted,
                     StageCompleted, StageStarted, ToolInvoked, derive_trace)
from .runtime import (AgentRuntime, PatternConfig, RunOutcome,
                      create_runner, pattern_names, register_pattern,
                      resolve_pattern)

__all__ = [
    "AgentRuntime", "PatternConfig", "RunOutcome", "create_runner",
    "pattern_names", "register_pattern", "resolve_pattern",
    "RunEvent", "RunStarted", "StageStarted", "PlanProduced", "LLMCompleted",
    "ToolInvoked", "OverheadIncurred", "ReflectionEmitted", "StageCompleted",
    "RunCompleted", "derive_trace",
]
