"""Mamba2 SSD chunked scan for TPU (training / prefill hot loop).

Grid: (batch*heads, n_chunks); the chunk axis is minor/sequential, so the
carried SSM state (head_dim × d_state, fp32) lives in VMEM scratch across
chunk steps — the TPU-idiomatic mapping of the SSD inter-chunk recurrence
(GPU implementations use a separate state-passing kernel; on TPU the
sequential grid gives us the recurrence for free).

Per chunk (all MXU matmuls):
  intra:  y_d = ((C B^T) ⊙ decay_seg) (x·dt)
  carry:  y_o = (C ⊙ decay_in) h_prev
  update: h   = decay_chunk · h_prev + (B ⊙ decay_out)^T (x·dt)

B/C are shared across heads (ngroups=1): their BlockSpec maps head h of
batch b to row b — no replication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fin_ref, h_scr,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (cs, p)
    dt = dt_ref[0].astype(jnp.float32)        # (cs, 1)
    A = a_ref[0, 0]                           # scalar decay rate (this head)
    B = b_ref[0].astype(jnp.float32)          # (cs, n)
    C = c_ref[0].astype(jnp.float32)          # (cs, n)

    a = dt * A                                # (cs, 1) log-decay per step
    xb = x * dt                               # discretized input
    cum = jnp.cumsum(a, axis=0)               # (cs, 1)

    # intra-chunk (quadratic) term
    seg = cum - cum.T                         # (cs, cs): sum_{s+1..l}
    tri = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # (cs, cs)
    y_d = jax.lax.dot(scores * L, xb)         # (cs, p)

    # carried-state contribution
    h_prev = h_scr[...]                       # (n, p)
    y_o = jax.lax.dot(C * jnp.exp(cum), h_prev)

    y_ref[0] = (y_d + y_o).astype(y_ref.dtype)

    # state update
    total = cum[-1:, :]                       # (1,1)
    decay_out = jnp.exp(total - cum)          # (cs, 1)
    S = jax.lax.dot_general(B * decay_out, xb, (((0,), (0,)), ((), ())))
    h_scr[...] = jnp.exp(total) * h_prev + S  # (n, p)

    @pl.when(ci == n_chunks - 1)
    def _final():
        fin_ref[0] = h_scr[...].astype(fin_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 256,
             interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); B/C: (b, s, n).
    Returns (y (b,s,h,p), final_state (b,h,p,n)). Requires s % chunk == 0."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk

    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s, 1)
    af = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)

    y, fin = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci, H=h: (bh // H, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci, H=h: (bh // H, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, n, p), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, B, C)

    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    fin = fin.reshape(b, h, n, p).transpose(0, 1, 3, 2)  # (b,h,p,n)
    return y, fin
