"""RMSNorm row kernel (TPU): rows tiled over the grid, full feature dim in
VMEM (d_model ≤ 8192 → ≤ 32 KiB/row fp32, comfortably VMEM-resident)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5, *,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (rows, d); scale: (d,). Requires rows % block_rows == 0
    (ops wrapper pads)."""
    rows, d = x.shape
    n = rows // block_rows
    kernel = functools.partial(_rms_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale[None, :])
