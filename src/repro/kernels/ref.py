"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: each kernel test sweeps shapes/dtypes
and asserts allclose against these functions.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, S, Hq, hd); k/v: (B, S, Hkv, hd); GQA via head grouping.
    Returns (B, S, Hq, hd)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, s, hkv, group, hd)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    q_pos = jnp.arange(s)[:, None]
    kv_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window:
        mask &= kv_pos > q_pos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out.reshape(b, s, hq, hd)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: jax.Array | int) -> jax.Array:
    """Single-token decode. q: (B, Hq, hd); k/v: (B, C, Hkv, hd);
    ``length``: number of valid cache rows (per batch or scalar).
    Returns (B, Hq, hd)."""
    b, hq, hd = q.shape
    c, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, hd)
    scores = jnp.einsum("bhgd,bthd->bhgt", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    idx = jnp.arange(c)
    length = jnp.asarray(length)
    valid = idx[None] < (length[..., None] if length.ndim else length)
    scores = jnp.where(valid[:, None, None] if length.ndim else valid[None, None, None],
                       scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgt,bthd->bhgd", probs, v)
    return out.reshape(b, hq, hd)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """Single-token decode over a block-paged KV pool.

    q: (B, Hq, hd); k_pool/v_pool: (n_blocks, bs, Hkv, hd);
    block_tables: (B, max_blocks) physical block ids; lengths: (B,).
    Gathers the pool into a dense per-sequence view through the table,
    then masked-softmax attends; ``lengths[b] == 0`` rows are exact
    zeros (mirrors the kernel's empty-sequence semantics — plain
    softmax would emit the mean of junk rows instead)."""
    b, hq, hd = q.shape
    n_blocks, bs, hkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    mb = block_tables.shape[1]
    group = hq // hkv
    tables = jnp.clip(block_tables, 0, n_blocks - 1)
    k = k_pool[tables].reshape(b, mb * bs, hkv, hd)   # (B, C, Hkv, hd)
    v = v_pool[tables].reshape(b, mb * bs, hkv, hd)
    qg = q.reshape(b, hkv, group, hd)
    scores = jnp.einsum("bhgd,bthd->bhgt", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    valid = jnp.arange(mb * bs)[None] < lengths[:, None]     # (B, C)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, None], jnp.exp(scores - m), 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgt,bthd->bhgd", (p / denom).astype(v.dtype), v)
    return out.reshape(b, hq, hd)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                 C: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Naive O(S) SSD recurrence (the definitional semantics).

    x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,n).
    Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    n = B.shape[-1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,n), (b,n)
        decay = jnp.exp(dtt * A[None, :])
        state = state * decay[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    init = jnp.zeros((b, h, p, n), dtype=jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
