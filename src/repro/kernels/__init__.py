"""Pallas TPU kernels (validated via interpret=True on CPU).

Each kernel: <name>.py (pl.pallas_call + BlockSpec), a jit'd wrapper in
ops.py, and a pure-jnp oracle in ref.py.
"""
from .ops import (flash_attention_op, decode_attention_op,
                  paged_decode_attention_op, ssd_scan_op,
                  rmsnorm_op, default_interpret)
from . import ref
