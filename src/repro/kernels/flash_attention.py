"""Blocked flash attention for TPU (prefill path).

Grid: (batch*q_heads, n_q_blocks, n_kv_blocks) — the kv axis is minor and
iterated sequentially on TPU, so the running (max, sum, acc) state lives in
VMEM scratch and is finalized on the last kv step.

GQA is handled in the BlockSpec index map: query-head ``bh`` reads kv head
``bh // group`` — no KV replication in HBM.

Block shapes are MXU-aligned (multiples of 128 on the lane dim; head_dim is
padded by the ops wrapper if needed). Causal + sliding-window masking is
applied from absolute block offsets; fully-masked kv blocks short-circuit.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int,
               block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s *= scale                                          # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)

    if causal or window:
        # skip kv blocks fully outside the (causal, window) band
        q_last = q_start + block_q - 1
        live = k_start <= q_last if causal else True
        if window:
            live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window) \
                if causal else (k_start + block_k - 1 > q_start - window)
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, S, hd) with BH = batch*q_heads; k/v: (BHkv, S, hd).
    Requires S % block == 0 (ops wrapper pads)."""
    bh, s, hd = q.shape
    bhkv = k.shape[0]
    group = bh // bhkv
    n_q = s // block_q
    n_k = s // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=n_k)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki, g=group: (b // g, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki, g=group: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
