"""Single-token GQA decode attention against a long KV cache (TPU).

The TPU analogue of GPU split-KV decode kernels: grid
(batch*kv_heads, n_kv_blocks); the kv axis is minor/sequential, so the
running (max, sum, acc) flash state lives in VMEM scratch. Each program
attends the whole query-head *group* (``group`` rows — MXU-friendly since
group × block_k matmuls map onto the systolic array) against one kv block.

Valid-length masking comes from a scalar-prefetch operand so ragged batches
(continuous batching) don't recompile.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, scale: float, block_k: int, n_kv_blocks: int,
                kv_heads: int):
    bh = pl.program_id(0)
    ki = pl.program_id(1)
    b = bh // kv_heads

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (group, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)

    pl.when(k_start < length)(_compute)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, block_k: int = 256,
                     interpret: bool = False) -> jax.Array:
    """q: (B, Hq, hd); k/v: (B, C, Hkv, hd); lengths: (B,) int32.
    Returns (B, Hq, hd). Requires C % block_k == 0 (ops wrapper pads)."""
    b, hq, hd = q.shape
    c, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    n_k = c // block_k
    scale = 1.0 / math.sqrt(hd)

    # layout: (B*Hkv, group, hd) for q; (B*Hkv? ...) — index kv via maps
    qg = q.reshape(b, hkv, group, hd).reshape(b * hkv, group, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, c, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, c, hd)

    kernel = functools.partial(
        _dec_kernel, scale=scale, block_k=block_k, n_kv_blocks=n_k,
        kv_heads=hkv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, n_k),
        in_specs=[
            pl.BlockSpec((1, group, hd), lambda bh, ki, lens: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, ki, lens: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, ki, lens: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, hd), lambda bh, ki, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kf, vf)
    return out.reshape(b, hkv * group, hd)


def _paged_dec_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr, *, scale: float,
                      block_size: int, max_blocks: int, kv_heads: int):
    bh = pl.program_id(0)
    bi = pl.program_id(1)
    b = bh // kv_heads

    @pl.when(bi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = bi * block_size

    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (group, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)

    pl.when(k_start < length)(_compute)

    @pl.when(bi == max_blocks - 1)
    def _finalize():
        # length == 0 leaves l at 0: the clamp makes the output exact
        # zeros (the documented empty-sequence semantics) instead of NaN
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """Single-token GQA decode over a block-paged KV pool.

    q: (B, Hq, hd); k_pool/v_pool: (n_blocks, bs, Hkv, hd) — the
    scheduler's pool layout, one leaf, no layer axis; block_tables:
    (B, max_blocks) int32 physical block ids (rows past the sequence
    may point anywhere valid — the length mask discards them);
    lengths: (B,) int32. Returns (B, Hq, hd); ``lengths[b] == 0``
    rows come back exact zeros.

    The pool never materialises per-sequence: both scalar-prefetch
    operands (lengths + tables) are available to the BlockSpec index
    maps, so each grid step DMAs exactly one physical block
    ``k_pool[h, tables[b, bi]]`` into VMEM. Grid and flash state
    (running max / sum / acc in VMEM scratch) mirror
    :func:`decode_attention` with ``block_k == block_size``.
    """
    b, hq, hd = q.shape
    n_blocks, bs, hkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    mb = block_tables.shape[1]
    group = hq // hkv
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(b, hkv, group, hd).reshape(b * hkv, group, hd)
    # (Hkv, n_blocks, bs, hd): head-major so one (block, head) pair is a
    # contiguous (bs, hd) tile for the index-mapped DMA
    kp = k_pool.transpose(2, 0, 1, 3)
    vp = v_pool.transpose(2, 0, 1, 3)
    # every index map must yield a real block even past the written
    # prefix (masked anyway) — clamp junk/sentinel table entries
    tables = jnp.clip(block_tables.astype(jnp.int32), 0, n_blocks - 1)

    kernel = functools.partial(
        _paged_dec_kernel, scale=scale, block_size=bs, max_blocks=mb,
        kv_heads=hkv)

    def kv_map(bh, bi, lens, tbl):
        return (bh % hkv, tbl[bh // hkv, bi], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hkv, mb),
        in_specs=[
            pl.BlockSpec((1, group, hd), lambda bh, bi, lens, tbl: (bh, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), kv_map),
            pl.BlockSpec((1, 1, bs, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, group, hd),
                               lambda bh, bi, lens, tbl: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), tables, qg, kp, vp)
    return out.reshape(b, hkv * group, hd)
