"""Jit'd public wrappers around the Pallas kernels.

Handle layout/padding so callers use natural (B, S, H, hd) shapes, and pick
``interpret=True`` automatically off-TPU so the same call sites work in CPU
CI and on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _fa
from .decode_attention import decode_attention as _dec
from .decode_attention import paged_decode_attention as _paged_dec
from .ssd_scan import ssd_scan as _ssd
from .rmsnorm import rmsnorm as _rms


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover - device probing
        return False


def default_interpret() -> bool:
    return not _on_tpu()


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_op(q, k, v, *, causal=True, window=0, block_q=128,
                       block_k=128, interpret=None):
    """q: (B,S,Hq,hd); k/v: (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    interpret = default_interpret() if interpret is None else interpret
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    pad = (-s) % max(block_q, block_k)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = _fa(qf, kf, vf, causal=causal, window=window,
              block_q=block_q, block_k=block_k, interpret=interpret)
    out = out[:, :s].reshape(b, hq, s, hd).transpose(0, 2, 1, 3)
    return out


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_op(q, k, v, lengths, *, block_k=256, interpret=None):
    """q: (B,Hq,hd); k/v: (B,C,Hkv,hd); lengths: (B,) -> (B,Hq,hd)."""
    interpret = default_interpret() if interpret is None else interpret
    c = k.shape[1]
    block_k = min(block_k, c)
    pad = (-c) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return _dec(q, k, v, lengths, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_op(q, k_pool, v_pool, block_tables, lengths, *,
                              interpret=None):
    """q: (B,Hq,hd); pools: (n_blocks,bs,Hkv,hd); block_tables: (B,MB);
    lengths: (B,) -> (B,Hq,hd). Zero-length rows return exact zeros."""
    interpret = default_interpret() if interpret is None else interpret
    return _paged_dec(q, k_pool, v_pool, block_tables, lengths,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_op(x, dt, A, B, C, *, chunk=256, interpret=None):
    """Chunked SSD; pads s to a chunk multiple (dt=0 padding is
    state-neutral). Returns (y, final_state)."""
    interpret = default_interpret() if interpret is None else interpret
    s = x.shape[1]
    chunk = min(chunk, s) if s < chunk else chunk
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, fin = _ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return y[:, :s], fin


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_op(x, scale, eps=1e-5, *, block_rows=256, interpret=None):
    """x: (..., d) -> same shape."""
    interpret = default_interpret() if interpret is None else interpret
    shape = x.shape
    d = shape[-1]
    rows = 1
    for dim in shape[:-1]:
        rows *= dim
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = _rms(xf, scale, eps, block_rows=block_rows, interpret=interpret)
    return out[:rows].reshape(shape)
