"""MusicGen-large: decoder-only transformer over EnCodec tokens
[arXiv:2306.05284]. The EnCodec audio frontend is a stub per the assignment
carve-out: input_specs() provides precomputed frame embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", arch_type="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    frontend="audio", frontend_positions=256,
    source="arXiv:2306.05284",
)
