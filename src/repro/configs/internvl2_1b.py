"""InternVL2-1B: InternViT vision encoder + Qwen2-0.5B-style LM
[arXiv:2404.16821]. Vision frontend (ViT + projector) is a stub per the
assignment carve-out; we implement the language backbone consuming patch
embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", arch_type="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151655, qkv_bias=True,
    frontend="vision", frontend_positions=256,
    source="arXiv:2404.16821",
)
