"""Qwen2-72B: dense GQA decoder with QKV bias [arXiv:2407.10671]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", arch_type="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064, qkv_bias=True,
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671",
)
