"""DeepSeek-V2 (236B total / 21B active): MLA (kv_lora=512) + MoE with
2 shared + 160 routed experts, top-6 [arXiv:2405.04434]."""
from .base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", arch_type="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    head_dim=128,
    d_ff=1536, vocab_size=102400, attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536),
    source="arXiv:2405.04434",
)
