"""Mamba2-370m: attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m", arch_type="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280, attention="none",
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
