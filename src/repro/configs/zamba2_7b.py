"""Zamba2-7B: Mamba2 backbone with shared attention blocks [arXiv:2411.15242].

The shared transformer block (attention + MLP, weights shared across all
invocations) is interleaved after every 6th Mamba2 layer, Zamba2-style.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", arch_type="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64),
    hybrid_attn_every=6,
    source="arXiv:2411.15242",
)
