"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from .base import ModelConfig, MoEConfig, MLAConfig, SSMConfig, InputShape, INPUT_SHAPES

from . import (qwen2_72b, zamba2_7b, musicgen_large, tinyllama_1_1b,
               mamba2_370m, phi3_5_moe, internvl2_1b, granite_34b,
               deepseek_v2_236b, qwen1_5_4b)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen2_72b, zamba2_7b, musicgen_large, tinyllama_1_1b,
              mamba2_370m, phi3_5_moe, internvl2_1b, granite_34b,
              deepseek_v2_236b, qwen1_5_4b)
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "InputShape",
           "INPUT_SHAPES", "ARCHS", "get_config"]
