"""Model configuration system.

Every assigned architecture is expressed as a ``ModelConfig``. The config is
a plain frozen dataclass (no external deps) and fully determines:
  - parameter shapes (via ``repro.models.params.init_params`` /
    ``abstract_params``),
  - the layer program (dense attention / MLA / MoE / SSD / hybrid schedule),
  - cache kinds for serving,
  - sharding rules (via ``repro.launch.sharding``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 0
    n_shared: int = 0             # shared (always-on) experts
    d_ff_expert: int = 0          # hidden size per expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD config."""
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp_type: str = "swiglu"      # "swiglu" (3 mats) | "gelu" (2 mats)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    # attention variant: "gqa" | "mla" | "none" (attention-free)
    attention: str = "gqa"
    # sliding window (tokens); 0 = full attention. Used for long_500k.
    sliding_window: int = 0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: a shared attention block is invoked after every
    # ``hybrid_attn_every`` SSM layers (Zamba2-style shared block).
    hybrid_attn_every: int = 0
    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    # number of frontend embedding positions prepended in serve shapes
    frontend_positions: int = 0
    source: str = ""              # citation

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Logical layer schedule, e.g. ('attn', 'attn', ...) or hybrid mix."""
        if self.arch_type == "ssm":
            return tuple("ssm" for _ in range(self.n_layers))
        if self.arch_type == "hybrid":
            kinds = []
            for i in range(self.n_layers):
                if self.hybrid_attn_every and (i + 1) % self.hybrid_attn_every == 0:
                    kinds.append("shared_attn")
                else:
                    kinds.append("ssm")
            return tuple(kinds)
        return tuple("attn" for _ in range(self.n_layers))

    # ------------------------------------------------------------------
    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs roofline)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        per_layer_attn = 0
        if self.attention == "mla" and self.mla is not None:
            m = self.mla
            per_layer_attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        elif self.attention == "gqa":
            per_layer_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                per_layer_attn += self.q_dim + 2 * self.kv_dim
        if self.is_moe:
            moe = self.moe
            per_layer_mlp = (
                moe.n_experts * 3 * d * moe.d_ff_expert
                + moe.n_shared * 3 * d * moe.d_ff_expert
                + d * moe.n_experts  # router
            )
        else:
            n_mats = 3 if self.mlp_type == "swiglu" else 2
            per_layer_mlp = n_mats * d * ff
        per_ssm = 0
        if self.ssm is not None:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            # in_proj (z, x, B, C, dt), conv, A, D, norm, out_proj
            per_ssm = (
                d * (2 * di + 2 * s.d_state + nh)
                + s.conv_width * (di + 2 * s.d_state)
                + 2 * nh
                + di
                + di * d
            )
        kinds = self.layer_kinds()
        n_attn = sum(1 for k in kinds if k == "attn")
        n_ssm = sum(1 for k in kinds if k == "ssm")
        n_shared_attn = 1 if any(k == "shared_attn" for k in kinds) else 0
        total += n_attn * (per_layer_attn + per_layer_mlp + 2 * d)
        total += n_ssm * (per_ssm + d)
        # shared attention block (counted once: weights are shared)
        total += n_shared_attn * (per_layer_attn + (3 if self.mlp_type == "swiglu" else 2) * d * ff + 2 * d)
        total += d  # final norm
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if not self.is_moe:
            return self.n_params()
        moe = self.moe
        d = self.d_model
        dense_like = dataclasses.replace(self, moe=None, d_ff=1)
        base = dense_like.n_params() - self.n_layers * 3 * d  # strip d_ff=1 mlps
        active_mlp = (moe.top_k + moe.n_shared) * 3 * d * moe.d_ff_expert + d * moe.n_experts
        return base + self.n_layers * active_mlp

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        head_dim = max(d_model // n_heads, 32) if n_heads else 0
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        moe = None
        if self.is_moe:
            moe = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                d_ff_expert=min(self.moe.d_ff_expert, 512),
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora_rank=64, q_lora_rank=96,
                            qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=32,
                                      chunk_size=32)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2 if self.hybrid_attn_every == 0 else 4,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            moe=moe,
            mla=mla,
            ssm=ssm,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            frontend_positions=min(self.frontend_positions, 8),
        )

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return dataclasses.replace(self, sliding_window=window)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
