"""Multi-tenant serving: identity, fair share, budgets, span export.

See ``docs/TENANCY.md``.  The subsystem is strictly additive: with no
tenants configured (every ``RunSpec.tenant == ""``, no ``Tenancy`` on
the session, no weights on the driver) the stack behaves bit-identically
to the pre-tenancy code.
"""
from .budget import HARD, OK, SOFT, BudgetMeter, DegradePolicy, Tenancy
from .fair_share import DeficitRoundRobin, FairShareGate, TenantQueue
from .registry import DEFAULT_TENANT, Tenant, TenantRegistry
from .tracing import (Span, export_otlp_json, fold_spans, spans_for_result,
                      to_otlp)

__all__ = [
    "DEFAULT_TENANT", "Tenant", "TenantRegistry",
    "BudgetMeter", "DegradePolicy", "Tenancy", "OK", "SOFT", "HARD",
    "DeficitRoundRobin", "FairShareGate", "TenantQueue",
    "Span", "fold_spans", "spans_for_result", "to_otlp",
    "export_otlp_json",
]
