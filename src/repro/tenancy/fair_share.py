"""Weighted fair-share admission: deficit round-robin (DRR) over tenants.

Three layers, smallest first:

  * :class:`DeficitRoundRobin` — the pure scheduling core.  Tenants
    accumulate *deficit* in proportion to their weight each time the
    round-robin pointer visits them; an admission spends ``cost`` units
    of it.  Over any contended window, admissions per tenant converge to
    the weight ratio — a bursting tenant is throttled to its share, an
    idle tenant's credit is reset (no hoarding), and nobody starves
    (every ring pass replenishes every backlogged tenant).  Fully
    deterministic: no randomness, insertion-ordered ring.

  * :class:`FairShareGate` — the virtual-clock capacity gate
    (:mod:`repro.traffic.driver`): the drop-in tenant-aware replacement
    for ``VirtualSemaphore``.  Waiters park per-tenant; each freed slot
    is granted to the DRR-chosen tenant's oldest waiter.  Parked waiters
    count as *blocked* on the shared ``VirtualTimeline``, so a queued
    run's wait shows up as measured queueing delay, exactly like the
    plain semaphore.  With a single tenant the gate degenerates to FIFO
    — bit-identical to ``VirtualSemaphore`` (tested).

  * :class:`TenantQueue` — the real-mode admission structure layered
    between ``BatchScheduler.submit`` and the scheduler's priority
    classes: one priority heap per tenant, drained in DRR order.  DRR
    picks WHICH tenant admits next; ``priority`` (FIFO within a class)
    still orders that tenant's own requests — fairness across
    principals, urgency within one.
"""
from __future__ import annotations

import asyncio
import heapq
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from .registry import TenantRegistry


def _weight_fn(weights) -> Callable[[str], float]:
    if weights is None:
        return lambda tenant: 1.0
    if isinstance(weights, TenantRegistry):
        return weights.weight
    if isinstance(weights, dict):
        return lambda tenant: weights.get(tenant, 1.0)
    return weights   # already a callable


class DeficitRoundRobin:
    """The DRR core: pick the next tenant to admit among the backlogged.

    ``weights`` may be a :class:`TenantRegistry`, a plain dict, a
    callable ``tenant -> weight``, or ``None`` (all weights 1.0).
    ``quantum`` scales how much deficit one ring visit grants
    (``quantum * weight``); with unit admission cost any positive value
    yields the same long-run shares.
    """

    def __init__(self, weights=None, quantum: float = 1.0):
        self.weight = _weight_fn(weights)
        self.quantum = quantum
        self._ring: List[str] = []
        self._idx = 0
        self._visited = False    # current position already replenished?
        self._deficit: Dict[str, float] = {}
        self.admitted: Dict[str, int] = {}

    def _observe(self, backlogged: Iterable[str]) -> List[str]:
        active = []
        for t in backlogged:
            if t not in self._deficit:
                self._deficit[t] = 0.0
                self._ring.append(t)
            active.append(t)
        return active

    def next_tenant(self, backlogged: Iterable[str],
                    cost: float = 1.0) -> Optional[str]:
        """Charge ``cost`` against the DRR-chosen backlogged tenant and
        return its name (``None`` when nothing is backlogged)."""
        t = self._advance(set(self._observe(backlogged)), cost, charge=True)
        if t is not None:
            self.admitted[t] = self.admitted.get(t, 0) + 1
        return t

    def preview(self, backlogged: Iterable[str],
                cost: float = 1.0) -> Optional[str]:
        """What :meth:`next_tenant` would return, without charging."""
        return self._advance(set(self._observe(backlogged)), cost,
                             charge=False)

    def _advance(self, active: set, cost: float,
                 charge: bool) -> Optional[str]:
        if not active:
            return None
        idx, visited = self._idx, self._visited
        deficit = self._deficit if charge else dict(self._deficit)
        # each full ring pass replenishes every backlogged tenant by
        # quantum*weight, so as long as one weight is positive the loop
        # terminates; the guard is a defensive ceiling, not a budget
        for _ in range(64 * (len(self._ring) + 1)
                       * max(2, int(cost / self.quantum) + 1)):
            t = self._ring[idx % len(self._ring)]
            if t not in active:
                # idle tenants lose their credit: an empty queue must not
                # hoard deficit and burst past its share later
                deficit[t] = 0.0
                idx, visited = idx + 1, False
                continue
            if not visited:
                deficit[t] += self.quantum * self.weight(t)
                visited = True
            if deficit[t] >= cost:
                if charge:
                    deficit[t] -= cost
                    self._idx, self._visited = idx, visited
                return t
            idx, visited = idx + 1, False
        raise RuntimeError("DRR failed to converge — non-positive weights?")

    def shares(self) -> Dict[str, float]:
        total = sum(self.admitted.values())
        return {t: n / total for t, n in self.admitted.items()} if total \
            else {}


class FairShareGate:
    """DRR capacity gate over a :class:`repro.traffic.driver.VirtualTimeline`.

    Duck-types ``VirtualSemaphore`` (``acquire``/``release``), with the
    acquiring run's tenant as the extra argument.  Waiters park in
    per-tenant FIFO queues; each release (or initial free slot) is
    dispatched to the tenant :class:`DeficitRoundRobin` picks.  The
    ``admissions`` log — ``(virtual time, tenant, contended)`` with
    ``contended`` true when EVERY tenant that has arrived so far had
    queued work — is what the noisy-neighbor benchmark reads
    weight-proportionality off.
    """

    def __init__(self, timeline, capacity: int, weights=None,
                 quantum: float = 1.0):
        self._tl = timeline
        self._free = capacity
        self.capacity = capacity
        self._drr = DeficitRoundRobin(weights, quantum=quantum)
        self._queues: Dict[str, deque] = {}
        self._seen: set = set()
        self.admissions: List[Tuple[float, str, bool]] = []

    async def acquire(self, tenant: str = "") -> None:
        fut = asyncio.get_running_loop().create_future()
        self._seen.add(tenant)
        self._queues.setdefault(tenant, deque()).append(fut)
        self._tl._blocked += 1
        self._dispatch()
        self._tl._maybe_fire()
        await fut

    def release(self) -> None:
        self._free += 1
        self._dispatch()

    def _backlogged(self) -> List[str]:
        return [t for t, q in self._queues.items() if q]

    def _dispatch(self) -> None:
        while self._free > 0:
            backlogged = self._backlogged()
            tenant = self._drr.next_tenant(backlogged)
            if tenant is None:
                return
            fut = self._queues[tenant].popleft()
            self._tl._blocked -= 1
            self._free -= 1
            self.admissions.append((self._tl.now(), tenant,
                                    len(backlogged) == len(self._seen)))
            fut.set_result(None)

    def queue_depth(self) -> int:
        return sum(len(q) for q in self._queues.values())


class TenantQueue:
    """Per-tenant priority heaps drained in DRR order — the real-mode
    admission layer for :class:`repro.serving.scheduler.BatchScheduler`.

    ``push`` files an item under its tenant with the scheduler's own
    sort key (``(-priority, seq)``: priority classes, FIFO within);
    ``pop`` charges the DRR and returns the chosen tenant's head;
    ``peek`` previews it without charging.  ``pop_same_tenant`` grows a
    same-bucket prefill group without crossing tenants more than the DRR
    allows."""

    def __init__(self, weights=None, quantum: float = 1.0):
        self._drr = DeficitRoundRobin(weights, quantum=quantum)
        self._heaps: Dict[str, List] = {}

    def _backlogged(self) -> List[str]:
        return [t for t, h in self._heaps.items() if h]

    def push(self, tenant: str, key: tuple, item: Any) -> None:
        heapq.heappush(self._heaps.setdefault(tenant, []), (key, item))

    def peek(self) -> Optional[Any]:
        t = self._drr.preview(self._backlogged())
        return self._heaps[t][0][1] if t is not None else None

    def pop(self) -> Optional[Tuple[str, Any]]:
        t = self._drr.next_tenant(self._backlogged())
        if t is None:
            return None
        return t, heapq.heappop(self._heaps[t])[1]

    def pop_same_tenant(self, tenant: str,
                        pred: Callable[[Any], bool]) -> Optional[Any]:
        """Pop ``tenant``'s head iff the DRR would pick that tenant next
        AND ``pred`` accepts the head — one more admission inside the
        tenant's own share, never a cross-tenant cut."""
        heap = self._heaps.get(tenant)
        if not heap or not pred(heap[0][1]):
            return None
        if self._drr.preview(self._backlogged()) != tenant:
            return None
        self._drr.next_tenant(self._backlogged())
        return heapq.heappop(heap)[1]

    def __len__(self) -> int:
        return sum(len(h) for h in self._heaps.values())

    def shares(self) -> Dict[str, float]:
        return self._drr.shares()
