"""Per-tenant budgets: metering, graceful degradation, hard rejection.

The :class:`BudgetMeter` accumulates each tenant's Eq. 1 (LLM token
cost) + Eq. 2 (FaaS invocation cost) spend from finished runs'
accounting traces.  Two thresholds per axis (tokens, dollars):

* **soft** — ``soft_fraction`` (default 0.8) of the tenant's cap: the
  tenant keeps running, but :class:`DegradePolicy` downgrades each new
  run to a cheaper configuration (pattern and/or deployment) and emits
  a :class:`repro.core.events.RunDegraded` on the run's stream.
* **hard** — the cap itself: new runs are rejected outright with a
  typed :class:`repro.core.events.BudgetExceeded` event; nothing is
  built, nothing billed.

The default tenant (``""``) has infinite caps, so the whole machinery
is inert until somebody configures a :class:`repro.tenancy.Tenant` with
finite budgets — the tenancy-off parity contract.

:class:`Tenancy` bundles registry + meter + degrade policy into the one
object ``Session(tenancy=...)`` takes.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Optional, Tuple

from .registry import Tenant, TenantRegistry

#: meter states, in order of severity
OK, SOFT, HARD = "ok", "soft", "hard"


class BudgetMeter:
    """Thread-safe per-tenant token/cost accumulator with soft/hard
    exhaustion states.

    ``charge`` is called by the session after every finished run with
    the run's billed tokens and Eq. 1+2 dollars; ``state`` classifies a
    tenant before admission.  Rejected runs are tallied (for telemetry)
    but never billed."""

    def __init__(self, registry: TenantRegistry,
                 soft_fraction: float = 0.8):
        if not 0.0 < soft_fraction <= 1.0:
            raise ValueError(f"soft_fraction must be in (0, 1] "
                             f"(got {soft_fraction})")
        self.registry = registry
        self.soft_fraction = soft_fraction
        self._lock = threading.Lock()
        self._tokens: Dict[str, float] = {}
        self._cost: Dict[str, float] = {}
        self._degraded: Dict[str, int] = {}
        self._rejected: Dict[str, int] = {}

    def charge(self, tenant: str, tokens: float, cost_usd: float) -> None:
        with self._lock:
            self._tokens[tenant] = self._tokens.get(tenant, 0.0) + tokens
            self._cost[tenant] = self._cost.get(tenant, 0.0) + cost_usd

    def record_degraded(self, tenant: str) -> None:
        with self._lock:
            self._degraded[tenant] = self._degraded.get(tenant, 0) + 1

    def record_rejected(self, tenant: str) -> None:
        with self._lock:
            self._rejected[tenant] = self._rejected.get(tenant, 0) + 1

    def used(self, tenant: str) -> Tuple[float, float]:
        with self._lock:
            return (self._tokens.get(tenant, 0.0),
                    self._cost.get(tenant, 0.0))

    def _axis_state(self, used: float, cap: float) -> str:
        if math.isinf(cap):
            return OK
        if used >= cap:
            return HARD
        if used >= self.soft_fraction * cap:
            return SOFT
        return OK

    def state(self, tenant: str) -> str:
        """``"ok"`` | ``"soft"`` | ``"hard"`` — the worse of the two
        axes."""
        t = self.registry.resolve(tenant)
        tokens, cost = self.used(tenant)
        states = (self._axis_state(tokens, t.token_budget),
                  self._axis_state(cost, t.cost_budget_usd))
        if HARD in states:
            return HARD
        if SOFT in states:
            return SOFT
        return OK

    def exhausted_axis(self, tenant: str) -> Tuple[str, float, float]:
        """For a HARD tenant: ``(kind, used, budget)`` of the axis that
        tripped (tokens first, then cost)."""
        t = self.registry.resolve(tenant)
        tokens, cost = self.used(tenant)
        if self._axis_state(tokens, t.token_budget) == HARD:
            return "tokens", tokens, t.token_budget
        return "cost", cost, t.cost_budget_usd

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant telemetry: tokens, cost, degraded/rejected counts,
        current state."""
        with self._lock:
            names = (set(self._tokens) | set(self._cost)
                     | set(self._degraded) | set(self._rejected))
        return {name: {
            "tokens": self._tokens.get(name, 0.0),
            "cost_usd": self._cost.get(name, 0.0),
            "degraded_runs": self._degraded.get(name, 0),
            "rejected_runs": self._rejected.get(name, 0),
            "state": self.state(name),
        } for name in sorted(names)}


class DegradePolicy:
    """Maps a soft-exhausted tenant's spec to a cheaper one.

    Two independent axes, both optional:

    * **deployment** — remote transports fall back to in-process
      execution (``faas``/``faas-mono``/``a2a`` → ``local``), shedding
      the Eq. 2 invocation bill and the simulated network overhead.
    * **pattern** — ``agentx`` → ``agentx-compiled`` *only when* the
      session's plan cache already holds a graph for the (possibly
      deployment-degraded) spec's task template: compiled replay skips
      the planner/critic LLM calls.  The spec's ``pattern`` field is NOT
      rewritten for this axis — the plan key is pattern-scoped, and the
      session replays a cached graph on its own — the policy merely
      *commits* the run to the compiled path and reports it; a downgrade
      whose graph is not cached would fall straight back to full
      planning, so it is skipped.

    :meth:`degrade` returns ``(spec', info)``: ``spec'`` is the spec to
    execute and ``info`` is ``None`` when nothing applies, else the
    from/to description for the :class:`repro.core.events.RunDegraded`
    event."""

    DEPLOYMENT_MAP = {"faas": "local", "faas-mono": "local", "a2a": "local"}
    PATTERN_MAP = {"agentx": "agentx-compiled"}

    def __init__(self, deployment_map: Optional[dict] = None,
                 pattern_map: Optional[dict] = None):
        self.deployment_map = (self.DEPLOYMENT_MAP if deployment_map is None
                               else dict(deployment_map))
        self.pattern_map = (self.PATTERN_MAP if pattern_map is None
                            else dict(pattern_map))

    def degrade(self, spec, plan_cache=None):
        """Cheapen ``spec``: returns ``(new_spec, info)`` — see class
        docstring."""
        import dataclasses

        to_dep = self.deployment_map.get(spec.deployment, spec.deployment)
        to_pat = spec.pattern
        mapped = self.pattern_map.get(spec.pattern)
        changes = {}
        if to_dep != spec.deployment:
            changes["deployment"] = to_dep
        if mapped == "agentx-compiled":
            # probe under the (possibly degraded) deployment: the plan
            # key is deployment-scoped too
            probe = (dataclasses.replace(spec, **changes) if changes
                     else spec)
            if plan_cache is not None and self._plan_cached(probe,
                                                            plan_cache):
                to_pat = mapped    # spec.pattern intentionally unchanged
        elif mapped is not None:
            to_pat = mapped
            changes["pattern"] = mapped
        if to_dep == spec.deployment and to_pat == spec.pattern:
            return spec, None
        new_spec = dataclasses.replace(spec, **changes) if changes else spec
        return new_spec, {
            "from_pattern": spec.pattern, "to_pattern": to_pat,
            "from_deployment": spec.deployment, "to_deployment": to_dep,
        }

    @staticmethod
    def _plan_cached(spec, plan_cache) -> bool:
        try:
            from repro.plans.compile import plan_key
            return plan_cache.get(plan_key(spec)) is not None
        except Exception:
            return False


class Tenancy:
    """The bundle ``Session(tenancy=...)`` takes: registry + meter +
    degrade policy.  Constructing it with just a registry gives
    fair-share weights and telemetry with no budget enforcement."""

    def __init__(self, registry: Optional[TenantRegistry] = None,
                 soft_fraction: float = 0.8,
                 degrade: Optional[DegradePolicy] = None):
        self.registry = registry if registry is not None else TenantRegistry()
        self.meter = BudgetMeter(self.registry, soft_fraction=soft_fraction)
        self.degrade = degrade if degrade is not None else DegradePolicy()

    @classmethod
    def with_tenants(cls, *tenants: Tenant, **kw) -> "Tenancy":
        return cls(TenantRegistry(*tenants), **kw)
