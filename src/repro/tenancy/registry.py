"""Tenant identity: who a run is billed to, and what they are entitled to.

A :class:`Tenant` names one principal sharing the serving stack — its
fair-share ``weight`` (deficit-round-robin admission,
:mod:`repro.tenancy.fair_share`), its token/cost budgets (metered by
:class:`repro.tenancy.budget.BudgetMeter`), and its SLO class.  The
:class:`TenantRegistry` resolves ``RunSpec.tenant`` names; the empty name
``""`` is the single DEFAULT tenant — unlimited budget, weight 1.0 — so
a stack that never mentions tenants behaves exactly as before tenancy
existed (the bit-identical parity contract).

Like ``priority``, a spec's ``tenant`` steers scheduling and billing,
never the run's content: it is EXCLUDED from the ``World`` seed and the
plan-cache key, but INCLUDED in the run-cache fingerprint — two tenants
issuing the identical request share a plan graph yet never a cached
result billed to the wrong principal.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, Optional

#: the implicit single-tenant principal (``RunSpec.tenant == ""``)
DEFAULT_TENANT = ""


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One principal: fair-share weight, budgets, SLO class.

    ``token_budget`` / ``cost_budget_usd`` are hard caps over the
    meter's lifetime (``inf`` = unlimited); soft exhaustion — the point
    where :class:`repro.tenancy.budget.DegradePolicy` starts downgrading
    runs — is a *fraction* of the hard cap, owned by the meter, not the
    tenant."""
    name: str
    weight: float = 1.0
    token_budget: float = math.inf
    cost_budget_usd: float = math.inf
    slo_class: str = "standard"

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0 "
                             f"(got {self.weight})")


class TenantRegistry:
    """Name -> :class:`Tenant` table with a permissive default.

    Unknown names resolve to an unlimited weight-1.0 tenant of that name
    (registered on first resolve), so traffic can stamp tenants before
    anyone configures entitlements — configuration tightens behavior, it
    never gates admission."""

    def __init__(self, *tenants: Tenant):
        self._tenants: Dict[str, Tenant] = {}
        self.register(Tenant(DEFAULT_TENANT))
        for t in tenants:
            self.register(t)

    def register(self, tenant: Tenant) -> Tenant:
        self._tenants[tenant.name] = tenant
        return tenant

    def resolve(self, name: str) -> Tenant:
        t = self._tenants.get(name)
        if t is None:
            t = self.register(Tenant(name))
        return t

    def weight(self, name: str) -> float:
        return self.resolve(name).weight

    def get(self, name: str) -> Optional[Tenant]:
        return self._tenants.get(name)

    def __iter__(self) -> Iterator[Tenant]:
        return iter(self._tenants.values())

    def __len__(self) -> int:
        return len(self._tenants)

    def names(self) -> list:
        return list(self._tenants)

    def describe(self) -> Dict[str, Dict]:
        return {t.name or "<default>": {
            "weight": t.weight,
            "token_budget": (None if math.isinf(t.token_budget)
                             else t.token_budget),
            "cost_budget_usd": (None if math.isinf(t.cost_budget_usd)
                                else t.cost_budget_usd),
            "slo_class": t.slo_class,
        } for t in self}
