"""OTel-style span export: fold a ``RunEvent`` stream into a span tree.

The event stream is already the run's complete history — it replays
bit-identically across FaaS / A2A wire boundaries (PR 4/5 parity) — so
spans are a *derived view*, never a second instrumentation path:
``fold_spans(events)`` on an in-process stream and on
``events_from_wire(events_to_wire(events))`` produce identical trees
(tested).

Tree shape::

    run (RunStarted .. RunCompleted)           tenant, pattern, cost attrs
    ├── stage[i] (StageStarted .. StageCompleted / next stage)
    │   ├── llm  <agent>        [t-latency, t]   token + cost attrs
    │   ├── tool <server.tool>  [t-latency, t]
    │   │   ├── retry #n        zero-width, at the retry's emission time
    │   │   └── hedge           zero-width, winner/saved_s attrs
    │   └── annotation events (PlanProduced, ReflectionEmitted, ...)
    └── (patterns without stages — react — attach children to the run)

Every span carries the run's ``tenant`` and its own ``cost_usd``
(Eq. 1 for llm spans, summed upward), so a span dump is a billing
attribution document.  **Losslessness**: every event in the stream is
represented — as a span, or as a zero-width annotation event on the
innermost open span — so no accounting escapes the export.

``to_otlp`` renders the tree as OTLP-shaped JSON
(``resourceSpans → scopeSpans → spans`` with hex trace/span ids and
UnixNano timestamps); ids are deterministic sequence numbers, keeping
exports reproducible under the virtual clock.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from repro.core.events import (BudgetExceeded, LLMCompleted,
                               OverheadIncurred, RunCompleted, RunDegraded,
                               RunEvent, RunHedged, RunStarted,
                               StageCompleted, StageStarted, ToolInvoked,
                               ToolRetried)


@dataclasses.dataclass
class Span:
    """One node of the tree.  ``start``/``end`` are virtual-clock
    seconds; zero-width spans (retry/hedge markers) have
    ``start == end``."""
    name: str
    kind: str                     # run | stage | llm | tool | retry | hedge
    start: float
    end: float
    span_id: str
    parent_id: Optional[str]
    attributes: Dict[str, Any] = dataclasses.field(default_factory=dict)
    events: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    children: List["Span"] = dataclasses.field(default_factory=list)

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()


class _Ids:
    """Deterministic 8-byte hex span ids: a simple counter, so the same
    event stream always yields the same ids (virtual clock, no RNG)."""

    def __init__(self):
        self._n = 0

    def next(self) -> str:
        self._n += 1
        return "%016x" % self._n


def fold_spans(events: List[RunEvent],
               service: str = "repro") -> List[Span]:
    """Fold one run's event stream into its span tree (list of roots —
    normally one run span; pre-run admission events such as
    ``BudgetExceeded`` on a rejected stream produce a zero-width root)."""
    ids = _Ids()
    roots: List[Span] = []
    run: Optional[Span] = None
    stage: Optional[Span] = None
    # retries/hedges are emitted DURING a tool call, before its
    # ToolInvoked: buffer per (server, tool) and attach to the next
    # matching tool span
    pending: Dict[tuple, List[Span]] = {}
    # admission decisions (RunDegraded) precede RunStarted: buffer and
    # attach to the run span once it opens
    preamble: List[Span] = []

    def container() -> Optional[Span]:
        return stage if stage is not None else run

    def close_stage(t: float, success: Optional[bool] = None) -> None:
        nonlocal stage
        if stage is None:
            return
        stage.end = t
        if success is not None:
            stage.attributes["success"] = success
        stage = None

    for ev in events:
        if isinstance(ev, RunStarted):
            run = Span(name=f"run {ev.pattern}", kind="run", start=ev.t,
                       end=ev.t, span_id=ids.next(), parent_id=None,
                       attributes={"service": service,
                                   "tenant": ev.tenant,
                                   "pattern": ev.pattern,
                                   "task": ev.task})
            for p in preamble:
                p.parent_id = run.span_id
                run.children.append(p)
            preamble.clear()
            roots.append(run)
        elif isinstance(ev, StageStarted):
            close_stage(ev.t)
            parent = run
            stage = Span(name=f"stage[{ev.index}] {ev.name}", kind="stage",
                         start=ev.t, end=ev.t, span_id=ids.next(),
                         parent_id=parent.span_id if parent else None,
                         attributes={"index": ev.index})
            if parent is not None:
                parent.children.append(stage)
            else:
                roots.append(stage)
        elif isinstance(ev, StageCompleted):
            close_stage(ev.t, success=ev.success)
        elif isinstance(ev, LLMCompleted):
            e = ev.event
            parent = container()
            span = Span(name=f"llm {e.agent}", kind="llm",
                        start=ev.t - e.latency, end=ev.t,
                        span_id=ids.next(),
                        parent_id=parent.span_id if parent else None,
                        attributes={"agent": e.agent,
                                    "input_tokens": e.input_tokens,
                                    "output_tokens": e.output_tokens,
                                    "cost_usd": e.cost})
            (parent.children if parent else roots).append(span)
        elif isinstance(ev, ToolInvoked):
            e = ev.event
            parent = container()
            span = Span(name=f"tool {e.server}.{e.tool}", kind="tool",
                        start=ev.t - e.latency, end=ev.t,
                        span_id=ids.next(),
                        parent_id=parent.span_id if parent else None,
                        attributes={"server": e.server, "tool": e.tool,
                                    "ok": e.ok})
            for child in pending.pop((e.server, e.tool), []):
                child.parent_id = span.span_id
                span.children.append(child)
            (parent.children if parent else roots).append(span)
        elif isinstance(ev, ToolRetried):
            pending.setdefault((ev.server, ev.tool), []).append(
                Span(name=f"retry #{ev.attempt}", kind="retry",
                     start=ev.t, end=ev.t, span_id=ids.next(),
                     parent_id=None,
                     attributes={"attempt": ev.attempt, "error": ev.error,
                                 "backoff_s": ev.backoff_s}))
        elif isinstance(ev, RunHedged):
            pending.setdefault((ev.server, ev.tool), []).append(
                Span(name=f"hedge {ev.winner}", kind="hedge",
                     start=ev.t, end=ev.t, span_id=ids.next(),
                     parent_id=None,
                     attributes={"winner": ev.winner,
                                 "primary_s": ev.primary_s,
                                 "hedge_s": ev.hedge_s,
                                 "saved_s": ev.saved_s}))
        elif isinstance(ev, RunCompleted):
            close_stage(ev.t)
            if run is not None:
                run.end = ev.t
                run.attributes["completed"] = ev.completed
        elif isinstance(ev, RunDegraded) and run is None:
            preamble.append(
                Span(name="degraded", kind="admission", start=ev.t,
                     end=ev.t, span_id=ids.next(), parent_id=None,
                     attributes={"tenant": ev.tenant, "reason": ev.reason,
                                 "from_pattern": ev.from_pattern,
                                 "to_pattern": ev.to_pattern,
                                 "from_deployment": ev.from_deployment,
                                 "to_deployment": ev.to_deployment}))
        elif isinstance(ev, BudgetExceeded) and run is None:
            roots.append(
                Span(name="rejected", kind="admission", start=ev.t,
                     end=ev.t, span_id=ids.next(), parent_id=None,
                     attributes={"tenant": ev.tenant, "kind": ev.kind,
                                 "used": ev.used, "budget": ev.budget}))
        else:
            # losslessness: every remaining event (PlanProduced,
            # ReflectionEmitted, PlanCompiled, EngineStepped, ...)
            # becomes a zero-width annotation on the innermost open span
            c = container()
            record = {"t": ev.t, "type": type(ev).__name__}
            for f in dataclasses.fields(ev):
                if f.name == "t":
                    continue
                record[f.name] = _short(getattr(ev, f.name))
            if c is not None:
                c.events.append(record)
            else:
                roots.append(Span(name=type(ev).__name__, kind="event",
                                  start=ev.t, end=ev.t,
                                  span_id=ids.next(), parent_id=None,
                                  attributes=record))

    # orphaned retries/hedges (policy gave up before any ToolInvoked):
    # attach to the innermost open container so nothing is dropped
    for key, orphans in sorted(pending.items()):
        target = container() or run
        for o in orphans:
            if target is not None:
                o.parent_id = target.span_id
                target.children.append(o)
            else:
                roots.append(o)

    for root in roots:
        _propagate(root, root.attributes.get("tenant", ""))
    return roots


def _short(v: Any, limit: int = 200) -> Any:
    if isinstance(v, (bool, int, float)) or v is None:
        return v
    s = v if isinstance(v, str) else repr(v)
    return s if len(s) <= limit else s[:limit] + "…"


def _propagate(span: Span, tenant: str) -> float:
    """Stamp ``tenant`` on every span and roll ``cost_usd`` upward
    (a parent's cost = own + sum of children's)."""
    span.attributes.setdefault("tenant", tenant)
    cost = float(span.attributes.get("cost_usd", 0.0))
    for c in span.children:
        cost += _propagate(c, tenant)
    span.attributes["cost_usd"] = cost
    return cost


def spans_for_result(result) -> List[Span]:
    """Span tree for a finished :class:`repro.core.metrics.RunResult`
    (its ``extras["events"]`` stream)."""
    return fold_spans(list(result.extras.get("events", ())))


# ---------------------------------------------------------------------------
# OTLP-shaped JSON export

def _nanos(t: float) -> int:
    return int(round(t * 1e9))


def _otlp_value(v: Any) -> Dict[str, Any]:
    if isinstance(v, bool):
        return {"boolValue": v}
    if isinstance(v, int):
        return {"intValue": str(v)}
    if isinstance(v, float):
        return {"doubleValue": v}
    return {"stringValue": str(v)}


def _otlp_span(span: Span, trace_id: str) -> Dict[str, Any]:
    d = {
        "traceId": trace_id,
        "spanId": span.span_id,
        "name": span.name,
        "kind": 1,   # SPAN_KIND_INTERNAL
        "startTimeUnixNano": str(_nanos(span.start)),
        "endTimeUnixNano": str(_nanos(span.end)),
        "attributes": [{"key": k, "value": _otlp_value(v)}
                       for k, v in sorted(span.attributes.items())],
    }
    if span.parent_id is not None:
        d["parentSpanId"] = span.parent_id
    if span.events:
        d["events"] = [{
            "timeUnixNano": str(_nanos(e["t"])),
            "name": e["type"],
            "attributes": [{"key": k, "value": _otlp_value(v)}
                           for k, v in sorted(e.items())
                           if k not in ("t", "type")],
        } for e in span.events]
    return d


def to_otlp(roots: List[Span], service: str = "repro",
            trace_id: str = "%032x" % 1) -> Dict[str, Any]:
    """Render a span tree as an OTLP/JSON ``ExportTraceServiceRequest``
    payload (the shape an OTel collector's HTTP receiver accepts)."""
    flat = [s for root in roots for s in root.walk()]
    return {"resourceSpans": [{
        "resource": {"attributes": [
            {"key": "service.name", "value": {"stringValue": service}},
        ]},
        "scopeSpans": [{
            "scope": {"name": "repro.tenancy.tracing"},
            "spans": [_otlp_span(s, trace_id) for s in flat],
        }],
    }]}


def export_otlp_json(events: List[RunEvent], service: str = "repro",
                     indent: Optional[int] = None) -> str:
    """One-call convenience: events → span tree → OTLP JSON string."""
    return json.dumps(to_otlp(fold_spans(events, service=service),
                              service=service), indent=indent,
                      sort_keys=True)
