"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,value,derived`` CSV rows per figure, plus kernel
micro-benchmarks (name,us_per_call,derived) and the roofline table if
dry-run artifacts exist.

    PYTHONPATH=src python -m benchmarks.run            # full paper protocol
    PYTHONPATH=src python -m benchmarks.run --quick    # 1 instance per app
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def kernel_microbench() -> list:
    """Kernel wall-time micro-benchmarks (interpret mode on CPU: these are
    correctness-path timings, not TPU perf — TPU numbers come from the
    roofline analysis)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import (decode_attention_op, flash_attention_op,
                               rmsnorm_op, ssd_scan_op)
    rows = ["kernel.name,us_per_call,config"]
    key = jax.random.key(0)

    def time_it(fn, *args, n=3, **kw):
        fn(*args, **kw)  # warm compile
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args, **kw))
        return (time.perf_counter() - t0) / n * 1e6

    q = jax.random.normal(key, (1, 256, 4, 64))
    k = jax.random.normal(key, (1, 256, 2, 64))
    us = time_it(flash_attention_op, q, k, k, interpret=True, block_q=128,
                 block_k=128)
    rows.append(f"kernel.flash_attention,{us:.0f},b1_s256_h4_kv2_interp")

    qd = jax.random.normal(key, (2, 8, 64))
    kd = jax.random.normal(key, (2, 512, 2, 64))
    lens = jnp.array([256, 512], jnp.int32)
    us = time_it(decode_attention_op, qd, kd, kd, lens, interpret=True)
    rows.append(f"kernel.decode_attention,{us:.0f},b2_c512_interp")

    x = jax.random.normal(key, (1, 128, 2, 32))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 128, 2)))
    A = -jnp.exp(jax.random.normal(key, (2,)))
    B = jax.random.normal(key, (1, 128, 16))
    us = time_it(ssd_scan_op, x, dt, A, B, B, chunk=64, interpret=True)
    rows.append(f"kernel.ssd_scan,{us:.0f},b1_s128_interp")

    xs = jax.random.normal(key, (512, 256))
    sc = jnp.ones((256,))
    us = time_it(rmsnorm_op, xs, sc, interpret=True)
    rows.append(f"kernel.rmsnorm,{us:.0f},rows512_d256_interp")
    return rows


def roofline_rows() -> list:
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "roofline.json")
    rows = ["roofline.arch.shape,dominant_term,compute_s;memory_s;coll_s"]
    if not os.path.exists(art):
        rows.append("roofline.missing,run `python -m benchmarks.roofline`,")
        return rows
    for r in json.load(open(art)):
        rows.append(f"roofline.{r['arch']}.{r['shape']},{r['dominant']},"
                    f"{r['compute_s']:.3e};{r['memory_s']:.3e};"
                    f"{r['collective_s']:.3e}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="1 instance per app (CI)")
    ap.add_argument("--force", action="store_true",
                    help="ignore the agent-run cache")
    ap.add_argument("--workers", type=int, default=1,
                    help="thread-pool fan-out across sweep combos")
    ap.add_argument("--cache-dir", default=None,
                    help="persist per-run results here (cold re-sweeps "
                         "replay from disk)")
    args = ap.parse_args()

    from .experiments import run_sweep
    from .figures import ALL_FIGURES

    t0 = time.time()
    records = run_sweep(full=not args.quick, force=args.force,
                        max_workers=args.workers,
                        cache_dir=args.cache_dir)
    print(f"# agent sweep: {len(records)} runs "
          f"({time.time() - t0:.0f}s wall, virtual-clock latencies)")
    for fig in ALL_FIGURES:
        print(f"\n# --- {fig.__name__} ---")
        for row in fig(records):
            print(row)

    print("\n# --- kernel microbench ---")
    for row in kernel_microbench():
        print(row)

    print("\n# --- roofline (from dry-run artifacts) ---")
    for row in roofline_rows():
        print(row)


if __name__ == "__main__":
    main()
