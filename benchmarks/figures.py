"""One function per paper table/figure. Each emits CSV rows
(name,value,derived) and returns the rows for run.py aggregation."""
from __future__ import annotations

import statistics
from typing import Dict, List

from repro.apps.apps import APPS
from repro.faas.deployments import SERVER_FACTORIES

from .experiments import (PATTERNS, all_runs, mean_of, run_sweep,
                          success_rate, successes)


def table1_servers(records) -> List[str]:
    """Table 1: MCP server descriptions."""
    rows = ["table1.server,tools,origin,execution,memory_mb,storage_mb"]
    for name, factory in sorted(SERVER_FACTORIES.items()):
        s = factory()
        r = s.describe_row()
        rows.append(f"table1.{name},{r['tools']},{r['origin']},"
                    f"{r['execution']},{r['memory_mb']},{r['storage_mb']}")
    return rows


def fig4_accuracy(records) -> List[str]:
    rows = ["fig4.app.instance.pattern,score,attr_breakdown"]
    for app in APPS:
        for inst in APPS[app].instances:
            for p in PATTERNS:
                sel = successes(records, app=app, instance=inst, pattern=p,
                                deployment="local")
                if not sel:
                    continue
                score = mean_of(sel, "score")
                attrs = {}
                for r in sel:
                    for k, v in r["score_attrs"].items():
                        attrs.setdefault(k, []).append(v)
                detail = ";".join(f"{k}={statistics.mean(v):.0f}"
                                  for k, v in attrs.items())
                rows.append(f"fig4.{app}.{inst}.{p},{score:.1f},{detail}")
    return rows


def _latency_rows(records, deployment: str, tag: str) -> List[str]:
    rows = [f"{tag}.app.instance.pattern,total_s,llm_s;tool_s;framework_s"]
    for app in APPS:
        for inst in APPS[app].instances:
            for p in PATTERNS:
                sel = successes(records, app=app, instance=inst, pattern=p,
                                deployment=deployment)
                if not sel:
                    continue
                rows.append(
                    f"{tag}.{app}.{inst}.{p},"
                    f"{mean_of(sel, 'total_latency'):.1f},"
                    f"{mean_of(sel, 'llm_latency'):.1f};"
                    f"{mean_of(sel, 'tool_latency'):.1f};"
                    f"{mean_of(sel, 'framework_latency'):.1f}")
    return rows


def fig5_latency_local(records) -> List[str]:
    return _latency_rows(records, "local", "fig5")


def fig6_latency_faas(records) -> List[str]:
    return _latency_rows(records, "faas", "fig6")


def fig7_tool_latency(records) -> List[str]:
    rows = ["fig7.tool.deployment,mean_s,n"]
    acc: Dict[tuple, List[float]] = {}
    for r in records:
        for e in r["tool_latencies"]:
            acc.setdefault((e["tool"], r["deployment"]), []).append(
                e["latency"])
    for (tool, dep), vals in sorted(acc.items()):
        rows.append(f"fig7.{tool}.{dep},{statistics.mean(vals):.2f},"
                    f"{len(vals)}")
    return rows


def fig8_local_vs_faas(records) -> List[str]:
    rows = ["fig8.app.pattern.deployment,total_s,success_rate"]
    for app in APPS:
        for p in PATTERNS:
            for dep in ("local", "faas"):
                sel = successes(records, app=app, pattern=p, deployment=dep)
                sr = success_rate(records, app=app, pattern=p,
                                  deployment=dep)
                if not sel:
                    continue
                rows.append(f"fig8.{app}.{p}.{dep},"
                            f"{mean_of(sel, 'total_latency'):.1f},{sr:.2f}")
    return rows


def _token_rows(records, dep, key, tag) -> List[str]:
    rows = [f"{tag}.app.instance.pattern,{key},n_runs"]
    for app in APPS:
        for inst in APPS[app].instances:
            for p in PATTERNS:
                sel = successes(records, app=app, instance=inst, pattern=p,
                                deployment=dep)
                if not sel:
                    continue
                rows.append(f"{tag}.{app}.{inst}.{p},"
                            f"{mean_of(sel, key):.0f},{len(sel)}")
    return rows


def fig9_input_tokens_local(records) -> List[str]:
    return _token_rows(records, "local", "input_tokens", "fig9")


def fig11_input_tokens_faas(records) -> List[str]:
    return _token_rows(records, "faas", "input_tokens", "fig11")


def fig12_output_tokens_local(records) -> List[str]:
    return _token_rows(records, "local", "output_tokens", "fig12")


def fig13_output_tokens_faas(records) -> List[str]:
    return _token_rows(records, "faas", "output_tokens", "fig13")


def fig14_cost_local(records) -> List[str]:
    return _token_rows(records, "local", "llm_cost", "fig14")


def fig15_cost_faas(records) -> List[str]:
    return _token_rows(records, "faas", "llm_cost", "fig15")


def fig16_lambda_cost(records) -> List[str]:
    rows = ["fig16.app.instance.pattern,lambda_usd,ratio_vs_llm"]
    for app in APPS:
        for inst in APPS[app].instances:
            for p in PATTERNS:
                sel = successes(records, app=app, instance=inst, pattern=p,
                                deployment="faas")
                if not sel:
                    continue
                fc = mean_of(sel, "faas_cost")
                lc = mean_of(sel, "llm_cost")
                rows.append(f"fig16.{app}.{inst}.{p},{fc:.8f},"
                            f"{fc / max(lc, 1e-12):.5f}")
    return rows


def fig17_tool_invokes_local(records) -> List[str]:
    return _token_rows(records, "local", "tool_invocations", "fig17")


def fig18_tool_invokes_faas(records) -> List[str]:
    return _token_rows(records, "faas", "tool_invocations", "fig18")


def fig19_agent_invokes_local(records) -> List[str]:
    return _token_rows(records, "local", "agent_invocations", "fig19")


def fig20_agent_invokes_faas(records) -> List[str]:
    return _token_rows(records, "faas", "agent_invocations", "fig20")


def fig10_fetch_counts(records) -> List[str]:
    rows = ["fig10.instance.pattern,fetch_calls,search_calls"]
    for inst in APPS["web_search"].instances:
        for p in PATTERNS:
            sel = successes(records, app="web_search", instance=inst,
                            pattern=p, deployment="local")
            if not sel:
                continue
            fetch = statistics.mean(
                [r["tool_breakdown"].get("fetch", 0) for r in sel])
            search = statistics.mean(
                [r["tool_breakdown"].get("google_search", 0) for r in sel])
            rows.append(f"fig10.{inst}.{p},{fetch:.1f},{search:.1f}")
    return rows


ALL_FIGURES = [
    table1_servers, fig4_accuracy, fig5_latency_local, fig6_latency_faas,
    fig7_tool_latency, fig8_local_vs_faas, fig9_input_tokens_local,
    fig10_fetch_counts, fig11_input_tokens_faas, fig12_output_tokens_local,
    fig13_output_tokens_faas, fig14_cost_local, fig15_cost_faas,
    fig16_lambda_cost, fig17_tool_invokes_local, fig18_tool_invokes_faas,
    fig19_agent_invokes_local, fig20_agent_invokes_faas,
]
