"""Plan-compilation benchmark: clean vs compiled passes over an
identical repeat-heavy workload.

Three passes over the SAME seeded arrival stream (agentx-only mix,
``unique_seeds`` capped so the stream repeats):

  1. **clean** — no plan cache: every run pays the full stage-designer +
     per-stage planner LLM calls;
  2. **cold**  — empty ``PlanCache``: first occurrence of each template
     compiles, repeats already replay planner-free within the pass;
  3. **warm**  — a fresh ``Session`` sharing the now-warm cache: steady
     state, where hits replay compiled graphs with ZERO planner calls.

Reported per pass: planner-call count (stage_generator + planner +
cot_reasoner invocations), Eq. 1 LLM cost + Eq. 2 FaaS cost, latency
percentiles, and the plan-cache hit/miss/fallback counters.  Two
invariants are asserted (the CI smoke):

  * every warm-pass run that replayed a graph (no ``PlanCacheMiss`` /
    ``PlanFallback`` on its stream) made zero planner calls, and the
    warm hit rate is > 0;
  * compiled tool-call sequences match fresh ones for deterministic
    specs: for each scenario, a fresh run of spec X and a compiled
    replay of the SAME spec X produce identical ``ToolInvoked``
    (server, tool, args) sequences and identical artifacts.  (Replays
    of a *different* seed intentionally keep the source run's anomaly
    structure — only same-spec replay is bit-deterministic.)

Merges a ``plan_cache`` section into ``artifacts/BENCH_traffic.json``
(uploaded by CI; run ``benchmarks.traffic`` first for the full file).

    PYTHONPATH=src python -m benchmarks.plans --requests 60 --rate 2
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.apps.session import RunSpec, Session
from repro.core.events import PlanCacheMiss, PlanFallback, ToolInvoked
from repro.plans import PlanCache
from repro.traffic import (Scenario, SLOTarget, TrafficDriver, Workload,
                           aggregate_report)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

#: planner-side agents — the calls plan compilation eliminates
PLANNER_AGENTS = frozenset({"stage_generator", "planner", "cot_reasoner"})

#: agentx-only mix (the compilable pattern) across apps + deployments
PLAN_MIX = (
    Scenario("web/local/agentx", "web_search", "quantum", "agentx",
             "local", weight=3.0),
    Scenario("web2/local/agentx", "web_search", "edge", "agentx",
             "local", weight=2.0),
    Scenario("stock/local/agentx", "stock_correlation", "apple", "agentx",
             "local", weight=2.0),
    Scenario("stock/faas/agentx", "stock_correlation", "netflix", "agentx",
             "faas", weight=1.0),
    Scenario("research/local/agentx", "research_report", "flow", "agentx",
             "local", weight=1.0),
    Scenario("web/faas/agentx", "web_search", "materials", "agentx",
             "faas", weight=1.0),
)


def planner_calls(result) -> int:
    return sum(1 for c in result.trace.llm_events
               if c.agent in PLANNER_AGENTS)


def tool_seq(result):
    return [(e.event.server, e.event.tool, e.event.args)
            for e in result.extras.get("events", ())
            if isinstance(e, ToolInvoked)]


def _pass_summary(report, slo) -> dict:
    agg = aggregate_report(report, slo)
    return {
        "planner_calls": sum(planner_calls(r.result)
                             for r in report.records),
        "success_rate": agg["overall"]["success_rate"],
        "latency_s": agg["overall"]["latency_s"],
        "cost_usd": agg["overall"]["cost_usd"],
        "plan_cache": agg.get("plan_cache"),
    }


def _check_warm_replays(report) -> int:
    """Warm-pass invariant: a run whose stream carries neither
    PlanCacheMiss nor PlanFallback replayed a compiled graph — it must
    have made ZERO planner calls.  Returns the replay count."""
    replays = 0
    for r in report.records:
        events = r.result.extras.get("events", ())
        marked = any(isinstance(e, (PlanCacheMiss, PlanFallback))
                     for e in events)
        if marked:
            continue
        replays += 1
        calls = planner_calls(r.result)
        assert calls == 0, (
            f"compiled replay of {r.spec} made {calls} planner calls")
    return replays


def _check_parity(seed: int) -> dict:
    """Same-spec determinism: fresh(X) and compiled-replay(X) produce
    identical tool-call sequences and artifacts, per scenario."""
    out = {}
    for s in PLAN_MIX:
        spec = RunSpec(s.app, s.instance, s.pattern, s.deployment,
                       seed=seed + 1)
        fresh = Session().execute(spec)
        pc = PlanCache()
        compiled_session = Session(plan_cache=pc)
        cold = compiled_session.execute(spec)       # compiles
        warm = compiled_session.execute(spec)       # replays
        fell_back = any(isinstance(e, PlanFallback)
                        for e in warm.extras.get("events", ()))
        seq_ok = tool_seq(fresh) == tool_seq(warm)
        art_ok = fresh.artifact == warm.artifact
        out[s.name] = {"compiled": pc.stats()["entries"] > 0,
                       "fallback": fell_back,
                       "seq_parity": seq_ok, "artifact_parity": art_ok,
                       "planner_calls_fresh": planner_calls(fresh),
                       "planner_calls_replay": planner_calls(warm)}
        if cold.success and not fell_back:
            assert seq_ok and art_ok, (
                f"{s.name}: compiled replay of {spec} diverged from the "
                f"fresh run (seq={seq_ok} artifact={art_ok})")
    return out


def measure(n_requests: int = 60, rate: float = 2.0, seed: int = 0,
            unique_seeds: int = 5) -> dict:
    slo = SLOTarget(latency_s=180.0, ttft_s=30.0, success_rate=0.85)
    wl = Workload(scenarios=PLAN_MIX, rate=rate, n_requests=n_requests,
                  seed=seed, unique_seeds=unique_seeds)

    clean = TrafficDriver(Session()).run(wl)

    pc = PlanCache()
    cold = TrafficDriver(Session(plan_cache=pc)).run(wl)
    warm = TrafficDriver(Session(plan_cache=pc)).run(wl)

    replays = _check_warm_replays(warm)
    assert warm.plan_cache["hit_rate"] > 0, "warm pass produced no hits"

    s_clean = _pass_summary(clean, slo)
    s_cold = _pass_summary(cold, slo)
    s_warm = _pass_summary(warm, slo)
    return {
        "workload": wl.describe(),
        "mix": [s.name for s in PLAN_MIX],
        "clean": s_clean,
        "cold": s_cold,
        "warm": s_warm,
        "warm_replays_checked": replays,
        "savings": {
            # what compilation eliminates at steady state, per Eq. 1+2
            "planner_calls": (s_clean["planner_calls"]
                              - s_warm["planner_calls"]),
            "llm_cost_usd": (s_clean["cost_usd"]["llm_mean"]
                             - s_warm["cost_usd"]["llm_mean"]),
            "total_cost_usd": (s_clean["cost_usd"]["total_mean"]
                               - s_warm["cost_usd"]["total_mean"]),
            "latency_p50_s": (s_clean["latency_s"]["p50"]
                              - s_warm["latency_s"]["p50"]),
            "latency_p95_s": (s_clean["latency_s"]["p95"]
                              - s_warm["latency_s"]["p95"]),
        },
        "parity": _check_parity(seed),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--unique-seeds", type=int, default=5,
                    help="distinct spec seeds in the stream (repeat-mix)")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_traffic.json"))
    args = ap.parse_args()

    try:
        rec = measure(n_requests=args.requests, rate=args.rate,
                      seed=args.seed, unique_seeds=args.unique_seeds)
    except AssertionError as e:
        print(f"PLAN-CACHE INVARIANT VIOLATED: {e}", file=sys.stderr)
        sys.exit(1)

    # merge into the traffic artifact (benchmarks.traffic owns the rest)
    existing = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    existing["plan_cache"] = rec
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(existing, f, indent=2)

    warm_pc = rec["warm"]["plan_cache"]
    print(f"# plan bench: {rec['workload']['n_requests']} requests x3 "
          f"passes, {args.unique_seeds} unique seeds")
    print(f"clean.planner_calls,{rec['clean']['planner_calls']},")
    print(f"cold.planner_calls,{rec['cold']['planner_calls']},")
    print(f"warm.planner_calls,{rec['warm']['planner_calls']},")
    print(f"warm.hit_rate,{warm_pc['hit_rate']:.3f},")
    print(f"warm.fallbacks,{warm_pc['fallbacks']},")
    print(f"warm.replays_checked,{rec['warm_replays_checked']},")
    print(f"clean.success_rate,{rec['clean']['success_rate']:.3f},")
    print(f"warm.success_rate,{rec['warm']['success_rate']:.3f},")
    print(f"savings.planner_calls,{rec['savings']['planner_calls']},")
    print(f"savings.llm_cost_usd,{rec['savings']['llm_cost_usd']:.6f},")
    print(f"savings.latency_p50_s,{rec['savings']['latency_p50_s']:.1f},")
    parity_ok = all(v["seq_parity"] and v["artifact_parity"]
                    for v in rec["parity"].values() if not v["fallback"])
    print(f"parity.same_spec,{parity_ok},")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
