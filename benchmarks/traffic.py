"""Traffic SLO benchmark: success rate, latency/TTFT percentiles and
Eq. 1/Eq. 2 cost per scenario under open-loop load — clean, faulted, and
faulted-with-resilience.

Four passes over the same seeded workload (identical specs, identical
worlds — the ``world_alias`` guarantee):

  1. **clean** — the no-fault baseline;
  2. **faults** — transient errors + cold starts + throttling injected
     at the deployment transport (``repro.traffic.faults``), no
     mitigation;
  3. **faults+retry** — the same fault plan countered by
     ``Session(retry=RetryPolicy(...))``: success rate should recover
     to the clean baseline (the paper's *robust orchestration* claim,
     quantified), and every injected error is reconciled against a
     ``ToolRetried`` event — the retry-only pass is what makes that
     accounting exact (a hedge can absorb an injected error without a
     retry event);
  4. **faults+retry+hedge** — adds ``HedgePolicy``: the latency/cost
     premium of full resilience, priced against the clean baseline.

A fifth axis — **durability** (``repro.durable``) — re-drives the same
workload under injected *platform crashes* (whole runs killed mid-
flight) three ways: no recovery, restart-from-scratch, and journal
resume.  The headline criteria, asserted at exit: resumed success rate
recovers the clean baseline *exactly* (determinism makes == meaningful),
and resume bills strictly less than rerun (the recovered-prefix saving,
Eq. 1 + Eq. 2).

A sixth axis — **tenancy** (``repro.tenancy``) — drives a noisy-
neighbor mix (one tenant bursting 5x against two steady tenants)
through the weighted fair-share gate, plus a weighted-saturation pass
and a budget-enforcement pass.  Asserted at exit: steady-tenant SLO
attainment within 5% of the isolated baseline, per-tenant throughput
tracking registry weights, and tight budgets producing both graceful
degradation and hard rejection (``--tenancy-only`` merges just this
section into an existing artifact — the CI smoke).

Writes ``artifacts/BENCH_traffic.json`` (uploaded by CI).

    PYTHONPATH=src python -m benchmarks.traffic --requests 60 --rate 2
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

from repro.apps.session import Session
from repro.core.policies import HedgePolicy, RetryPolicy
from repro.durable import RunJournal
from repro.traffic import (DEFAULT_MIX, FaultPlan, Scenario, SLOTarget,
                           TrafficDriver, Workload, aggregate_report,
                           register_fault_plan)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

FAULT_PLAN = FaultPlan(transient_rate=0.2, transient_delay_s=0.1,
                       throttle_rate=0.05, throttle_delay_s=1.0,
                       cold_start_rate=0.08, cold_start_s=2.5,
                       first_call_cold=False)
RETRY = RetryPolicy(max_attempts=8, backoff_s=0.25, backoff_mult=2.0)
HEDGE = HedgePolicy(hedge_after_s=8.0)


def _faulty_mix(stats_sink) -> tuple:
    """The DEFAULT_MIX with every deployment swapped for its registered
    faulty twin (one shared FaultStats across all of them)."""
    scenarios = []
    for s in DEFAULT_MIX:
        name = f"{s.deployment}+faults"
        register_fault_plan(name, s.deployment, FAULT_PLAN, stats=stats_sink)
        scenarios.append(Scenario(s.name, s.app, s.instance, s.pattern,
                                  name, s.llm, s.priority, s.weight))
    return tuple(scenarios)


def _crash_mix(crash_rate: float, stats_sink) -> tuple:
    """The DEFAULT_MIX over crash-only twins: no transport faults, no
    cold starts — a crash twin's run is bit-identical to the plain
    deployment's until the platform kill, so the clean pass IS the
    ground truth a recovered pass must match exactly."""
    plan = FaultPlan(crash_rate=crash_rate, first_call_cold=False)
    scenarios = []
    for s in DEFAULT_MIX:
        name = f"{s.deployment}+crash"
        register_fault_plan(name, s.deployment, plan, stats=stats_sink)
        scenarios.append(Scenario(s.name, s.app, s.instance, s.pattern,
                                  name, s.llm, s.priority, s.weight))
    return tuple(scenarios)


def measure_durability(n_requests: int = 100, rate: float = 2.0,
                       seed: int = 0, arrival: str = "poisson",
                       max_concurrency: int = 0, crash_rate: float = 0.2,
                       clean_overall: dict = None) -> dict:
    """Crash-recovery economics: the same workload under a
    ``crash_rate`` per-attempt kill probability, recovered three ways.

    ``clean_overall``: the no-crash baseline aggregate to compare
    against (computed here over the plain DEFAULT_MIX when not passed
    in by ``measure``)."""
    from repro.traffic.faults import FaultStats
    slo = SLOTarget(latency_s=180.0, ttft_s=30.0, success_rate=0.85)
    if clean_overall is None:
        wl = Workload(arrival=arrival, rate=rate, n_requests=n_requests,
                      seed=seed)
        clean_overall = aggregate_report(
            TrafficDriver(Session(), max_concurrency=max_concurrency)
            .run(wl), slo)["overall"]

    stats = FaultStats()
    plan = FaultPlan(crash_rate=crash_rate, first_call_cold=False)
    crash_wl = Workload(scenarios=_crash_mix(crash_rate, stats),
                        arrival=arrival, rate=rate, n_requests=n_requests,
                        seed=seed)

    # pass A: crashes land, nobody recovers — the damage baseline
    none_rep = TrafficDriver(Session(), max_concurrency=max_concurrency,
                             restart="none").run(crash_wl)
    crashes_unrecovered = stats.snapshot()["crashes"]

    # pass B: restart-from-scratch — every dead attempt fully re-billed
    stats.reset()
    rerun_rep = TrafficDriver(Session(), max_concurrency=max_concurrency,
                              restart="rerun").run(crash_wl)

    # pass C: journal resume — fsync_batch=1 commits every event, so the
    # whole journaled prefix is recovered (larger batches would re-pay
    # the unfsynced tail; that knob is exercised in tests)
    stats.reset()
    journal_dir = tempfile.mkdtemp(prefix="repro-journal-")
    resume_rep = TrafficDriver(
        Session(journal=RunJournal(journal_dir, fsync_batch=1)),
        max_concurrency=max_concurrency, restart="resume").run(crash_wl)

    agg_none = aggregate_report(none_rep, slo)
    agg_rerun = aggregate_report(rerun_rep, slo)
    agg_resume = aggregate_report(resume_rep, slo)
    dur_rerun = agg_rerun["overall"]["durability"]
    dur_resume = agg_resume["overall"]["durability"]
    return {
        "plan": {"crash_rate": crash_rate,
                 "crash_min_events": plan.crash_min_events,
                 "crash_max_events": plan.crash_max_events,
                 "fsync_batch": 1},
        "no_recovery": {"injected_crashes": crashes_unrecovered,
                        "overall": agg_none["overall"]},
        "rerun": {"overall": agg_rerun["overall"]},
        "resume": {"overall": agg_resume["overall"]},
        "success_rate": {
            "clean": clean_overall["success_rate"],
            "no_recovery": agg_none["overall"]["success_rate"],
            "rerun": agg_rerun["overall"]["success_rate"],
            "resume": agg_resume["overall"]["success_rate"],
        },
        "economics": {
            "rerun_billed_usd": dur_rerun["billed_cost_usd"],
            "resume_billed_usd": dur_resume["billed_cost_usd"],
            "resume_saving_usd": (dur_rerun["billed_cost_usd"]
                                  - dur_resume["billed_cost_usd"]),
            "recovered_tokens": dur_resume["recovered_tokens"],
            "replayed_events": dur_resume["replayed_events"],
            "resumes": dur_resume["resumes"],
        },
        # the headline recovery criteria (determinism makes == meaningful)
        "recovers_clean_success": (agg_resume["overall"]["success_rate"]
                                   == clean_overall["success_rate"]),
        "resume_cheaper_than_rerun": (dur_resume["billed_cost_usd"]
                                      < dur_rerun["billed_cost_usd"]),
    }


#: tolerance on the noisy-neighbor isolation criterion: each steady
#: tenant's SLO latency attainment under the 5x burst must be within
#: this of its isolated baseline (ISSUE acceptance: "within 5%")
TENANCY_SLO_TOL = 0.05
#: absolute tolerance on per-tenant shares in the weighted-saturation
#: pass (weight shares are {4/7, 2/7, 1/7} — far wider apart than this)
TENANCY_SHARE_TOL = 0.12


def measure_tenancy(n_requests: int = 105, seed: int = 0,
                    total_rate: float = 0.21, max_concurrency: int = 8,
                    burst_factor: float = 5.0) -> dict:
    """Multi-tenant serving (``repro.tenancy``): three sub-experiments
    over the DEFAULT_MIX replicated per tenant.

    1. **Noisy neighbor** — two steady tenants offering 1x load each
       plus one tenant bursting ``burst_factor``x, all weight 1.0,
       through the deficit-round-robin ``FairShareGate``.  Asserted:
       each steady tenant's SLO latency attainment stays within
       ``TENANCY_SLO_TOL`` of its isolated (no-noisy-tenant) baseline.
       The same burst through the plain FIFO gate is reported as the
       contrast case.

    2. **Weight proportionality** — three tenants with weights 1:2:4
       offering identical saturating load (every request arrives up
       front).  Over the fully-contended window (admissions where every
       tenant had queued work) both DRR admissions and token throughput
       must track the weight shares within ``TENANCY_SHARE_TOL``.

    3. **Budgets** — the burst workload re-driven with a finite token
       budget on the noisy tenant: soft exhaustion must degrade at
       least one run (``RunDegraded``) and hard exhaustion must reject
       at least one (``BudgetExceeded``), with steady tenants untouched.
    """
    from repro.tenancy import Tenancy, Tenant, TenantRegistry
    from repro.traffic import tenant_mix

    slo = SLOTarget(latency_s=180.0, ttft_s=30.0, success_rate=0.85)
    steady = ("steady-a", "steady-b")
    noisy = "noisy"
    registry = TenantRegistry(Tenant(steady[0]), Tenant(steady[1]),
                              Tenant(noisy))

    # -- 1: noisy neighbor ------------------------------------------------
    # isolated baseline: the steady tenants alone, at the same per-tenant
    # arrival rate they will offer during the burst (mix weights shape
    # WHO arrives; the Workload rate is the total, so both scale by the
    # steady fraction of the burst mix)
    share = 2.0 / (2.0 + burst_factor)
    iso_wl = Workload(scenarios=tenant_mix({t: 1.0 for t in steady}),
                      rate=total_rate * share,
                      n_requests=max(8, round(n_requests * share)),
                      seed=seed)
    iso = aggregate_report(
        TrafficDriver(Session(tenancy=Tenancy(registry)),
                      max_concurrency=max_concurrency,
                      tenants=registry).run(iso_wl), slo)

    burst_wl = Workload(
        scenarios=tenant_mix({steady[0]: 1.0, steady[1]: 1.0,
                              noisy: burst_factor}),
        rate=total_rate, n_requests=n_requests, seed=seed)
    burst = aggregate_report(
        TrafficDriver(Session(tenancy=Tenancy(registry)),
                      max_concurrency=max_concurrency,
                      tenants=registry).run(burst_wl), slo)
    # contrast: the identical burst through the tenant-blind FIFO gate
    fifo = aggregate_report(
        TrafficDriver(Session(), max_concurrency=max_concurrency)
        .run(burst_wl), slo)

    def attain(agg: dict, tenant: str) -> float:
        return agg["tenants"][tenant]["slo"]["latency_attainment"]

    steady_ok = all(attain(burst, t) >= attain(iso, t) - TENANCY_SLO_TOL
                    for t in steady)

    # -- 2: weight proportionality under saturation -----------------------
    weights = {"bronze": 1.0, "silver": 2.0, "gold": 4.0}
    wsum = sum(weights.values())
    wreg = TenantRegistry(*(Tenant(t, weight=w)
                            for t, w in weights.items()))
    sat_wl = Workload(scenarios=tenant_mix({t: 1.0 for t in weights}),
                      arrival="uniform", rate=50.0,
                      n_requests=n_requests, seed=seed + 1)
    sat_drv = TrafficDriver(Session(tenancy=Tenancy(wreg)),
                            max_concurrency=max_concurrency, tenants=wreg)
    sat_rep = sat_drv.run(sat_wl)
    contended = [(t, tenant) for t, tenant, c
                 in sat_drv.last_gate.admissions if c]
    window_s = max(t for t, _ in contended)
    adm_counts = {t: sum(tenant == t for _, tenant in contended)
                  for t in weights}
    tokens = {t: 0.0 for t in weights}
    for r in sat_rep.records:
        if r.start <= window_s:
            tokens[r.spec.tenant] += (r.result.trace.input_tokens
                                      + r.result.trace.output_tokens)
    tok_sum, adm_sum = sum(tokens.values()), sum(adm_counts.values())
    shares = {t: {"weight": weights[t] / wsum,
                  "admissions": adm_counts[t] / adm_sum,
                  "tokens": tokens[t] / tok_sum,
                  "token_throughput": tokens[t] / window_s}
              for t in weights}
    weights_ok = all(
        abs(s["admissions"] - s["weight"]) <= TENANCY_SHARE_TOL
        and abs(s["tokens"] - s["weight"]) <= TENANCY_SHARE_TOL
        for s in shares.values())

    # -- 3: budgets: degrade then reject ----------------------------------
    # sized to trip mid-workload: ~15k tokens/run, the noisy tenant draws
    # burst_factor/(2+burst_factor) of the requests; soft at 40% leaves a
    # wide degradation window before the hard cut
    token_budget = 4800.0 * n_requests
    breg = TenantRegistry(Tenant(steady[0]), Tenant(steady[1]),
                          Tenant(noisy, token_budget=token_budget))
    btenancy = Tenancy(breg, soft_fraction=0.4)
    brep = TrafficDriver(Session(tenancy=btenancy),
                         max_concurrency=max_concurrency,
                         tenants=breg).run(burst_wl)
    bagg = aggregate_report(brep, slo)
    meter = btenancy.meter.snapshot()
    noisy_meter = meter.get(noisy, {})
    budget_ok = (noisy_meter.get("degraded_runs", 0) >= 1
                 and noisy_meter.get("rejected_runs", 0) >= 1
                 and all(meter.get(t, {}).get("degraded_runs", 0) == 0
                         and meter.get(t, {}).get("rejected_runs", 0) == 0
                         for t in steady))

    return {
        "config": {"steady_tenants": list(steady), "noisy_tenant": noisy,
                   "burst_factor": burst_factor, "total_rate": total_rate,
                   "max_concurrency": max_concurrency,
                   "n_requests": n_requests,
                   "slo_tolerance": TENANCY_SLO_TOL,
                   "share_tolerance": TENANCY_SHARE_TOL,
                   "token_budget": token_budget},
        "noisy_neighbor": {
            "isolated": {t: iso["tenants"][t] for t in steady},
            "burst": burst["tenants"],
            "burst_fifo_attainment": {t: attain(fifo, t) for t in steady},
            "steady_attainment": {
                t: {"isolated": attain(iso, t), "burst": attain(burst, t),
                    "fifo": attain(fifo, t)} for t in steady},
        },
        "fair_share": {"weights": weights,
                       "contended_admissions": adm_sum,
                       "window_virtual_s": window_s,
                       "shares": shares},
        "budget": {"meter": meter, "tenants": bagg.get("tenants", {})},
        "steady_slo_within_tolerance": steady_ok,
        "throughput_tracks_weights": weights_ok,
        "budget_degrades_and_rejects": budget_ok,
    }


def measure_telemetry(n_requests: int = 40, rate: float = 2.0,
                      seed: int = 0, max_concurrency: int = 0) -> dict:
    """The unified-telemetry section, with its invariants asserted:

      * two independent virtual-clock replays of the same seeded
        (faulted + retry + plan-cache) workload fold into BYTE-identical
        Prometheus and OTLP exports;
      * the key series are non-empty — tool latency, run latency,
        plan-cache lookups — and a real (reduced) engine pass populates
        the EngineStepped series;
      * the jit profiler reports >= 1 profiled executable with a compile
        count and call-time stats;
      * the SLO monitor fires burn-rate alerts on the faulted workload,
        identically across replays.
    """
    import hashlib

    from repro.plans import PlanCache
    from repro.telemetry import (EventMetricsBridge, JitProfiler,
                                 MetricsRegistry, SloMonitor,
                                 export_otlp_metrics_json, fold_report,
                                 render_prometheus)
    from repro.traffic.faults import FaultStats

    slo = SLOTarget()

    def one_replay():
        stats = FaultStats()
        wl = Workload(scenarios=_faulty_mix(stats), arrival="poisson",
                      rate=rate, n_requests=n_requests, seed=seed,
                      unique_seeds=max(4, n_requests // 8))
        session = Session(retry=RETRY, plan_cache=PlanCache())
        report = TrafficDriver(session,
                               max_concurrency=max_concurrency).run(wl)
        registry = MetricsRegistry()
        fold_report(EventMetricsBridge(registry), report)
        slo_mon = SloMonitor(slo, window_s=60.0, threshold=2.0,
                             registry=registry)
        slo_mon.observe_records(report.records)
        return (render_prometheus(registry),
                export_otlp_metrics_json(registry), registry, slo_mon)

    text1, otlp1, registry, slo_mon = one_replay()
    text2, otlp2, _, slo_mon2 = one_replay()
    assert text1 == text2, \
        "two virtual replays must render byte-identical Prometheus text"
    assert otlp1 == otlp2, \
        "two virtual replays must render byte-identical OTLP JSON"
    assert len(slo_mon.alerts) == len(slo_mon2.alerts)

    def total(name):
        return int(registry.total(name))

    assert total("repro_tool_latency_seconds") > 0, "tool series empty"
    assert total("repro_run_latency_seconds") == n_requests
    assert total("repro_cache_lookups_total") > 0, "cache series empty"
    assert len(slo_mon.alerts) >= 1, \
        "the faulted workload should burn error budget"

    # -- a real (reduced) engine pass: EngineStepped series + profiler --
    from repro.configs import get_config
    from repro.serving import BatchScheduler, Engine, RunMonitor
    engine = Engine(get_config("tinyllama-1.1b").reduced(), seed=seed)
    profiler = JitProfiler()
    profiler.wrap_engine(engine)
    monitor = RunMonitor()
    sched = BatchScheduler(engine, n_slots=4, max_len=64,
                           on_event=monitor)
    for i in range(4):
        sched.submit(f"telemetry probe {i}: measure decode", max_new=8)
    sched.run()
    ereg = monitor.registry
    assert int(ereg.total("repro_engine_steps_total")) > 0
    assert int(ereg.total("repro_engine_decode_tokens_total")) > 0
    assert int(ereg.total("repro_engine_prefill_tokens_total")) > 0
    profiled = {name: s for name, s in profiler.stats().items()
                if s["calls"] > 0}
    assert profiled and any(s["compiles"] >= 1 for s in profiled.values()), \
        "expected >= 1 profiled jit executable with a compile"

    cache_gauge = registry.get("repro_cache_hit_rate")
    return {
        "config": {"n_requests": n_requests, "rate": rate, "seed": seed,
                   "slo": slo.describe(), "burn_window_s": 60.0,
                   "burn_threshold": 2.0},
        "determinism": {
            "replays": 2,
            "prometheus_bytes": len(text1),
            "prometheus_sha256":
                hashlib.sha256(text1.encode()).hexdigest(),
            "byte_identical_prometheus": text1 == text2,
            "byte_identical_otlp": otlp1 == otlp2,
        },
        "series": {
            "families": len(registry.names()),
            "events_folded": total("repro_events_total"),
            "llm_calls": total("repro_llm_calls_total"),
            "tool_latency_observations":
                total("repro_tool_latency_seconds"),
            "tool_retries": total("repro_tool_retries_total"),
            "run_latency_observations":
                total("repro_run_latency_seconds"),
            "cache_lookups": total("repro_cache_lookups_total"),
            "plan_cache_hit_rate":
                (cache_gauge.value(cache="plan")
                 if cache_gauge is not None else 0.0),
        },
        "slo": dict(slo_mon.summary(),
                    fired=[{"slo": a.slo, "window_start": a.window_start,
                            "burn_rate": a.burn_rate, "bad": a.bad,
                            "total": a.total} for a in slo_mon.alerts]),
        "engine": {
            "steps": int(ereg.total("repro_engine_steps_total")),
            "decode_tokens":
                int(ereg.total("repro_engine_decode_tokens_total")),
            "prefill_tokens":
                int(ereg.total("repro_engine_prefill_tokens_total")),
            "peak_live": monitor.engine_peak_live,
        },
        "jit_profile": profiled,
        "checks": {
            "byte_identical_exports": True,
            "engine_series_nonempty": True,
            "slo_alerts_fired": len(slo_mon.alerts),
            "profiled_jit_executables": len(profiled),
        },
    }


def measure(n_requests: int = 100, rate: float = 2.0, seed: int = 0,
            arrival: str = "poisson", max_concurrency: int = 0) -> dict:
    from repro.traffic.faults import FaultStats
    slo = SLOTarget(latency_s=180.0, ttft_s=30.0, success_rate=0.85)
    wl = Workload(arrival=arrival, rate=rate, n_requests=n_requests,
                  seed=seed)

    # pass 1: clean baseline
    clean = TrafficDriver(Session(),
                          max_concurrency=max_concurrency).run(wl)

    # pass 2/3: identical workload over the faulty deployment twins
    stats = FaultStats()
    faulty_wl = Workload(scenarios=_faulty_mix(stats), arrival=arrival,
                         rate=rate, n_requests=n_requests, seed=seed)
    faulted = TrafficDriver(Session(),
                            max_concurrency=max_concurrency).run(faulty_wl)
    injected_no_retry = stats.snapshot()

    stats.reset()
    retry_only = TrafficDriver(Session(retry=RETRY),
                               max_concurrency=max_concurrency).run(faulty_wl)
    injected_with_retry = stats.snapshot()

    stats.reset()
    hedged = TrafficDriver(Session(retry=RETRY, hedge=HEDGE),
                           max_concurrency=max_concurrency).run(faulty_wl)

    agg_clean = aggregate_report(clean, slo)
    agg_fault = aggregate_report(faulted, slo)
    agg_retry = aggregate_report(retry_only, slo)
    agg_hedge = aggregate_report(hedged, slo)
    retried = agg_retry["overall"]["resilience"]["retries"]
    return {
        "workload": wl.describe(),
        "slo": slo.describe(),
        "scenarios": agg_clean["scenarios"],
        "overall": agg_clean["overall"],
        "replay": agg_clean["replay"],
        "fault_injection": {
            "plan": {
                "transient_rate": FAULT_PLAN.transient_rate,
                "throttle_rate": FAULT_PLAN.throttle_rate,
                "cold_start_rate": FAULT_PLAN.cold_start_rate,
                "cold_start_s": FAULT_PLAN.cold_start_s,
            },
            "no_mitigation": {
                "injected": injected_no_retry,
                "scenarios": agg_fault["scenarios"],
                "overall": agg_fault["overall"],
            },
            "with_retry": {
                "injected": injected_with_retry,
                "retried": retried,
                "retry_accounts_for_all_faults":
                    retried == injected_with_retry["errors"],
                "scenarios": agg_retry["scenarios"],
                "overall": agg_retry["overall"],
            },
            "with_retry_hedge": {
                "hedges": agg_hedge["overall"]["resilience"]["hedges"],
                "scenarios": agg_hedge["scenarios"],
                "overall": agg_hedge["overall"],
            },
            "success_rate": {
                "clean": agg_clean["overall"]["success_rate"],
                "faulted": agg_fault["overall"]["success_rate"],
                "recovered": agg_retry["overall"]["success_rate"],
            },
            "latency_premium_p95_s":
                (agg_hedge["overall"]["latency_s"]["p95"]
                 - agg_clean["overall"]["latency_s"]["p95"]),
            "cost_premium_usd":
                (agg_hedge["overall"]["cost_usd"]["total_sum"]
                 - agg_clean["overall"]["cost_usd"]["total_sum"]),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="mean arrivals per virtual second")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty", "uniform"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--concurrency", type=int, default=0,
                    help="in-flight run cap (0 = unbounded)")
    ap.add_argument("--crash-rate", type=float, default=0.2,
                    help="per-attempt platform-kill probability for the "
                         "durability passes")
    ap.add_argument("--no-durability", action="store_true",
                    help="skip the crash-recovery passes")
    ap.add_argument("--durability-only", action="store_true",
                    help="run only the durability passes and merge the "
                         "section into an existing artifact")
    ap.add_argument("--no-tenancy", action="store_true",
                    help="skip the multi-tenant passes")
    ap.add_argument("--tenancy-only", action="store_true",
                    help="run only the multi-tenant passes and merge the "
                         "section into an existing artifact")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="skip the unified-telemetry passes")
    ap.add_argument("--telemetry-only", action="store_true",
                    help="run only the telemetry passes and merge the "
                         "section into an existing artifact")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_traffic.json"))
    args = ap.parse_args()

    if args.telemetry_only:
        rec = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                rec = json.load(f)
        rec["telemetry"] = measure_telemetry(n_requests=args.requests,
                                             rate=args.rate,
                                             seed=args.seed,
                                             max_concurrency=args.concurrency)
    elif args.tenancy_only:
        rec = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                rec = json.load(f)
        rec["tenancy"] = measure_tenancy(n_requests=args.requests,
                                         seed=args.seed)
    elif args.durability_only:
        # merge into whatever artifact is already there (the clean
        # overall, when present, is the recovery ground truth)
        rec = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                rec = json.load(f)
        rec["durability"] = measure_durability(
            n_requests=args.requests, rate=args.rate, seed=args.seed,
            arrival=args.arrival, max_concurrency=args.concurrency,
            crash_rate=args.crash_rate,
            clean_overall=rec.get("overall"))
    else:
        rec = measure(n_requests=args.requests, rate=args.rate,
                      seed=args.seed, arrival=args.arrival,
                      max_concurrency=args.concurrency)
        if not args.no_durability:
            rec["durability"] = measure_durability(
                n_requests=args.requests, rate=args.rate, seed=args.seed,
                arrival=args.arrival, max_concurrency=args.concurrency,
                crash_rate=args.crash_rate,
                clean_overall=rec["overall"])
        if not args.no_tenancy:
            rec["tenancy"] = measure_tenancy(n_requests=args.requests,
                                             seed=args.seed)
        if not args.no_telemetry:
            rec["telemetry"] = measure_telemetry(
                n_requests=args.requests, rate=args.rate, seed=args.seed,
                max_concurrency=args.concurrency)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)

    if "fault_injection" in rec:
        ov, rp = rec["overall"], rec["replay"]
        fi = rec["fault_injection"]
        print(f"# traffic bench: {rec['workload']['n_requests']} requests, "
              f"{rec['workload']['arrival']} arrivals @ "
              f"{rec['workload']['rate']}/s")
        print(f"replay.virtual_s,{rp['virtual_s']:.0f},")
        print(f"replay.wall_s,{rp['wall_s']:.2f},")
        print(f"replay.speedup,{rp['speedup']:.0f},x")
        print(f"replay.peak_concurrency,{rp['peak_concurrency']},")
        print(f"clean.success_rate,{ov['success_rate']:.3f},")
        print(f"clean.latency_p95_s,{ov['latency_s']['p95']:.1f},")
        print(f"clean.ttft_p95_s,{ov['ttft_s']['p95']:.1f},")
        print(f"clean.cost_mean_usd,{ov['cost_usd']['total_mean']:.5f},")
        sr = fi["success_rate"]
        print(f"faults.success_rate,{sr['faulted']:.3f},")
        print(f"faults.recovered_success_rate,{sr['recovered']:.3f},")
        print(f"faults.injected,{fi['with_retry']['injected']['errors']},")
        print(f"faults.retried,{fi['with_retry']['retried']},")
        print(f"faults.accounted,"
              f"{fi['with_retry']['retry_accounts_for_all_faults']},")
        print(f"faults.hedges,{fi['with_retry_hedge']['hedges']},")
        print(f"faults.latency_premium_p95_s,"
              f"{fi['latency_premium_p95_s']:.1f},")

    failed = False
    if "durability" in rec:
        du = rec["durability"]
        sr, eco = du["success_rate"], du["economics"]
        print(f"durability.crash_rate,{du['plan']['crash_rate']:.2f},")
        print(f"durability.success_clean,{sr['clean']:.3f},")
        print(f"durability.success_no_recovery,{sr['no_recovery']:.3f},")
        print(f"durability.success_rerun,{sr['rerun']:.3f},")
        print(f"durability.success_resume,{sr['resume']:.3f},")
        print(f"durability.crashes,"
              f"{du['rerun']['overall']['durability']['crashes']},")
        print(f"durability.resumes,{eco['resumes']},")
        print(f"durability.replayed_events,{eco['replayed_events']},")
        print(f"durability.recovered_tokens,{eco['recovered_tokens']},")
        print(f"durability.rerun_billed_usd,{eco['rerun_billed_usd']:.5f},")
        print(f"durability.resume_billed_usd,"
              f"{eco['resume_billed_usd']:.5f},")
        print(f"durability.resume_saving_usd,"
              f"{eco['resume_saving_usd']:.5f},")
        print(f"durability.recovers_clean_success,"
              f"{du['recovers_clean_success']},")
        print(f"durability.resume_cheaper_than_rerun,"
              f"{du['resume_cheaper_than_rerun']},")
        if not du["recovers_clean_success"]:
            print("# FAIL: resumed success rate != clean baseline")
            failed = True
        if not du["resume_cheaper_than_rerun"]:
            print("# FAIL: resume did not bill less than rerun")
            failed = True
    if "tenancy" in rec:
        te = rec["tenancy"]
        nn = te["noisy_neighbor"]["steady_attainment"]
        for t, a in sorted(nn.items()):
            print(f"tenancy.{t}.attainment_isolated,{a['isolated']:.3f},")
            print(f"tenancy.{t}.attainment_burst,{a['burst']:.3f},")
            print(f"tenancy.{t}.attainment_burst_fifo,{a['fifo']:.3f},")
        for t, s in sorted(te["fair_share"]["shares"].items()):
            print(f"tenancy.share.{t},{s['tokens']:.3f},"
                  f"(weight {s['weight']:.3f})")
        nm = te["budget"]["meter"].get(te["config"]["noisy_tenant"], {})
        print(f"tenancy.noisy_degraded_runs,"
              f"{nm.get('degraded_runs', 0)},")
        print(f"tenancy.noisy_rejected_runs,"
              f"{nm.get('rejected_runs', 0)},")
        print(f"tenancy.steady_slo_within_tolerance,"
              f"{te['steady_slo_within_tolerance']},")
        print(f"tenancy.throughput_tracks_weights,"
              f"{te['throughput_tracks_weights']},")
        print(f"tenancy.budget_degrades_and_rejects,"
              f"{te['budget_degrades_and_rejects']},")
        if not te["steady_slo_within_tolerance"]:
            print("# FAIL: steady-tenant SLO attainment fell more than "
                  f"{TENANCY_SLO_TOL:.0%} below the isolated baseline")
            failed = True
        if not te["throughput_tracks_weights"]:
            print("# FAIL: per-tenant throughput does not track weights")
            failed = True
        if not te["budget_degrades_and_rejects"]:
            print("# FAIL: tight budget produced no degradation/rejection")
            failed = True
    if "telemetry" in rec:
        tm = rec["telemetry"]
        det, se, ck = tm["determinism"], tm["series"], tm["checks"]
        print(f"telemetry.byte_identical_prometheus,"
              f"{det['byte_identical_prometheus']},")
        print(f"telemetry.byte_identical_otlp,"
              f"{det['byte_identical_otlp']},")
        print(f"telemetry.prometheus_bytes,{det['prometheus_bytes']},")
        print(f"telemetry.events_folded,{se['events_folded']},")
        print(f"telemetry.tool_latency_observations,"
              f"{se['tool_latency_observations']},")
        print(f"telemetry.cache_lookups,{se['cache_lookups']},")
        print(f"telemetry.plan_cache_hit_rate,"
              f"{se['plan_cache_hit_rate']:.3f},")
        print(f"telemetry.engine_steps,{tm['engine']['steps']},")
        print(f"telemetry.slo_alerts,{ck['slo_alerts_fired']},")
        print(f"telemetry.profiled_jit_executables,"
              f"{ck['profiled_jit_executables']},")
        for fn, s in sorted(tm["jit_profile"].items()):
            print(f"telemetry.jit.{fn},{s['calls']} calls,"
                  f"{s['compiles']} compiles,{s['avg_ms']:.1f} ms avg")
        if not (det["byte_identical_prometheus"]
                and det["byte_identical_otlp"]):
            print("# FAIL: replayed exports were not byte-identical")
            failed = True
    print(f"# wrote {args.out}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
