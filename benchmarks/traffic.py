"""Traffic SLO benchmark: success rate, latency/TTFT percentiles and
Eq. 1/Eq. 2 cost per scenario under open-loop load — clean, faulted, and
faulted-with-resilience.

Four passes over the same seeded workload (identical specs, identical
worlds — the ``world_alias`` guarantee):

  1. **clean** — the no-fault baseline;
  2. **faults** — transient errors + cold starts + throttling injected
     at the deployment transport (``repro.traffic.faults``), no
     mitigation;
  3. **faults+retry** — the same fault plan countered by
     ``Session(retry=RetryPolicy(...))``: success rate should recover
     to the clean baseline (the paper's *robust orchestration* claim,
     quantified), and every injected error is reconciled against a
     ``ToolRetried`` event — the retry-only pass is what makes that
     accounting exact (a hedge can absorb an injected error without a
     retry event);
  4. **faults+retry+hedge** — adds ``HedgePolicy``: the latency/cost
     premium of full resilience, priced against the clean baseline.

Writes ``artifacts/BENCH_traffic.json`` (uploaded by CI).

    PYTHONPATH=src python -m benchmarks.traffic --requests 60 --rate 2
"""
from __future__ import annotations

import argparse
import json
import os

from repro.apps.session import Session
from repro.core.policies import HedgePolicy, RetryPolicy
from repro.traffic import (DEFAULT_MIX, FaultPlan, Scenario, SLOTarget,
                           TrafficDriver, Workload, aggregate_report,
                           register_fault_plan)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

FAULT_PLAN = FaultPlan(transient_rate=0.2, transient_delay_s=0.1,
                       throttle_rate=0.05, throttle_delay_s=1.0,
                       cold_start_rate=0.08, cold_start_s=2.5,
                       first_call_cold=False)
RETRY = RetryPolicy(max_attempts=8, backoff_s=0.25, backoff_mult=2.0)
HEDGE = HedgePolicy(hedge_after_s=8.0)


def _faulty_mix(stats_sink) -> tuple:
    """The DEFAULT_MIX with every deployment swapped for its registered
    faulty twin (one shared FaultStats across all of them)."""
    scenarios = []
    for s in DEFAULT_MIX:
        name = f"{s.deployment}+faults"
        register_fault_plan(name, s.deployment, FAULT_PLAN, stats=stats_sink)
        scenarios.append(Scenario(s.name, s.app, s.instance, s.pattern,
                                  name, s.llm, s.priority, s.weight))
    return tuple(scenarios)


def measure(n_requests: int = 100, rate: float = 2.0, seed: int = 0,
            arrival: str = "poisson", max_concurrency: int = 0) -> dict:
    from repro.traffic.faults import FaultStats
    slo = SLOTarget(latency_s=180.0, ttft_s=30.0, success_rate=0.85)
    wl = Workload(arrival=arrival, rate=rate, n_requests=n_requests,
                  seed=seed)

    # pass 1: clean baseline
    clean = TrafficDriver(Session(),
                          max_concurrency=max_concurrency).run(wl)

    # pass 2/3: identical workload over the faulty deployment twins
    stats = FaultStats()
    faulty_wl = Workload(scenarios=_faulty_mix(stats), arrival=arrival,
                         rate=rate, n_requests=n_requests, seed=seed)
    faulted = TrafficDriver(Session(),
                            max_concurrency=max_concurrency).run(faulty_wl)
    injected_no_retry = stats.snapshot()

    stats.reset()
    retry_only = TrafficDriver(Session(retry=RETRY),
                               max_concurrency=max_concurrency).run(faulty_wl)
    injected_with_retry = stats.snapshot()

    stats.reset()
    hedged = TrafficDriver(Session(retry=RETRY, hedge=HEDGE),
                           max_concurrency=max_concurrency).run(faulty_wl)

    agg_clean = aggregate_report(clean, slo)
    agg_fault = aggregate_report(faulted, slo)
    agg_retry = aggregate_report(retry_only, slo)
    agg_hedge = aggregate_report(hedged, slo)
    retried = agg_retry["overall"]["resilience"]["retries"]
    return {
        "workload": wl.describe(),
        "slo": slo.describe(),
        "scenarios": agg_clean["scenarios"],
        "overall": agg_clean["overall"],
        "replay": agg_clean["replay"],
        "fault_injection": {
            "plan": {
                "transient_rate": FAULT_PLAN.transient_rate,
                "throttle_rate": FAULT_PLAN.throttle_rate,
                "cold_start_rate": FAULT_PLAN.cold_start_rate,
                "cold_start_s": FAULT_PLAN.cold_start_s,
            },
            "no_mitigation": {
                "injected": injected_no_retry,
                "scenarios": agg_fault["scenarios"],
                "overall": agg_fault["overall"],
            },
            "with_retry": {
                "injected": injected_with_retry,
                "retried": retried,
                "retry_accounts_for_all_faults":
                    retried == injected_with_retry["errors"],
                "scenarios": agg_retry["scenarios"],
                "overall": agg_retry["overall"],
            },
            "with_retry_hedge": {
                "hedges": agg_hedge["overall"]["resilience"]["hedges"],
                "scenarios": agg_hedge["scenarios"],
                "overall": agg_hedge["overall"],
            },
            "success_rate": {
                "clean": agg_clean["overall"]["success_rate"],
                "faulted": agg_fault["overall"]["success_rate"],
                "recovered": agg_retry["overall"]["success_rate"],
            },
            "latency_premium_p95_s":
                (agg_hedge["overall"]["latency_s"]["p95"]
                 - agg_clean["overall"]["latency_s"]["p95"]),
            "cost_premium_usd":
                (agg_hedge["overall"]["cost_usd"]["total_sum"]
                 - agg_clean["overall"]["cost_usd"]["total_sum"]),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="mean arrivals per virtual second")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty", "uniform"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--concurrency", type=int, default=0,
                    help="in-flight run cap (0 = unbounded)")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_traffic.json"))
    args = ap.parse_args()

    rec = measure(n_requests=args.requests, rate=args.rate, seed=args.seed,
                  arrival=args.arrival, max_concurrency=args.concurrency)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)

    ov, rp = rec["overall"], rec["replay"]
    fi = rec["fault_injection"]
    print(f"# traffic bench: {rec['workload']['n_requests']} requests, "
          f"{rec['workload']['arrival']} arrivals @ "
          f"{rec['workload']['rate']}/s")
    print(f"replay.virtual_s,{rp['virtual_s']:.0f},")
    print(f"replay.wall_s,{rp['wall_s']:.2f},")
    print(f"replay.speedup,{rp['speedup']:.0f},x")
    print(f"replay.peak_concurrency,{rp['peak_concurrency']},")
    print(f"clean.success_rate,{ov['success_rate']:.3f},")
    print(f"clean.latency_p95_s,{ov['latency_s']['p95']:.1f},")
    print(f"clean.ttft_p95_s,{ov['ttft_s']['p95']:.1f},")
    print(f"clean.cost_mean_usd,{ov['cost_usd']['total_mean']:.5f},")
    sr = fi["success_rate"]
    print(f"faults.success_rate,{sr['faulted']:.3f},")
    print(f"faults.recovered_success_rate,{sr['recovered']:.3f},")
    print(f"faults.injected,{fi['with_retry']['injected']['errors']},")
    print(f"faults.retried,{fi['with_retry']['retried']},")
    print(f"faults.accounted,"
          f"{fi['with_retry']['retry_accounts_for_all_faults']},")
    print(f"faults.hedges,{fi['with_retry_hedge']['hedges']},")
    print(f"faults.latency_premium_p95_s,{fi['latency_premium_p95_s']:.1f},")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
