"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> measure.

For a chosen (arch × shape), measures the probe-corrected roofline terms
under each sharding variant (repro.launch.variants) plus the full-compile
memory analysis, and writes one JSON per (combo × variant) into
artifacts/perf/. EXPERIMENTS.md §Perf narrates the resulting
hypothesis→before→after→verdict log.

  PYTHONPATH=src python -m benchmarks.perf_hillclimb \
      --arch deepseek-v2-236b --shape train_4k --variants baseline,zero1
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "perf")


def measure(arch: str, shape: str, variant: str) -> dict:
    from repro.configs import get_config
    from repro.launch.dryrun import HBM_BW, dryrun_one
    from repro.launch.probe import corrected_roofline

    t0 = time.time()
    full = dryrun_one(arch, shape, variant=variant)
    probe = corrected_roofline(get_config(arch), shape, variant=variant)
    mem = full["memory_analysis"]
    mem_bytes = ((mem.get("argument_bytes") or 0)
                 + (mem.get("output_bytes") or 0)
                 + 2 * (mem.get("temp_bytes") or 0))
    terms = {
        "compute_s": probe["roofline"]["compute_s"],
        "memory_s": mem_bytes / HBM_BW,
        "collective_s": probe["roofline"]["collective_s"],
    }
    return {
        "arch": arch, "shape": shape, "variant": variant,
        "terms": terms, "dominant": max(terms, key=terms.get),
        "peak_bytes": mem.get("peak_bytes"),
        "argument_bytes": mem.get("argument_bytes"),
        "collective_bytes_per_chip": probe["per_chip"]["coll"],
        "flops_per_chip": probe["per_chip"]["flops"],
        "useful_flops_ratio": probe["useful_flops_ratio"],
        "wall_s": round(time.time() - t0, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline,zero1")
    args = ap.parse_args()

    os.makedirs(ART, exist_ok=True)
    for variant in args.variants.split(","):
        tag = f"{args.arch}__{args.shape}__{variant}"
        print(f"=== {tag} ===", flush=True)
        try:
            res = measure(args.arch, args.shape, variant)
        except Exception as e:
            res = {"arch": args.arch, "shape": args.shape,
                   "variant": variant,
                   "error": f"{type(e).__name__}: {e}"}
            print("FAILED:", res["error"], flush=True)
        else:
            print(json.dumps({"terms": res["terms"],
                              "dominant": res["dominant"],
                              "peak_GB": (res["peak_bytes"] or 0) / 1e9},
                             indent=None), flush=True)
        with open(os.path.join(ART, tag + ".json"), "w") as f:
            json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
