"""Agentic experiment sweep (paper §5 protocol).

For every (app × instance × pattern × deployment): run until 5 successes
(≈5 runs per paper §5.3), computing success rate as 5/total-needed
(§5.4.2). Results are cached in artifacts/agent_runs.json; every figure
function reads from the cache.
"""
from __future__ import annotations

import json
import os
import statistics
from typing import Dict, List

from repro.apps.apps import APPS
from repro.apps.runner import run_app, score_run

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
CACHE = os.path.join(ART, "agent_runs.json")

PATTERNS = ["react", "agentx", "magentic"]
DEPLOYMENTS = ["local", "faas"]
N_SUCCESS = 5
MAX_RUNS = 15


def _summarize(r, score) -> Dict:
    return {
        "app": r.app, "instance": r.instance, "pattern": r.pattern,
        "deployment": r.deployment, "success": r.success,
        "total_latency": r.total_latency,
        "llm_latency": r.trace.llm_latency,
        "tool_latency": r.trace.tool_latency,
        "framework_latency": r.trace.framework_latency,
        "input_tokens": r.trace.input_tokens,
        "output_tokens": r.trace.output_tokens,
        "llm_cost": r.trace.llm_cost, "faas_cost": r.faas_cost,
        "tool_invocations": r.trace.tool_invocations,
        "agent_invocations": r.trace.agent_invocations,
        "tool_breakdown": r.trace.tool_breakdown(),
        "agent_breakdown": r.trace.agent_breakdown(),
        "tool_latencies": [{"tool": e.tool, "latency": e.latency}
                           for e in r.trace.tool_events],
        "score": score.total, "score_attrs": score.attributes,
        "failure": r.failure_reason,
    }


def run_sweep(full: bool = True, deployments=None, force: bool = False
              ) -> List[Dict]:
    if os.path.exists(CACHE) and not force:
        return json.load(open(CACHE))
    deployments = deployments or DEPLOYMENTS
    records: List[Dict] = []
    for app_name, app in APPS.items():
        instances = list(app.instances) if full else list(app.instances)[:1]
        for inst in instances:
            for pattern in PATTERNS:
                for dep in deployments:
                    succ = 0
                    seed = 0
                    runs_needed = 0
                    while succ < N_SUCCESS and runs_needed < MAX_RUNS:
                        r = run_app(app_name, inst, pattern, dep, seed=seed)
                        rec = _summarize(r, score_run(r))
                        records.append(rec)
                        runs_needed += 1
                        seed += 1
                        if r.success:
                            succ += 1
    os.makedirs(ART, exist_ok=True)
    with open(CACHE, "w") as f:
        json.dump(records, f)
    return records


# ---------------------------------------------------------------------------
# aggregation helpers


def successes(records, **filt):
    rows = [r for r in records if r["success"]
            and all(r[k] == v for k, v in filt.items())]
    return rows


def all_runs(records, **filt):
    return [r for r in records if all(r[k] == v for k, v in filt.items())]


def mean_of(rows, key):
    vals = [r[key] for r in rows]
    return statistics.mean(vals) if vals else float("nan")


def success_rate(records, **filt):
    rows = all_runs(records, **filt)
    if not rows:
        return float("nan")
    n_succ = sum(r["success"] for r in rows)
    return n_succ / len(rows)
