"""Agentic experiment sweep (paper §5 protocol).

For every (app × instance × pattern × deployment): run until 5 successes
(≈5 runs per paper §5.3), computing success rate as 5/total-needed
(§5.4.2). Results are cached in artifacts/agent_runs.json; every figure
function reads from the cache.

Runs execute through the ``Session``/``RunSpec`` API. The per-combo
until-N-successes protocol is inherently serial (the seed sequence depends
on earlier outcomes), but combos are independent: pass ``max_workers > 1``
to fan them out across a thread pool. Records are assembled in
deterministic combo order regardless of worker count.

The session carries a content-addressed ``RunCache``: a re-invocation of
``run_sweep`` (e.g. ``--force`` figure regeneration) on a warm session
replays stored RunResults instead of re-executing runs.  Pass your own
``session=`` to share that cache across sweeps.
"""
from __future__ import annotations

import json
import os
import statistics
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional

from repro.apps.apps import APPS
from repro.apps.cache import RunCache
from repro.apps.session import RunSpec, Session, score_run
from repro.core.runtime import pattern_names

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
CACHE = os.path.join(ART, "agent_runs.json")

PATTERNS = pattern_names(tag="paper")   # react, agentx, magentic
DEPLOYMENTS = ["local", "faas"]
N_SUCCESS = 5
MAX_RUNS = 15


def _summarize(r, score) -> Dict:
    return {
        "app": r.app, "instance": r.instance, "pattern": r.pattern,
        "deployment": r.deployment, "success": r.success,
        "total_latency": r.total_latency,
        "llm_latency": r.trace.llm_latency,
        "tool_latency": r.trace.tool_latency,
        "framework_latency": r.trace.framework_latency,
        "input_tokens": r.trace.input_tokens,
        "output_tokens": r.trace.output_tokens,
        "llm_cost": r.trace.llm_cost, "faas_cost": r.faas_cost,
        "tool_invocations": r.trace.tool_invocations,
        "agent_invocations": r.trace.agent_invocations,
        "tool_breakdown": r.trace.tool_breakdown(),
        "agent_breakdown": r.trace.agent_breakdown(),
        "tool_latencies": [{"tool": e.tool, "latency": e.latency}
                           for e in r.trace.tool_events],
        "score": score.total, "score_attrs": score.attributes,
        "failure": r.failure_reason,
    }


def _run_combo(session: Session, spec: RunSpec) -> List[Dict]:
    """Paper protocol for one combo: serial seeds until N successes."""
    _, runs = session.run_until_n_successes(spec, n=N_SUCCESS,
                                            max_runs=MAX_RUNS)
    return [_summarize(r, score_run(r)) for r in runs]


def run_sweep(full: bool = True, deployments=None, force: bool = False,
              max_workers: int = 1,
              session: Optional[Session] = None,
              cache_dir: Optional[str] = None) -> List[Dict]:
    """``cache_dir`` persists every RunResult to disk (wire-serialized);
    a cold re-sweep in a fresh process then replays stored runs instead
    of executing — ``score_run`` rebuilds the deterministic world/policy
    for replayed results."""
    if os.path.exists(CACHE) and not force:
        return json.load(open(CACHE))
    deployments = deployments or DEPLOYMENTS
    if session is not None and cache_dir is not None:
        raise ValueError("pass cache_dir OR a preconfigured session, "
                         "not both (the session already owns its cache)")
    session = session if session is not None else Session(
        cache=RunCache(cache_dir=cache_dir))
    combos: List[RunSpec] = []
    for app_name, app in APPS.items():
        instances = list(app.instances) if full else list(app.instances)[:1]
        for inst in instances:
            for pattern in PATTERNS:
                for dep in deployments:
                    combos.append(RunSpec(app_name, inst, pattern, dep))
    if max_workers > 1:
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            per_combo = list(pool.map(
                lambda spec: _run_combo(session, spec), combos))
    else:
        per_combo = [_run_combo(session, spec) for spec in combos]
    records: List[Dict] = [rec for rows in per_combo for rec in rows]
    os.makedirs(ART, exist_ok=True)
    with open(CACHE, "w") as f:
        json.dump(records, f)
    return records


# ---------------------------------------------------------------------------
# aggregation helpers


def successes(records, **filt):
    rows = [r for r in records if r["success"]
            and all(r[k] == v for k, v in filt.items())]
    return rows


def all_runs(records, **filt):
    return [r for r in records if all(r[k] == v for k, v in filt.items())]


def mean_of(rows, key):
    vals = [r[key] for r in rows]
    return statistics.mean(vals) if vals else float("nan")


def success_rate(records, **filt):
    rows = all_runs(records, **filt)
    if not rows:
        return float("nan")
    n_succ = sum(r["success"] for r in rows)
    return n_succ / len(rows)
