"""Serving throughput benchmark: slot-batched decode vs the serial
per-slot loop, and scheduler-v2 admission latency.

Measures, on the reduced tinyllama config (CPU CI baseline; pass
--arch/--full for others):

  * decode-step throughput: tokens/s of ONE jitted ``decode_step`` over
    the full ``n_slots`` batch vs ``n_slots`` sequential batch-1 calls
    (the pre-redesign scheduler's inner loop);
  * end-to-end: ``BatchScheduler.drain`` wall time vs serial
    ``Engine.generate_ids`` per request;
  * admission latency: time-to-first-token percentiles (p50/p95) under a
    bursty arrival of mixed-length prompts — bucketed batched prefill
    (scheduler v2) vs the v1 per-request exact-length admission, whose
    per-length jit recompiles dominate cold TTFT.

Writes ``artifacts/BENCH_serving.json`` (uploaded by CI).

    PYTHONPATH=src python -m benchmarks.serving --slots 8
"""
from __future__ import annotations

import argparse
import json
import os
import random
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.serving import BatchScheduler, Engine

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _time_decode(engine, batch, max_len, reps) -> float:
    """Steady-state seconds per jitted decode step at the given batch
    width (the cache is donated, so it threads through the loop)."""
    from repro.models.model import init_cache
    cache = init_cache(engine.cfg, batch, max_len,
                       dtype=engine.params["embed"].dtype)
    tok = jnp.ones((batch, 1), jnp.int32)
    pos = jnp.arange(8, 8 + batch, dtype=jnp.int32)   # mixed positions
    logits, cache = engine._decode(engine.params, cache=cache, token=tok,
                                   pos=pos)    # warm (compile)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(reps):
        logits, cache = engine._decode(engine.params, cache=cache,
                                       token=tok, pos=pos)
        jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / reps


def _pct(sorted_vals, q: float) -> float:
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def measure_admission(engine, n_slots: int = 4, max_len: int = 64,
                      n_requests: int = 12, max_new: int = 4,
                      seed: int = 0) -> dict:
    """TTFT under a bursty arrival: ``n_requests`` mixed-length prompts
    submitted at once, drained by the scheduler step loop.

    Compares scheduler-v2 bucketed batched prefill against the v1
    per-request exact-length admission (``batched_prefill=False``). Both
    run cold on the prefill path: the v1 mode pays one jit compile per
    distinct prompt length, the bucketed mode one per power-of-two
    bucket — plus it prefills same-bucket requests together — which is
    where the admission-latency win comes from. Decode and sampler
    traces at the admission shapes are warmed up front so the timed
    drains measure admission, not decode compiles.
    """
    from repro.models.model import init_cache
    cache = init_cache(engine.cfg, n_slots, max_len,
                       dtype=engine.params["embed"].dtype)
    tok = jnp.ones((n_slots, 1), jnp.int32)
    pos = jnp.arange(n_slots, dtype=jnp.int32)
    logits, _ = engine._decode(engine.params, cache=cache, token=tok, pos=pos)
    engine.sample(logits, [0] * n_slots, [0] * n_slots)
    engine.sample(logits[:1], [0], [0])
    jax.block_until_ready(logits)

    rng = random.Random(seed)
    lengths = [rng.randint(4, max_len // 2) for _ in range(n_requests)]
    prompts = [[rng.randrange(1, engine.cfg.vocab_size) for _ in range(n)]
               for n in lengths]
    out = {"n_requests": n_requests,
           "prompt_lengths": sorted(set(lengths))}
    for mode, flag in (("bucketed", True), ("per_request", False)):
        sched = BatchScheduler(engine, n_slots=n_slots, max_len=max_len,
                               batched_prefill=flag)
        rids = [sched.submit(prompt_ids=ids, max_new=max_new)
                for ids in prompts]
        t0 = time.perf_counter()
        sched.drain()
        wall = time.perf_counter() - t0
        ttfts = sorted(sched.requests[r].t_first_token -
                       sched.requests[r].t_submit for r in rids)
        out[mode] = {"ttft_p50_s": _pct(ttfts, 0.50),
                     "ttft_p95_s": _pct(ttfts, 0.95),
                     "wall_s": wall}
    out["ttft_p95_speedup"] = (out["per_request"]["ttft_p95_s"] /
                               out["bucketed"]["ttft_p95_s"])
    out["ttft_p50_speedup"] = (out["per_request"]["ttft_p50_s"] /
                               out["bucketed"]["ttft_p50_s"])
    return out


def measure_paging(engine, n_slots: int = 4, max_len: int = 64,
                   block_size: int = 8, n_requests: int = 8,
                   max_new: int = 4, seed: int = 0) -> dict:
    """Prefix-reuse economics of the paged KV cache.

    One paged scheduler serves two bursts: a COLD burst of prompts with
    disjoint prefixes (every admission prefills the whole prompt) and a
    HOT burst sharing one of the now-cached prefixes (admissions skip to
    the divergent suffix).  Reports TTFT percentiles per phase, the hot
    hit rate and blocks-in-use vs the contiguous footprint.  CI-asserted:
    the hot burst must actually hit (> 0 rate) and its TTFT p95 must
    beat cold — prefix reuse that doesn't show up in admission latency
    is a regression.
    """
    rng = random.Random(seed)
    plen, slen = 5 * block_size, block_size          # 40 + 8 token prompts
    sched = BatchScheduler(engine, n_slots=n_slots, max_len=max_len,
                           paged_kv=True, block_size=block_size)

    def burst(prompts):
        rids = [sched.submit(prompt_ids=ids, max_new=max_new)
                for ids in prompts]
        sched.drain()
        return sorted(sched.requests[r].t_first_token -
                      sched.requests[r].t_submit for r in rids)

    def prompt(prefix):
        return prefix + [rng.randrange(1, engine.cfg.vocab_size)
                         for _ in range(slen)]

    # warm every trace both phases use (full prefill, suffix
    # continuation, decode, sampler, gather/scatter) before timing
    warm_prefix = [rng.randrange(1, engine.cfg.vocab_size)
                   for _ in range(plen)]
    burst([prompt(warm_prefix)])
    burst([prompt(warm_prefix)])

    prefixes = [[rng.randrange(1, engine.cfg.vocab_size)
                 for _ in range(plen)] for _ in range(n_requests)]
    base = sched.paging_stats()
    cold = burst([prompt(p) for p in prefixes])
    mid = sched.paging_stats()
    hot = burst([prompt(prefixes[0]) for _ in range(n_requests)])
    end = sched.paging_stats()

    hot_hits = end["hits"] - mid["hits"]
    hot_rate = hot_hits / n_requests
    out = {
        "n_requests": n_requests,
        "block_size": block_size,
        "prefix_tokens": plen,
        "cold": {"ttft_p50_s": _pct(cold, 0.50),
                 "ttft_p95_s": _pct(cold, 0.95),
                 "hits": mid["hits"] - base["hits"]},
        "hot": {"ttft_p50_s": _pct(hot, 0.50),
                "ttft_p95_s": _pct(hot, 0.95),
                "hits": hot_hits, "hit_rate": hot_rate},
        "tokens_reused": end["tokens_reused"] - base["tokens_reused"],
        "blocks_in_use_peak": end["n_blocks"] - end["blocks_free"],
        "contiguous_equiv_blocks": n_slots * (max_len // block_size),
        "ttft_p95_hot_speedup": _pct(cold, 0.95) / _pct(hot, 0.95),
    }
    assert hot_rate > 0, f"warm burst never hit the prefix cache: {end}"
    assert out["hot"]["ttft_p95_s"] < out["cold"]["ttft_p95_s"], (
        f"prefix reuse did not improve TTFT p95: {out}")
    return out


def measure(arch: str = "tinyllama-1.1b", reduced: bool = True,
            n_slots: int = 8, max_len: int = 128, max_new: int = 16,
            reps: int = 20) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    engine = Engine(cfg, temperature=0.0)

    # -- decode-step microbench: one batched call vs n_slots serial calls
    batched_s = _time_decode(engine, n_slots, max_len, reps)
    serial_1 = _time_decode(engine, 1, max_len, reps)
    step_batched_tok_s = n_slots / batched_s
    step_serial_tok_s = 1.0 / serial_1   # per-slot loop: one call per token

    # -- end-to-end: scheduler drain vs serial generate per request
    prompts = [f"request {i}: summarize the agentic workflow results"
               for i in range(n_slots)]
    sched = BatchScheduler(engine, n_slots=n_slots, max_len=max_len)
    for p in prompts:   # warm prefill/decode/insert compiles before timing
        sched.submit(p, max_new=2)
    sched.drain()
    rids = [sched.submit(p, max_new=max_new) for p in prompts]
    t0 = time.perf_counter()
    results = sched.drain()
    e2e_batched = time.perf_counter() - t0
    toks = sum(r.new_tokens for r in results.values())

    reqs = [sched.requests[r] for r in rids]
    for r in reqs:   # warm serial compiles before timing
        engine.generate_ids(r.prompt_ids, 1, rid=r.rid,
                            cache_len=sched.max_len)
    t0 = time.perf_counter()
    stoks = 0
    for r in reqs:
        g = engine.generate_ids(r.prompt_ids, r.max_new, rid=r.rid,
                                cache_len=sched.max_len)
        stoks += g.new_tokens
    e2e_serial = time.perf_counter() - t0

    # -- admission latency: bursty arrivals on a FRESH engine (shared
    # weights), so both modes pay their prefill compiles — the quantity
    # being measured; measure_admission warms decode/sampler itself
    adm_engine = Engine(cfg, params=engine.params, temperature=0.0)
    admission = measure_admission(adm_engine, n_slots=n_slots,
                                  max_len=min(max_len, 64))

    # -- paged KV + prefix reuse: hot vs cold admission on a fresh
    # engine (shared weights) so the suffix-continuation traces compile
    # inside the phase that warms them
    from repro.models.model import supports_paged_cache
    if supports_paged_cache(cfg) and engine.supports_fixed_shape_prefill:
        paging_engine = Engine(cfg, params=engine.params, temperature=0.0)
        paging = measure_paging(paging_engine, n_slots=min(n_slots, 4),
                                max_len=min(max_len, 64))
    else:
        paging = {"skipped": f"{cfg.name} has no paged-cache support"}

    return {
        "arch": cfg.name,
        "n_slots": n_slots,
        "max_len": max_len,
        "max_new": max_new,
        "decode_step": {
            "batched_tok_s": step_batched_tok_s,
            "serial_tok_s": step_serial_tok_s,
            "speedup": step_batched_tok_s / step_serial_tok_s,
        },
        "end_to_end": {
            "batched_tok_s": toks / e2e_batched,
            "serial_tok_s": stoks / e2e_serial,
            "speedup": (toks / e2e_batched) / (stoks / e2e_serial),
        },
        "admission": admission,
        "paging": paging,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_serving.json"))
    args = ap.parse_args()

    rec = measure(args.arch, reduced=not args.full, n_slots=args.slots,
                  max_len=args.max_len, max_new=args.max_new)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    ds, ee, adm = rec["decode_step"], rec["end_to_end"], rec["admission"]
    print(f"# serving bench on {rec['arch']} n_slots={rec['n_slots']}")
    print(f"decode_step.batched_tok_s,{ds['batched_tok_s']:.1f},")
    print(f"decode_step.serial_tok_s,{ds['serial_tok_s']:.1f},")
    print(f"decode_step.speedup,{ds['speedup']:.2f},x")
    print(f"end_to_end.batched_tok_s,{ee['batched_tok_s']:.1f},")
    print(f"end_to_end.serial_tok_s,{ee['serial_tok_s']:.1f},")
    print(f"end_to_end.speedup,{ee['speedup']:.2f},x")
    print(f"admission.bucketed.ttft_p50_s,{adm['bucketed']['ttft_p50_s']:.3f},")
    print(f"admission.bucketed.ttft_p95_s,{adm['bucketed']['ttft_p95_s']:.3f},")
    print(f"admission.per_request.ttft_p50_s,"
          f"{adm['per_request']['ttft_p50_s']:.3f},")
    print(f"admission.per_request.ttft_p95_s,"
          f"{adm['per_request']['ttft_p95_s']:.3f},")
    print(f"admission.ttft_p95_speedup,{adm['ttft_p95_speedup']:.2f},x")
    pg = rec["paging"]
    if "skipped" not in pg:
        print(f"paging.cold.ttft_p95_s,{pg['cold']['ttft_p95_s']:.3f},")
        print(f"paging.hot.ttft_p95_s,{pg['hot']['ttft_p95_s']:.3f},")
        print(f"paging.hot.hit_rate,{pg['hot']['hit_rate']:.2f},")
        print(f"paging.tokens_reused,{pg['tokens_reused']},")
        print(f"paging.ttft_p95_hot_speedup,"
              f"{pg['ttft_p95_hot_speedup']:.2f},x")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
