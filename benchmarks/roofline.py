"""Roofline analysis (assignment §Roofline): per (arch × shape) on the
single-pod 16×16 mesh, derive the three roofline terms from compiled
artifacts.

Term sources (see EXPERIMENTS.md §Roofline for the full rationale):
  compute_s    = probe-corrected HLO FLOPs / 197 TF/s
                 (probes: L=1 & L=2 unrolled compiles -> per-layer cost,
                 extrapolated; needed because XLA cost_analysis counts
                 lax.scan bodies once)
  memory_s     = (argument + output + 2×temp bytes) / 819 GB/s
                 from the FULL compile's buffer assignment (real HBM
                 working set; raw HLO "bytes accessed" ignores fusion)
  collective_s = probe-corrected collective bytes / 50 GB/s ICI

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--probe] [--arch A --shape S]

--probe runs the 2 probe compiles per combo (slow, run once; cached in
artifacts/probes/). Without it, the table is assembled from cached probes +
dry-run artifacts.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse   # noqa: E402
import glob       # noqa: E402
import json       # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
DRYRUN_DIR = os.path.join(ART, "dryrun")
PROBE_DIR = os.path.join(ART, "probes")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_probe(arch: str, shape: str) -> dict:
    from repro.configs import get_config
    from repro.launch.probe import corrected_roofline
    return corrected_roofline(get_config(arch), shape)


def load_artifacts():
    full, probes = {}, {}
    for f in glob.glob(os.path.join(DRYRUN_DIR, "*__16x16.json")):
        d = json.load(open(f))
        if "error" not in d:
            full[(d["arch"], d["shape"])] = d
    for f in glob.glob(os.path.join(PROBE_DIR, "*.json")):
        d = json.load(open(f))
        probes[(d["arch"], d["shape"])] = d
    return full, probes


def combined_row(arch: str, shape: str, full: dict, probe: dict) -> dict:
    mem = full["memory_analysis"]
    mem_bytes = ((mem.get("argument_bytes") or 0)
                 + (mem.get("output_bytes") or 0)
                 + 2 * (mem.get("temp_bytes") or 0))
    flops = probe["per_chip"]["flops"] if probe else full["flops_per_chip"]
    coll = (probe["per_chip"]["coll"] if probe
            else full["collective_bytes_per_chip"]["total"])
    terms = {"compute_s": flops / PEAK_FLOPS,
             "memory_s": mem_bytes / HBM_BW,
             "collective_s": coll / ICI_BW}
    dominant = max(terms, key=terms.get)
    n_chips = full["n_chips"]
    ratio = (probe["useful_flops_ratio"] if probe
             else full["useful_flops_ratio"])
    total = sum(terms.values())
    return {
        "arch": arch, "shape": shape, "kind": full["kind"],
        "flops_per_chip": flops, "hbm_bytes_per_chip": mem_bytes,
        "collective_bytes_per_chip": coll,
        "peak_bytes_per_chip": mem.get("peak_bytes"),
        **terms, "dominant": dominant,
        "model_flops": full["model_flops"],
        "useful_flops_ratio": ratio,
        "roofline_fraction": terms["compute_s"] / max(total, 1e-30),
        "probe_corrected": probe is not None,
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "compute_s":
        return ("compute-bound: raise MFU via larger per-chip batch or "
                "fewer remat recomputes")
    if d == "memory_s":
        if row["kind"] == "decode":
            return ("HBM-bound on weight/KV streaming: quantize cache, "
                    "shrink per-chip cache via more model-parallel cache "
                    "sharding, or batch more requests per chip")
        return ("HBM-bound: fuse/remat fewer intermediates or shard "
                "activations further so per-chip working set drops")
    return ("collective-bound: reshard to cut all-gathers (e.g. kv-head or "
            "expert placement), overlap collectives with compute, or move "
            "traffic from ICI to intra-chip")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--probe", action="store_true",
                    help="run probe compiles for combos missing a cache")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()

    os.makedirs(PROBE_DIR, exist_ok=True)
    full, probes = load_artifacts()

    combos = sorted(full) if not args.arch else [(args.arch, args.shape)]
    if args.probe:
        for arch, shape in combos:
            if (arch, shape) in probes:
                continue
            tag = f"{arch}__{shape}"
            print(f"probing {tag} ...", flush=True)
            try:
                res = run_probe(arch, shape)
            except Exception as e:
                print("  probe failed:", e, flush=True)
                continue
            with open(os.path.join(PROBE_DIR, tag + ".json"), "w") as f:
                json.dump(res, f, indent=2)
            probes[(arch, shape)] = res

    rows = []
    for arch, shape in sorted(full):
        row = combined_row(arch, shape, full[(arch, shape)],
                           probes.get((arch, shape)))
        row["next_step"] = suggestion(row)
        rows.append(row)

    with open(os.path.join(ART, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=2)

    hdr = (f"{'arch':25s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dominant':>12s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:25s} {r['shape']:12s} {r['compute_s']:10.3e} "
              f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
              f"{r['dominant'][:-2]:>12s} {r['useful_flops_ratio']:7.2f}")


if __name__ == "__main__":
    main()
