"""Render EXPERIMENTS.md tables from artifacts (dry-run, roofline, perf,
agent sweep). The narrative sections live in this file; tables auto-fill so
the doc always matches the artifacts.

    PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
import glob
import json
import os
import statistics

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "artifacts")


def _load(path):
    with open(path) as f:
        return json.load(f)


def dryrun_table(mesh_tag: str) -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, "dryrun",
                                           f"*__{mesh_tag}.json"))):
        d = _load(f)
        if "error" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | FAILED | | | |")
            continue
        mem = d["memory_analysis"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | ok "
            f"| {(mem.get('argument_bytes') or 0) / 1e9:.2f} "
            f"| {(mem.get('peak_bytes') or 0) / 1e9:.2f} "
            f"| {d['collective_bytes_per_chip']['total'] / 1e9:.2f} "
            f"| {d['compile_s']:.0f} |")
    hdr = ("| arch | shape | compile | args GB/dev | peak GB/dev "
           "| coll GB/dev (scan-body once) | compile s |\n"
           "|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table() -> str:
    path = os.path.join(ART, "roofline.json")
    if not os.path.exists(path):
        return "_(run `python -m benchmarks.roofline --probe`)_"
    rows = []
    for r in _load(path):
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| {r['dominant'].replace('_s', '')} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['compute_s'] / max(total, 1e-30):.2f} "
            f"| {r['next_step']} |")
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| 6ND/HLO | roofline frac | what would move it |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def perf_table() -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, "perf", "*.json"))):
        d = _load(f)
        if "error" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | {d['variant']} "
                        f"| FAILED | | | | |")
            continue
        t = d["terms"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['variant']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {d['dominant'].replace('_s', '')} "
            f"| {(d.get('peak_bytes') or 0) / 1e9:.1f} |")
    hdr = ("| arch | shape | variant | compute s | memory s | collective s "
           "| dominant | peak GB |\n|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def agent_summary() -> str:
    path = os.path.join(ART, "agent_runs.json")
    if not os.path.exists(path):
        return "_(run `python -m benchmarks.run`)_"
    recs = _load(path)
    rows = []
    for app in ("web_search", "stock_correlation", "research_report"):
        for pat in ("react", "agentx", "magentic"):
            for dep in ("local", "faas"):
                sel = [r for r in recs if r["app"] == app
                       and r["pattern"] == pat and r["deployment"] == dep]
                succ = [r for r in sel if r["success"]]
                if not sel:
                    continue
                sr = len(succ) / len(sel)
                m = lambda k: statistics.mean(r[k] for r in succ) if succ else 0
                rows.append(
                    f"| {app} | {pat} | {dep} | {sr:.0%} "
                    f"| {m('total_latency'):.1f} | {m('input_tokens'):.0f} "
                    f"| {m('output_tokens'):.0f} | {m('llm_cost'):.4f} "
                    f"| {m('score'):.1f} |")
    hdr = ("| app | pattern | deploy | success | latency s | in tok "
           "| out tok | LLM $ | accuracy |\n|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


TEMPLATE = open(os.path.join(os.path.dirname(__file__),
                             "experiments_template.md")).read()


def main():
    out = (TEMPLATE
           .replace("{{DRYRUN_SINGLE}}", dryrun_table("16x16"))
           .replace("{{DRYRUN_MULTI}}", dryrun_table("2x16x16"))
           .replace("{{ROOFLINE}}", roofline_table())
           .replace("{{PERF}}", perf_table())
           .replace("{{AGENTS}}", agent_summary()))
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(out)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
