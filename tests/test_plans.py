"""Plan compilation: trace->graph lifting, template-keyed cache,
planner-free replay, deviation fallback, and the traffic integration."""
import dataclasses
import json
import os

import pytest

from repro.apps.cache import RunCache
from repro.apps.session import RunSpec, Session
from repro.core.events import (PlanCacheMiss, PlanCompiled, PlanFallback,
                               ToolInvoked)
from repro.plans import PlanCache, graph_from_wire, graph_to_wire, plan_key
from repro.plans.compile import (TemplateMismatch, compile_result,
                                 extract_params, normalize_task)
from repro.traffic import TrafficDriver, Workload, aggregate_report
from repro.traffic.workload import Scenario

PLANNERS = {"stage_generator", "planner", "cot_reasoner"}


def planner_calls(result):
    return sum(1 for c in result.trace.llm_events if c.agent in PLANNERS)


def tool_seq(result):
    return [(e.event.server, e.event.tool, e.event.args)
            for e in result.extras["events"] if isinstance(e, ToolInvoked)]


def plan_markers(result):
    return [type(e).__name__ for e in result.extras["events"]
            if type(e).__name__.startswith("Plan")
            and type(e).__name__ != "PlanProduced"]


WEB = RunSpec("web_search", "quantum", "agentx", seed=1)


# ---------------------------------------------------------------------------
# compiler


def test_compile_lifts_trace_to_typed_graph():
    result = Session().execute(WEB)
    assert result.success
    g = compile_result(result)
    assert g is not None and g.app == "web_search" and g.stages
    kinds = {s.kind for n in g.nodes for s in n.slots.values()}
    # the search query is spec-bound, fetch URLs are data-flow edges
    assert "param" in kinds and "extract" in kinds
    search = next(n for n in g.nodes if n.tool == "google_search")
    assert any(s.kind == "param" and s.param == "query"
               for s in search.slots.values())
    assert g.edges()  # at least one (src, dst) data-flow edge


def test_graph_wire_roundtrip_and_version_gate():
    g = compile_result(Session().execute(WEB))
    wire = graph_to_wire(g)
    json.dumps(wire)                       # JSON-serializable end to end
    assert graph_from_wire(wire) == g
    bad = dict(wire, version=999)
    with pytest.raises(ValueError):
        graph_from_wire(bad)


# ---------------------------------------------------------------------------
# template normalization + key fingerprint (spec-bound vs template-bound)


def test_plan_key_shared_across_instances_and_seeds():
    base = plan_key(WEB)
    assert base is not None
    assert plan_key(dataclasses.replace(WEB, seed=7)) == base
    assert plan_key(dataclasses.replace(WEB, instance="edge")) == base
    assert plan_key(dataclasses.replace(WEB, llm="jax")) == base


def test_plan_key_separates_structure():
    base = plan_key(WEB)
    other_app = plan_key(RunSpec("research_report", "flow", "agentx", seed=1))
    faas = plan_key(dataclasses.replace(WEB, deployment="faas"))
    assert other_app is not None and other_app != base
    assert faas is not None and faas != base  # remote prompt + caps differ


def test_plan_key_none_for_uncompilable_specs():
    assert plan_key(dataclasses.replace(WEB, pattern="react")) is None
    assert plan_key(dataclasses.replace(WEB, pattern="magentic")) is None
    assert plan_key(dataclasses.replace(
        WEB, backend_factory=lambda *a, **k: None)) is None


def test_normalize_task_edges():
    from repro.apps.apps import APPS
    local = APPS["web_search"].prompt("quantum", False)
    remote = APPS["web_search"].prompt("quantum", True)
    t_local, var, is_remote = normalize_task("web_search", local)
    t_remote, var2, is_remote2 = normalize_task("web_search", remote)
    assert var == var2 and not is_remote and is_remote2
    assert t_local != t_remote            # storage hint is structural
    # same template for a different entity: only the variable differs
    t_edge, var_edge, _ = normalize_task(
        "web_search", APPS["web_search"].prompt("edge", False))
    assert t_edge == t_local and var_edge != var
    with pytest.raises(TemplateMismatch):
        normalize_task("web_search", "please do something else entirely")


def test_extract_params_per_app():
    from repro.apps.apps import APPS
    p = extract_params("stock_correlation",
                       APPS["stock_correlation"].prompt("apple", False))
    assert p["filename"].endswith(".png") and "c0" in p
    q = extract_params("web_search",
                       APPS["web_search"].prompt("quantum", False))
    assert list(q) == ["query"]


# ---------------------------------------------------------------------------
# cache


def test_plan_cache_disk_roundtrip_and_corrupt_skip(tmp_path):
    g = compile_result(Session().execute(WEB))
    pc = PlanCache(cache_dir=str(tmp_path))
    pc.put("k1", g)
    (tmp_path / "plan_zz.json").write_text("{not json")   # corrupt entry
    pc2 = PlanCache(cache_dir=str(tmp_path))
    assert len(pc2) == 1 and pc2.get("k1") == g
    assert pc2.stats()["hits"] == 1
    assert pc2.get("nope") is None and pc2.stats()["misses"] == 1


# ---------------------------------------------------------------------------
# compiled replay through Session


def test_same_spec_replay_is_planner_free_and_bit_identical():
    fresh = Session().execute(WEB)
    pc = PlanCache()
    s = Session(plan_cache=pc)
    cold = s.execute(WEB)
    warm = s.execute(WEB)
    assert plan_markers(cold) == ["PlanCacheMiss", "PlanCompiled"]
    assert plan_markers(warm) == []       # pure replay
    assert planner_calls(cold) > 0 and planner_calls(warm) == 0
    assert warm.success
    assert tool_seq(warm) == tool_seq(fresh) == tool_seq(cold)
    assert warm.artifact == fresh.artifact
    assert pc.stats()["hits"] == 1 and pc.stats()["fallbacks"] == 0
    # the planning overhead is gone from the virtual timeline too
    assert warm.total_latency < cold.total_latency


def test_cross_instance_replay_reuses_graph():
    pc = PlanCache()
    s = Session(plan_cache=pc)
    s.execute(WEB)
    warm = s.execute(RunSpec("web_search", "edge", "agentx", seed=2))
    assert warm.success and planner_calls(warm) == 0
    assert "edge" in warm.artifact.lower()
    assert len(pc) == 1                   # one graph serves both instances


def test_deviation_falls_back_to_full_replanning():
    pc = PlanCache()
    s = Session(plan_cache=pc)
    s.execute(WEB)
    key = plan_key(WEB)
    g = pc.get(key)
    poisoned = dataclasses.replace(
        g, nodes=(dataclasses.replace(g.nodes[0], tool="no_such_tool"),)
        + g.nodes[1:])
    pc.put(key, poisoned)
    events = []
    r = s.execute(RunSpec("web_search", "edge", "agentx", seed=2),
                  on_event=events.append)
    assert r.success                       # fallback run completed
    fb = [e for e in events if isinstance(e, PlanFallback)]
    assert fb and fb[0].reason.startswith("node-failed")
    assert pc.stats()["fallbacks"] == 1
    assert pc.get(key).nodes[0].tool != "no_such_tool"   # recompiled


def test_plan_compilable_specs_bypass_run_cache():
    rc, pc = RunCache(), PlanCache()
    s = Session(cache=rc, plan_cache=pc)
    s.execute(WEB)                        # compilable: plan path, no RunCache
    assert rc.stats()["entries"] == 0 and len(pc) == 1
    s.execute(RunSpec("web_search", "quantum", "react", seed=1))
    assert rc.stats()["entries"] == 1     # react still run-cached


# ---------------------------------------------------------------------------
# traffic integration


def test_traffic_reports_plan_cache_hit_rate():
    mix = (Scenario("web/agentx", "web_search", "quantum", "agentx"),)
    wl = Workload(scenarios=mix, n_requests=8, rate=4.0, seed=3,
                  unique_seeds=2)
    pc = PlanCache()
    report = TrafficDriver(Session(plan_cache=pc)).run(wl)
    assert report.plan_cache is not None
    assert report.plan_cache["hits"] >= 1
    assert report.plan_cache["hit_rate"] > 0
    agg = aggregate_report(report)
    assert agg["plan_cache"] == report.plan_cache
    # without a plan cache the section stays absent
    plain = TrafficDriver(Session()).run(wl)
    assert plain.plan_cache is None
    assert "plan_cache" not in aggregate_report(plain)


def test_unique_seeds_folds_spec_seeds():
    wl = Workload(n_requests=10, seed=2, unique_seeds=3)
    seeds = [a.spec.seed for a in wl.arrivals()]
    assert set(seeds) == {200_000, 200_001, 200_002}
    baseline = Workload(n_requests=10, seed=2)
    assert [a.spec.seed for a in baseline.arrivals()] == [
        200_000 + i for i in range(10)]
    assert "unique_seeds" in wl.describe()
