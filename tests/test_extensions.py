"""Beyond-paper extensions: CoT pre-reasoning, parallel stages, monolithic
FaaS deployment."""
import statistics

from repro.apps.runner import run_app

N = 5


def test_parallel_stages_cut_latency():
    seq = statistics.mean(
        run_app("multi_topic_digest", "tech", "agentx", "local", s)
        .total_latency for s in range(N))
    par = statistics.mean(
        run_app("multi_topic_digest", "tech", "agentx-parallel", "local", s)
        .total_latency for s in range(N))
    assert par < 0.8 * seq, (seq, par)


def test_parallel_stages_preserve_artifact():
    r = run_app("multi_topic_digest", "tech", "agentx-parallel", "local", 0)
    assert r.success
    assert "Digest section" in r.artifact
    assert r.extras["outcome"]["parallel_groups"][0] == [0, 1, 2]


def test_cot_adds_reasoner_inferences():
    r = run_app("research_report", "why", "agentx-cot", "local", seed=0)
    roles = r.trace.agent_breakdown()
    assert roles.get("cot_reasoner", 0) >= 2   # stage-gen + per-stage plans


def test_cot_improves_success_at_token_cost():
    base = [run_app("research_report", "why", "agentx", "local", s)
            for s in range(10)]
    cot = [run_app("research_report", "why", "agentx-cot", "local", s)
           for s in range(10)]
    sr_base = sum(r.success for r in base) / 10
    sr_cot = sum(r.success for r in cot) / 10
    assert sr_cot >= sr_base
    tin_base = statistics.mean(r.trace.input_tokens for r in base)
    tin_cot = statistics.mean(r.trace.input_tokens for r in cot)
    assert tin_cot > tin_base            # reasoning isn't free


def test_multi_topic_all_patterns():
    for pat in ("react", "agentx", "magentic"):
        r = run_app("multi_topic_digest", "tech", pat, "local", seed=1)
        assert r.success, (pat, r.failure_reason)
