"""Full-stack integration: agents + MCP + FaaS + judge + (optionally) the
real JAX serving engine as the LLM endpoint."""
import pytest

from repro.apps.apps import APPS
from repro.apps.runner import run_app, score_run


@pytest.mark.parametrize("app,inst", [
    ("web_search", "materials"),
    ("stock_correlation", "cola"),
    ("research_report", "flow"),
])
@pytest.mark.parametrize("pattern", ["react", "agentx", "magentic"])
def test_every_app_pattern_runs(app, inst, pattern):
    r = run_app(app, inst, pattern, "local", seed=1)
    # never crashes; trace always populated
    assert r.trace.agent_invocations >= 1
    assert r.total_latency > 0
    s = score_run(r)
    assert 0 <= s.total <= 100


@pytest.mark.parametrize("deployment", ["faas", "faas-mono"])
def test_faas_deployments_end_to_end(deployment):
    r = run_app("web_search", "edge", "react", deployment, seed=0)
    assert r.success
    assert r.faas_cost > 0
    assert r.artifact_path.startswith("s3://")


def test_determinism_same_seed():
    a = run_app("web_search", "quantum", "agentx", "local", seed=5)
    b = run_app("web_search", "quantum", "agentx", "local", seed=5)
    assert a.success == b.success
    assert a.trace.input_tokens == b.trace.input_tokens
    assert a.total_latency == pytest.approx(b.total_latency)


def test_jax_engine_backed_agent():
    """The real JAX serving engine in the agent loop (JaxLLMBackend)."""
    from repro.configs import get_config
    from repro.core.llm import JaxLLMBackend
    from repro.serving import Engine

    engine = Engine(get_config("tinyllama-1.1b").reduced())
    r = run_app("web_search", "quantum", "react", "local", seed=0,
                backend_factory=lambda world, policy, trace: JaxLLMBackend(
                    world, policy, engine, trace, max_gen=2))
    assert r.success
    assert r.trace.agent_invocations >= 3


def test_artifact_content_matches_app():
    r = run_app("stock_correlation", "apple", "react", "local", seed=0)
    assert r.success
    assert r.artifact.startswith("PNG")
    r2 = run_app("research_report", "why", "react", "local", seed=0)
    assert r2.success and "Report on" in r2.artifact
