"""Unified orchestration API tests: pattern registry, run-event stream /
Trace parity, Session.execute_many determinism, unified tool validation,
and per-client JSON-RPC ids."""
import dataclasses

import pytest

from repro.apps.runner import PATTERNS, run_app
from repro.apps.session import RunSpec, Session
from repro.core.events import (LLMCompleted, RunCompleted, RunStarted,
                               ToolInvoked, derive_trace)
from repro.core.llm import ToolCall
from repro.core.metrics import Trace
from repro.core.runtime import (AgentRuntime, PatternConfig, RunOutcome,
                                create_runner, pattern_names,
                                register_pattern, resolve_pattern)
from repro.env.world import World
from repro.faas.deployments import deploy_local

OLD_PATTERNS = ["agentx", "agentx-cot", "agentx-parallel",
                "agentx-cot-parallel", "react", "magentic"]


# -- registry ---------------------------------------------------------------


def test_registry_round_trip_old_names():
    """Every name in the old PATTERNS dict resolves through the registry
    and via the back-compat mapping view."""
    for name in OLD_PATTERNS:
        rp = resolve_pattern(name)
        assert rp.name == name
        assert issubclass(rp.runner_cls, AgentRuntime)
        assert PATTERNS[name] is not None
    # the registry only grew: old names all present, and the single
    # post-refactor addition is the compiled-replay pattern
    assert set(PATTERNS) - set(OLD_PATTERNS) == {"agentx-compiled"}


def test_registry_variant_configs():
    assert resolve_pattern("agentx").config.cot is False
    assert resolve_pattern("agentx-cot").config.cot is True
    assert resolve_pattern("agentx-parallel").config.parallel_stages is True
    cp = resolve_pattern("agentx-cot-parallel").config
    assert cp.cot and cp.parallel_stages
    assert resolve_pattern("react").config.max_steps == 25
    mag = resolve_pattern("magentic").config
    assert mag.max_replans == 3 and mag.overhead_jitter


def test_registry_paper_tag_and_unknown():
    assert pattern_names(tag="paper") == ["react", "agentx", "magentic"]
    with pytest.raises(KeyError):
        resolve_pattern("nope")


def test_register_pattern_decorator_one_liner_variant():
    from repro.core import runtime as rt

    @register_pattern("test-react-short", max_steps=2)
    class _Short(resolve_pattern("react").runner_cls):
        pass

    try:
        rp = resolve_pattern("test-react-short")
        assert rp.config.max_steps == 2
        r = Session().execute(RunSpec("web_search", "quantum",
                                      "test-react-short", seed=0))
        # 2 iterations are not enough to finish the web-search loop
        assert not r.success
    finally:
        rt._REGISTRY.pop("test-react-short", None)


# -- events / trace ---------------------------------------------------------


@pytest.mark.parametrize("pattern", ["agentx", "react", "magentic"])
def test_event_stream_trace_parity(pattern):
    """The Trace is derivable from the run-event stream: same LLM, tool
    and framework events, in order."""
    r = run_app("web_search", "quantum", pattern, "local", seed=3)
    events = r.extras["events"]
    assert isinstance(events[0], RunStarted)
    assert isinstance(events[-1], RunCompleted)
    derived = derive_trace(events)
    assert derived.llm_events == r.trace.llm_events
    assert derived.tool_events == r.trace.tool_events
    assert derived.framework_events == r.trace.framework_events
    assert derived.input_tokens == r.trace.input_tokens
    assert derived.llm_cost == r.trace.llm_cost


def test_live_event_observation():
    seen = []
    session = Session(on_event=seen.append)
    r = session.execute(RunSpec("web_search", "quantum", "agentx", seed=3))
    assert seen == r.extras["events"]
    assert sum(isinstance(e, LLMCompleted) for e in seen) \
        == r.trace.agent_invocations
    assert sum(isinstance(e, ToolInvoked) for e in seen) \
        == r.trace.tool_invocations


def test_crashing_run_still_terminates_event_stream():
    """A pattern-level crash is a supported path (Session catches it);
    the event stream must still end with RunCompleted so live observers
    don't leak in-flight runs."""
    def boom(world, policy, trace):
        class _Boom:
            def complete(self, request):
                raise RuntimeError("backend down")
        return _Boom()

    r = Session().execute(RunSpec("web_search", "quantum", "react",
                                  backend_factory=boom))
    assert not r.success
    assert "backend down" in r.failure_reason
    events = r.extras["events"]
    assert isinstance(events[-1], RunCompleted)
    assert events[-1].completed is False


def test_non_trace_logging_backend_keeps_trace_event_parity():
    """A backend that doesn't append to the shared Trace still yields a
    Trace consistent with the event stream (the runtime back-fills)."""
    from repro.core.llm import Decision, LLMResponse

    def quiet(world, policy, trace):
        class _Quiet:
            def complete(self, request):
                world.clock.sleep(0.5)
                return LLMResponse(Decision(text="Final Answer: done"),
                                   input_tokens=10, output_tokens=5,
                                   latency=0.5)
        return _Quiet()

    r = Session().execute(RunSpec("web_search", "quantum", "react",
                                  backend_factory=quiet))
    assert r.trace.agent_invocations == 1
    assert (r.trace.input_tokens, r.trace.output_tokens) == (10, 5)
    derived = derive_trace(r.extras["events"])
    assert derived.llm_events == r.trace.llm_events


def test_run_outcome_mapping_contract():
    out = RunOutcome(completed=True, data={"final": "x"})
    assert out["completed"] is True
    assert out.get("final") == "x"
    assert out.get("missing", 42) == 42
    assert set(out) == {"completed", "final"}
    assert len(out) == 2


# -- batch executor ---------------------------------------------------------


def _fingerprint(r):
    return (r.app, r.instance, r.pattern, r.deployment, r.success,
            r.total_latency, r.trace.input_tokens, r.trace.output_tokens,
            r.trace.llm_cost, r.faas_cost, r.failure_reason)


def test_execute_many_matches_serial():
    """Same RunResult metrics regardless of max_workers (bit-identical)."""
    specs = [RunSpec("web_search", "quantum", p, d, seed=s)
             for p in ("react", "agentx")
             for d in ("local", "faas")
             for s in (0, 1)]
    session = Session()
    serial = session.execute_many(specs, max_workers=1)
    pooled = session.execute_many(specs, max_workers=4)
    assert [_fingerprint(r) for r in serial] \
        == [_fingerprint(r) for r in pooled]


def test_run_until_n_successes_via_session():
    session = Session()
    succ, runs = session.run_until_n_successes(
        RunSpec("web_search", "quantum", "react"), n=3, max_runs=10)
    assert len(succ) == 3 and len(runs) >= 3


# -- unified tool validation ------------------------------------------------


def _make_runner(pattern):
    world = World(seed=0)
    clients, _ = deploy_local(world, ["serper", "fetch"])
    trace = Trace()

    class _NullBackend:
        def complete(self, request):
            raise AssertionError("not used")

    return create_runner(pattern, _NullBackend(), clients, world, trace,
                         deployment="local"), trace


@pytest.mark.parametrize("pattern", ["agentx", "react", "magentic"])
def test_invoke_rejects_unknown_server_and_tool(pattern):
    """All patterns validate both server and tool name identically —
    including ReAct, which previously only errored on server lookup."""
    runner, trace = _make_runner(pattern)
    # unknown server, explicit
    out = runner.invoke(ToolCall("nosuch", "google_search", {}))
    assert out.startswith("<tool-error") and "unknown server" in out
    # known server, tool never registered there
    out = runner.invoke(ToolCall("serper", "not_a_tool", {}))
    assert out.startswith("<tool-error") and "unknown tool" in out
    # unknown tool with no server hint
    out = runner.invoke(ToolCall("", "not_a_tool", {}))
    assert out.startswith("<tool-error")
    # all three attempts were accounted as failed tool events
    assert [e.ok for e in trace.tool_events] == [False, False, False]
    # a valid call still works
    ok = runner.invoke(ToolCall("serper", "google_search",
                                {"query": "quantum", "num_results": 2}))
    assert not ok.startswith("<tool-error")
    assert trace.tool_events[-1].ok


def test_runtime_has_no_per_pattern_invoke_overrides():
    """Zero duplicated plumbing: the runner subclasses share the base
    implementation of invoke/overhead/complete and the tool registry."""
    for name in ("agentx", "react", "magentic"):
        cls = resolve_pattern(name).runner_cls
        for method in ("invoke", "overhead", "complete", "run", "__init__"):
            assert getattr(cls, method) is getattr(AgentRuntime, method), \
                (name, method)


# -- per-client JSON-RPC ids -------------------------------------------------


def test_jsonrpc_ids_are_per_client():
    world = World(seed=0)
    clients, _ = deploy_local(world, ["serper", "fetch"])
    ids = {}
    for name, client in clients.items():
        ids[name] = [client._ids.next() for _ in range(3)]
    # both clients continue from their own sequence (initialize happened
    # during deploy), unaffected by each other's traffic
    assert ids["serper"] == ids["fetch"]
    assert ids["serper"][0] == 2  # initialize consumed id 1


def test_overhead_and_config_knobs():
    runner, trace = _make_runner("magentic")
    assert runner.config.overhead_jitter
    runner.overhead("test-dispatch")
    assert len(trace.framework_events) == 1
    ev = trace.framework_events[0]
    # jittered: dt in [0.6, 1.4] * 2.6
    assert 0.6 * 2.6 <= ev.latency <= 1.4 * 2.6
    cfg = dataclasses.replace(PatternConfig(), overhead_local_s=1.0)
    assert cfg.overhead_s("local") == 1.0
    assert cfg.overhead_s("faas") == 0.0
