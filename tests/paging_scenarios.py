"""Shared scenario machinery for the paged-KV parity battery.

Both the always-on seeded tests (``test_paging.py``) and the
hypothesis property suite (``test_properties.py``) drive the same
generator + runner: a scenario is a list of requests (prompt ids,
priority, budget, arrival step) and the assertion is always the same —
the paged scheduler's per-request token streams are bit-identical to
the contiguous scheduler's, which are bit-identical to serial
generation.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.configs import get_config
from repro.core.events import EngineStepped
from repro.serving import BatchScheduler, Engine

BLOCK = 8          # scenario block size: small enough to cross often
MAX_LEN = 64

_ENGINES: Dict[tuple, Engine] = {}


def _mla_dense_cfg():
    # deepseek's reduced config is MLA+MoE; MoE capacity dispatch is
    # batch-composition-dependent, so parity runs on an MLA-dense variant
    import dataclasses
    cfg = get_config("deepseek-v2-236b").reduced()
    return dataclasses.replace(cfg, arch_type="dense", moe=None)


def get_engine(arch: str, temperature: float, chunk: int = 0) -> Engine:
    """Engines are stateless across schedulers (the scheduler owns the
    cache) — build each (arch, temperature, chunk) once per process."""
    key = (arch, temperature, chunk)
    if key not in _ENGINES:
        cfg = (_mla_dense_cfg() if arch == "mla"
               else get_config("tinyllama-1.1b").reduced())
        _ENGINES[key] = Engine(cfg, temperature=temperature,
                               prefill_chunk=chunk)
    return _ENGINES[key]


def gen_scenario(rng, n_req: int, *, vocab: int = 400,
                 max_new_hi: int = 6) -> List[dict]:
    """Random request mix biased toward the paging edge cases: shared
    prefix groups, block-boundary prompt lengths (len % BLOCK in
    {0, 1, BLOCK-1}), priority classes, staggered arrivals."""
    shared = [int(rng.integers(1, vocab))
              for _ in range(int(rng.integers(BLOCK, 3 * BLOCK + 1)))]
    reqs = []
    for i in range(n_req):
        if rng.random() < 0.6:                  # shared-prefix group
            base = list(shared)
        else:
            base = [int(rng.integers(1, vocab))
                    for _ in range(int(rng.integers(1, 2 * BLOCK)))]
        # land total lengths on/next to block boundaries half the time;
        # prompts range all the way up to MAX_LEN - max_new_hi - 2, so the
        # battery exercises prefill_bucket(len) == MAX_LEN (the historical
        # half-context submit clamp that desynced the serial cross-check
        # at that edge is fixed; prompt+generation must still fit the
        # fixed cache for the serial comparison to stay meaningful)
        if rng.random() < 0.5:
            target = int(rng.integers(1, 8)) * BLOCK + int(rng.integers(-1, 2))
            target = max(len(base) + 1,
                         min(target, MAX_LEN - max_new_hi - 2))
        else:
            target = len(base) + int(rng.integers(1, BLOCK + 1))
        ids = base + [int(rng.integers(1, vocab))
                      for _ in range(target - len(base))]
        reqs.append({"ids": ids,
                     "priority": int(rng.integers(0, 3)),
                     "max_new": int(rng.integers(1, max_new_hi + 1)),
                     "at": int(rng.integers(0, 6))})
    return reqs


def run_scenario(engine: Engine, scenario: List[dict], *,
                 paged: bool, prefix: bool = True,
                 n_slots: int = 2, n_blocks: Optional[int] = None,
                 events: Optional[list] = None) -> Dict[int, List[int]]:
    """Drive one scheduler over the scenario's arrival schedule; returns
    {request index: generated token ids}."""
    kw: dict = {}
    if paged:
        kw = dict(paged_kv=True, block_size=BLOCK, n_blocks=n_blocks,
                  prefix_cache=prefix)
    sched = BatchScheduler(engine, n_slots=n_slots, max_len=MAX_LEN, **kw)
    if events is not None:
        sched.subscribe(lambda e: events.append(e)
                        if isinstance(e, EngineStepped) else None)
    order = sorted(range(len(scenario)), key=lambda i: scenario[i]["at"])
    rid_to_idx: Dict[int, int] = {}
    out: Dict[int, List[int]] = {}
    pos, step = 0, 0
    while len(out) < len(scenario):
        while pos < len(order) and scenario[order[pos]]["at"] <= step:
            r = scenario[order[pos]]
            rid = sched.submit(prompt_ids=r["ids"], max_new=r["max_new"],
                               priority=r["priority"])
            rid_to_idx[rid] = order[pos]
            pos += 1
        for fin in sched.step():
            out[rid_to_idx[fin.rid]] = list(fin.out_ids)
        step += 1
        assert step < 10_000, "scenario did not drain"
    return out


def serial_tokens(engine: Engine, scenario: List[dict],
                  rid_of: Dict[int, int]) -> Dict[int, List[int]]:
    """Uninterrupted per-request generation with the same sampling keys
    the schedulers use (rid = submission order)."""
    out = {}
    for idx, r in enumerate(scenario):
        res = engine.generate_ids(r["ids"], r["max_new"], rid=rid_of[idx],
                                  cache_len=MAX_LEN)
        out[idx] = list(res.token_ids)
    return out


def submission_rids(scenario: List[dict]) -> Dict[int, int]:
    """rid each request gets from the runner's arrival-ordered submit
    loop (stable sort by arrival step)."""
    order = sorted(range(len(scenario)), key=lambda i: scenario[i]["at"])
    return {idx: rid for rid, idx in enumerate(order)}


def assert_parity(engine: Engine, scenario: List[dict], *,
                  n_blocks: Optional[int] = None,
                  check_serial: bool = True) -> None:
    """The battery's core assertion: contiguous == paged+prefix ==
    paged-no-prefix (== serial), and the no-prefix paged event stream
    matches contiguous modulo the paging gauges."""
    ev_contig: list = []
    ev_paged: list = []
    contig = run_scenario(engine, scenario, paged=False, events=ev_contig)
    paged = run_scenario(engine, scenario, paged=True, n_blocks=n_blocks)
    noprefix = run_scenario(engine, scenario, paged=True, prefix=False,
                            n_blocks=n_blocks, events=ev_paged)
    assert paged == contig, f"paged+prefix diverged: {paged} != {contig}"
    assert noprefix == contig, f"paged-no-prefix diverged: {noprefix}"
    if check_serial:
        serial = serial_tokens(engine, scenario, submission_rids(scenario))
        assert serial == contig, f"contiguous diverged from serial: {serial}"
    # without prefix reuse the step loop is lockstep-identical, so every
    # event field except the paging gauges must match exactly
    assert len(ev_contig) == len(ev_paged)
    for a, b in zip(ev_contig, ev_paged):
        for f in ("t", "live", "queued", "generated", "prefilled",
                  "preempted"):
            assert getattr(a, f) == getattr(b, f), (
                f"event field {f}: contiguous {a} vs paged {b}")
