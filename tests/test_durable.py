"""Durable execution tests: journal round-trips, corrupt-segment
handling, version gates, the crash+resume parity matrix
(interrupted + resumed == uninterrupted, bit-identical, across
patterns x deployments), recovery economics, and the shared
disk-persistence helpers the journal and both caches ride on."""
import json
import os

import pytest

from repro.apps.cache import RunCache, spec_fingerprint
from repro.apps.session import RunSpec, Session
from repro.core.events import (RunCompleted, WIRE_VERSION, derive_trace,
                               to_wire)
from repro.core.persist import (atomic_write_json, atomic_write_text,
                                load_json_dir)
from repro.durable import (JOURNAL_FORMAT, JOURNAL_VERSION, JournalError,
                           JournalVersionError, RunJournal, billed_cost,
                           recovered_cost, recovered_tokens, resume_run)
from repro.traffic import (FaultPlan, Scenario, TrafficDriver, Workload,
                           register_fault_plan)
from test_event_wire import SAMPLES

CRASH = FaultPlan(crash_rate=1.0, crash_min_events=6, crash_max_events=6,
                  first_call_cold=False)
NO_CRASH = FaultPlan(crash_rate=0.0, crash_min_events=6, crash_max_events=6,
                     first_call_cold=False)


def _twins(deployment):
    """Register a crash twin + its no-crash control for ``deployment``;
    both seed their World as the wrapped deployment (``world_alias``),
    so they re-derive the identical run — the control IS the
    uninterrupted ground truth of the crashed run."""
    register_fault_plan(f"{deployment}+dcrash", deployment, CRASH)
    register_fault_plan(f"{deployment}+dclean", deployment, NO_CRASH)
    return f"{deployment}+dcrash", f"{deployment}+dclean"


def _wire(result):
    return [to_wire(e) for e in result.extras["events"]]


# -- journal segments -------------------------------------------------------


def test_segment_roundtrips_every_event_type(tmp_path):
    """Writer -> disk -> reader round-trips one instance of EVERY
    registered RunEvent type, and the read-back stream still derives a
    full trace."""
    journal = RunJournal(str(tmp_path), fsync_batch=3)
    spec = RunSpec("web_search", "quantum", "agentx")
    w = journal.begin("k" * 64, spec)
    for ev in SAMPLES:
        w.append(ev)
    w.close()
    seg = journal.read("k" * 64)
    assert seg.events == SAMPLES
    assert seg.resumes == 0 and not seg.truncated
    assert not seg.complete          # SAMPLES doesn't END with RunCompleted
    trace = derive_trace(seg.events)
    assert trace.llm_events and trace.tool_events


def test_segment_completeness_is_terminal_event(tmp_path):
    journal = RunJournal(str(tmp_path), fsync_batch=1)
    w = journal.begin("a" * 64, RunSpec("web_search", "quantum", "agentx"))
    w.append(SAMPLES[0])
    w.append(RunCompleted(t=9.0, completed=True, data={}))
    w.close()
    assert journal.read("a" * 64).complete
    assert journal.interrupted() == []


def test_abort_drops_unfsynced_buffer(tmp_path):
    """Host-failure semantics: everything up to the last fsync barrier
    survives, the buffered tail is lost."""
    journal = RunJournal(str(tmp_path), fsync_batch=4)
    w = journal.begin("b" * 64, RunSpec("web_search", "quantum", "agentx"))
    for ev in SAMPLES[:6]:           # 4 fsynced, 2 buffered
        w.append(ev)
    w.abort()
    seg = journal.read("b" * 64)
    assert seg.events == SAMPLES[:4]
    assert journal.interrupted() == ["b" * 64]


def test_truncated_tail_is_dropped(tmp_path):
    """A torn write at the physical tail: the valid prefix is still a
    committed, resumable history."""
    journal = RunJournal(str(tmp_path), fsync_batch=1)
    w = journal.begin("c" * 64, RunSpec("web_search", "quantum", "agentx"))
    for ev in SAMPLES[:5]:
        w.append(ev)
    w.close()
    path = journal.path_for("c" * 64)
    with open(path, "a") as f:
        f.write('{"type": "ToolInvoked", "t": 9.9, "eve')   # torn write
    seg = journal.read("c" * 64)
    assert seg.truncated and seg.events == SAMPLES[:5]


def test_corrupt_middle_line_truncates_rest(tmp_path):
    """Corruption mid-segment: everything AFTER the bad line is dropped
    too — an event stream with a hole in it cannot be trusted."""
    journal = RunJournal(str(tmp_path), fsync_batch=1)
    w = journal.begin("d" * 64, RunSpec("web_search", "quantum", "agentx"))
    for ev in SAMPLES[:6]:
        w.append(ev)
    w.close()
    path = journal.path_for("d" * 64)
    lines = open(path).read().splitlines()
    lines[3] = lines[3][: len(lines[3]) // 2]        # corrupt event #3
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    seg = journal.read("d" * 64)
    assert seg.truncated
    assert seg.events == SAMPLES[:2]                 # events after hole gone


def test_resume_writer_repairs_torn_tail(tmp_path):
    journal = RunJournal(str(tmp_path), fsync_batch=1)
    w = journal.begin("e" * 64, RunSpec("web_search", "quantum", "agentx"))
    for ev in SAMPLES[:4]:
        w.append(ev)
    w.close()
    path = journal.path_for("e" * 64)
    with open(path, "a") as f:
        f.write('{"half a line')
    seg = journal.read("e" * 64)
    assert seg.truncated
    w2 = journal.resume_writer(seg)
    w2.append(SAMPLES[0])            # skipped (committed replay)
    for ev in SAMPLES[:4]:
        w2.append(ev)                # 3 more skips, then 1 live append
    w2.close()
    seg2 = journal.read("e" * 64)
    assert not seg2.truncated
    assert seg2.events == SAMPLES[:4] + [SAMPLES[3]]
    assert seg2.resumes == 1


def test_header_gates(tmp_path):
    journal = RunJournal(str(tmp_path))
    key = "f" * 64
    path = journal.path_for(key)

    def write_header(header):
        with open(path, "w") as f:
            f.write(json.dumps(header) + "\n")

    write_header({"format": "something-else", "version": 1})
    with pytest.raises(JournalError):
        journal.read(key)
    write_header({"format": JOURNAL_FORMAT, "version": JOURNAL_VERSION - 1,
                  "wire_version": WIRE_VERSION})
    with pytest.raises(JournalVersionError):
        journal.read(key)
    write_header({"format": JOURNAL_FORMAT, "version": JOURNAL_VERSION,
                  "wire_version": WIRE_VERSION - 1})
    with pytest.raises(JournalVersionError):
        journal.read(key)
    with open(path, "w") as f:
        f.write("this is not even json\n")
    with pytest.raises(JournalError):
        journal.read(key)


def test_discard_and_len(tmp_path):
    journal = RunJournal(str(tmp_path), fsync_batch=1)
    journal.begin("9" * 64, RunSpec("web_search", "quantum", "agentx")).close()
    assert len(journal) == 1 and journal.keys() == ["9" * 64]
    assert journal.discard("9" * 64) and len(journal) == 0
    assert not journal.discard("9" * 64)


# -- crash + resume parity --------------------------------------------------

MATRIX = [(p, d) for p in ("agentx", "react", "magentic")
          for d in ("local", "faas", "a2a")]


@pytest.mark.parametrize("pattern,deployment", MATRIX,
                         ids=[f"{p}-{d}" for p, d in MATRIX])
def test_interrupted_plus_resumed_is_bit_identical(tmp_path, pattern,
                                                   deployment):
    """THE durable-execution contract: kill a run mid-pattern, resume it
    from the journal, and the full event sequence and artifact equal the
    uninterrupted run's, wire-bit for wire-bit."""
    crash_dep, clean_dep = _twins(deployment)
    clean = Session().execute(
        RunSpec("web_search", "quantum", pattern, clean_dep))

    session = Session(journal=RunJournal(str(tmp_path), fsync_batch=1))
    spec = RunSpec("web_search", "quantum", pattern, crash_dep)
    dead = session.execute(spec)
    assert dead.extras.get("aborted") and not dead.success
    assert len(dead.extras["events"]) == 6
    seg = session.journal.read(session.journal.key_for(spec))
    assert len(seg.events) == 6 and not seg.complete

    resumed = resume_run(session, spec)
    assert not resumed.extras.get("aborted")
    assert _wire(resumed) == _wire(clean)
    assert resumed.artifact == clean.artifact
    assert resumed.success == clean.success
    assert resumed.extras["resume"]["replayed_events"] == 6
    assert session.journal.read(session.journal.key_for(spec)).complete


def test_fsync_batch_tail_loss_still_converges(tmp_path):
    """With a coarse fsync batch a crash swallows the buffered tail —
    the committed prefix is SHORTER than what the dead attempt emitted —
    and the resume re-executes the lost events.  Parity still holds
    after repeated crashes (attempt-keyed draws guarantee progress)."""
    register_fault_plan("faas+dvar", "faas",
                        FaultPlan(crash_rate=1.0, crash_min_events=5,
                                  crash_max_events=30,
                                  first_call_cold=False))
    register_fault_plan("faas+dclean", "faas", NO_CRASH)
    clean = Session().execute(
        RunSpec("web_search", "quantum", "agentx", "faas+dclean"))
    session = Session(journal=RunJournal(str(tmp_path), fsync_batch=4))
    spec = RunSpec("web_search", "quantum", "agentx", "faas+dvar")

    result = session.execute(spec)
    lost_tail = False
    resumes = 0
    while result.extras.get("aborted") and resumes < 10:
        seg = session.journal.read(session.journal.key_for(spec))
        # committed history never exceeds what the dead attempt emitted
        assert len(seg.events) <= len(result.extras["events"])
        lost_tail |= len(seg.events) < len(result.extras["events"])
        resumes += 1
        result = resume_run(session, spec)
    assert not result.extras.get("aborted")
    assert resumes >= 1 and lost_tail    # the knob actually cost something
    assert _wire(result) == _wire(clean)
    assert result.artifact == clean.artifact


def test_second_crash_resumes_further(tmp_path):
    """A resume that crashes AGAIN leaves a longer committed prefix; the
    next resume continues from there.  With this plan's attempt-keyed
    draws the run dies at event 9, resumes and dies at 14, then the
    attempt-2 draw (8) lands inside committed history — disarmed — and
    the run finishes.  Parity still holds through both crashes."""
    name = "local+dcrash2"
    register_fault_plan(name, "local",
                        FaultPlan(crash_rate=1.0, crash_min_events=5,
                                  crash_max_events=30,
                                  first_call_cold=False))
    # the crash twin injects nothing but kills, so plain "local" is the
    # uninterrupted control
    clean = Session().execute(RunSpec("web_search", "quantum", "agentx"))
    session = Session(journal=RunJournal(str(tmp_path), fsync_batch=1))
    spec = RunSpec("web_search", "quantum", "agentx", name)

    dead = session.execute(spec)
    assert dead.extras.get("aborted")
    assert len(dead.extras["events"]) == 9
    dead2 = resume_run(session, spec)
    assert dead2.extras.get("aborted")
    assert len(dead2.extras["events"]) == 14
    seg = session.journal.read(session.journal.key_for(spec))
    assert len(seg.events) == 14 and seg.resumes == 1

    resumed = resume_run(session, spec)
    assert not resumed.extras.get("aborted")
    assert resumed.extras["resume"]["replayed_events"] == 14
    assert _wire(resumed) == _wire(clean)
    assert resumed.artifact == clean.artifact


def test_resume_of_complete_segment_reexecutes(tmp_path):
    session = Session(journal=RunJournal(str(tmp_path), fsync_batch=1))
    spec = RunSpec("web_search", "quantum", "agentx")
    first = session.execute(spec)
    assert session.journal.read(session.journal.key_for(spec)).complete
    again = resume_run(session, spec)
    assert "resume" not in again.extras
    assert _wire(again) == _wire(first)


def test_tampered_journal_deviates_to_full_rerun(tmp_path):
    """A journal that no longer matches the run's deterministic history
    is detected by the replay cursor; resume falls back to a fresh,
    fully billed rerun that still converges to the clean result."""
    # seed=6: the attempt-0 draw kills the run, the attempt-1 draw does
    # not — so the post-deviation fallback rerun completes
    register_fault_plan("local+dtamper", "local",
                        FaultPlan(crash_rate=0.5, crash_min_events=6,
                                  crash_max_events=6, first_call_cold=False,
                                  seed=6))
    clean = Session().execute(RunSpec("web_search", "quantum", "agentx"))
    session = Session(journal=RunJournal(str(tmp_path), fsync_batch=1))
    spec = RunSpec("web_search", "quantum", "agentx", "local+dtamper")
    dead = session.execute(spec)
    assert dead.extras.get("aborted")
    key = session.journal.key_for(spec)
    path = session.journal.path_for(key)
    lines = open(path).read().splitlines()
    d = json.loads(lines[1])         # first event: RunStarted
    d["task"] = "a task this run never saw"
    lines[1] = json.dumps(d)
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")

    resumed = resume_run(session, spec)
    assert "resume" not in resumed.extras      # fallback, not recovery
    assert not resumed.extras.get("aborted")
    assert _wire(resumed) == _wire(clean)


def test_foreign_journal_file_falls_back(tmp_path):
    session = Session(journal=RunJournal(str(tmp_path), fsync_batch=1))
    spec = RunSpec("web_search", "quantum", "agentx")
    key = session.journal.key_for(spec)
    with open(session.journal.path_for(key), "w") as f:
        f.write("not a journal\n")
    result = resume_run(session, spec)         # JournalError -> execute
    assert result.extras.get("events")
    assert "resume" not in result.extras


# -- recovery economics -----------------------------------------------------


def test_billing_identity_and_recovered_progress(tmp_path):
    crash_dep, clean_dep = _twins("faas")
    clean = Session().execute(
        RunSpec("web_search", "quantum", "agentx", clean_dep))
    session = Session(journal=RunJournal(str(tmp_path), fsync_batch=1))
    spec = RunSpec("web_search", "quantum", "agentx", crash_dep)
    session.execute(spec)
    resumed = resume_run(session, spec)
    assert recovered_tokens(resumed) > 0
    assert recovered_cost(resumed) > 0
    assert billed_cost(resumed) + recovered_cost(resumed) == pytest.approx(
        resumed.total_cost)
    # the resumed run re-derives the whole history, so its intrinsic
    # totals equal the clean run's — and what it BILLS is strictly less
    assert resumed.total_cost == pytest.approx(clean.total_cost)
    assert billed_cost(resumed) < clean.total_cost
    # fresh runs recover nothing by definition
    assert recovered_cost(clean) == 0.0
    assert billed_cost(clean) == clean.total_cost


def test_aborted_runs_never_cached(tmp_path):
    crash_dep, _ = _twins("local")
    cache = RunCache()
    session = Session(cache=cache)
    spec = RunSpec("web_search", "quantum", "agentx", crash_dep)
    dead = session.execute(spec)
    assert dead.extras.get("aborted")
    assert cache.get(spec_fingerprint(spec)) is None


# -- the recovery traffic scenario ------------------------------------------

MIX = (Scenario("web/local", "web_search", "quantum", "agentx", "local"),
       Scenario("web/faas", "web_search", "edge", "react", "faas"))


def _crash_mix(rate):
    plan = FaultPlan(crash_rate=rate, first_call_cold=False)
    out = []
    for s in MIX:
        name = f"{s.deployment}+tcrash"
        register_fault_plan(name, s.deployment, plan)
        out.append(Scenario(s.name, s.app, s.instance, s.pattern, name,
                            s.llm, s.priority, s.weight))
    return tuple(out)


def test_driver_resumes_journaled_dead_runs(tmp_path):
    """The recovery scenario end-to-end: under a heavy crash rate the
    journal+resume driver recovers the crash-free success rate exactly
    and bills less than restart-from-scratch."""
    wl_kw = dict(arrival="poisson", rate=4.0, n_requests=16, seed=3)
    clean_rep = TrafficDriver(Session()).run(
        Workload(scenarios=MIX, **wl_kw))
    crash_wl = Workload(scenarios=_crash_mix(0.5), **wl_kw)

    rerun_rep = TrafficDriver(Session(), restart="rerun").run(crash_wl)
    resume_rep = TrafficDriver(
        Session(journal=RunJournal(str(tmp_path), fsync_batch=1)),
        restart="resume").run(crash_wl)

    def ok(rep):
        return sum(r.result.success for r in rep.records)

    assert sum(r.crashes for r in resume_rep.records) > 0
    assert sum(r.resumes for r in resume_rep.records) > 0
    assert ok(resume_rep) == ok(clean_rep)
    assert ok(rerun_rep) == ok(clean_rep)
    # per-run parity against the clean pass (same worlds via world_alias)
    for c, r in zip(clean_rep.records, resume_rep.records):
        assert r.result.success == c.result.success

    def billed(rep):
        return sum(r.sunk_cost + billed_cost(r.result) for r in rep.records)

    assert billed(resume_rep) < billed(rerun_rep)
    crashed = [r for r in resume_rep.records if r.crashes and r.resumes]
    assert crashed and all(r.sunk_cost > 0 for r in crashed)


def test_driver_restart_none_leaves_crashes_failed(tmp_path):
    crash_wl = Workload(scenarios=_crash_mix(1.0), arrival="uniform",
                        rate=4.0, n_requests=4, seed=1)
    rep = TrafficDriver(Session(), restart="none").run(crash_wl)
    # crash_rate=1.0: every run whose draw lands inside its natural
    # length dies and STAYS dead (no restart loop engaged)
    assert any(r.result.extras.get("aborted") for r in rep.records)
    assert all(r.crashes == 0 for r in rep.records)


def test_driver_auto_restart_resolution(tmp_path):
    assert TrafficDriver(Session()).restart == "none"
    assert TrafficDriver(
        Session(journal=RunJournal(str(tmp_path)))).restart == "resume"
    with pytest.raises(ValueError):
        TrafficDriver(Session(), restart="nonsense")


# -- shared disk-persistence helpers (repro.core.persist) -------------------


def test_atomic_write_and_load_json_dir(tmp_path):
    d = str(tmp_path)
    atomic_write_json(os.path.join(d, "one.json"), {"v": 1})
    atomic_write_json(os.path.join(d, "two.json"), {"v": 2})
    with open(os.path.join(d, "bad.json"), "w") as f:
        f.write("{corrupt")
    with open(os.path.join(d, "ignored.txt"), "w") as f:
        f.write("{}")
    loaded = load_json_dir(d, lambda stem, payload: (stem, payload["v"]))
    assert loaded == {"one": 1, "two": 2}      # corrupt + foreign skipped
    assert not [p for p in os.listdir(d) if ".tmp." in p]


def test_load_json_dir_prefix_filter(tmp_path):
    d = str(tmp_path)
    atomic_write_json(os.path.join(d, "plan_x.json"), {"v": 1})
    atomic_write_json(os.path.join(d, "other.json"), {"v": 2})
    loaded = load_json_dir(d, lambda stem, payload: (stem, payload["v"]),
                           prefix="plan_")
    assert loaded == {"x": 1}        # stem is the name MINUS the prefix


def test_atomic_write_text_best_effort(tmp_path):
    target = os.path.join(str(tmp_path), "no", "such", "dir", "f.txt")
    assert atomic_write_text(target, "x", best_effort=True) is False
    with pytest.raises(OSError):
        atomic_write_text(target, "x")
    ok_path = os.path.join(str(tmp_path), "f.txt")
    assert atomic_write_text(ok_path, "hello") is True
    assert open(ok_path).read() == "hello"
