"""Dry-run machinery on a small debug mesh (2×2 / 2×2×2), exercised in a
subprocess so the forced host-device count never leaks into other tests.

The full 16×16 and 2×16×16 sweeps are exercised by
``python -m repro.launch.dryrun --all [--multi-pod]`` (artifacts in
artifacts/dryrun/); this test proves the identical code path on CI scale.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
from repro.configs import get_config
from repro.launch.dryrun import dryrun_one

arch, shape, mp = sys.argv[1], sys.argv[2], sys.argv[3] == "mp"
res = dryrun_one(arch, shape, multi_pod=mp, debug_mesh=True)
print("RESULT::" + json.dumps({k: res[k] for k in
    ("arch", "shape", "dominant", "n_chips")}))
"""


def _run(arch, shape, mp=False):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, shape, "mp" if mp else "sp"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT::")][0]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.parametrize("arch,shape", [
    ("tinyllama-1.1b", "train_4k"),
    ("phi3.5-moe-42b-a6.6b", "decode_32k"),
    # mamba2-370m/long_500k dies in a NATIVE XLA abort (free(): invalid
    # pointer) while compiling the 500k-token SSM scan on forced-host
    # devices — pre-existing since the seed and unreachable from Python
    # (returncode -6, no traceback), so it is skipped rather than
    # xfailed to keep tier-1 output clean.  Tracked in ROADMAP "Open
    # items"; repro: the dryrun.KNOWN_BAD entry + an explicit
    # `python -m repro.launch.dryrun --arch mamba2-370m --shape long_500k`.
    pytest.param("mamba2-370m", "long_500k",
                 marks=pytest.mark.skip(
                     reason="known native XLA abort (free(): invalid "
                            "pointer) — pre-existing, tracked in ROADMAP "
                            "open items")),
    ("zamba2-7b", "decode_32k"),
])
def test_debug_mesh_lowers(arch, shape):
    res = _run(arch, shape)
    assert res["n_chips"] == 4
    assert res["dominant"] in ("compute_s", "memory_s", "collective_s")


def test_debug_mesh_multipod():
    res = _run("tinyllama-1.1b", "train_4k", mp=True)
    assert res["n_chips"] == 8


def test_production_artifacts_complete():
    """All 40 pairs × 2 meshes must have clean artifacts after the sweep."""
    art = os.path.join(ROOT, "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip("run `python -m repro.launch.dryrun --all` first")
    files = [f for f in os.listdir(art) if f.endswith(".json")]
    if len(files) < 80:
        pytest.skip(f"sweep incomplete ({len(files)}/80)")
    bad = []
    for f in files:
        d = json.load(open(os.path.join(art, f)))
        if "error" in d:
            bad.append(f)
    assert not bad, bad
