"""Serving engine, scheduler, training loop, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.serving import BatchScheduler, Engine
from repro.training import (load_checkpoint, save_checkpoint, train,
                            init_opt_state)
from repro.training.data import AgentTraceCorpus, SyntheticLM
from repro.training.optimizer import OptConfig, lr_schedule


def test_training_loss_decreases():
    cfg = get_config("tinyllama-1.1b").reduced()
    out = train(cfg, steps=12, batch=2, seq_len=64, log_every=4)
    assert out["history"][-1]["loss"] < out["history"][0]["loss"]


def test_lr_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (1, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] > lrs[3] > lrs[4]          # cosine decay
    assert lrs[4] >= 0.099 * cfg.lr          # 10% floor


def test_checkpoint_roundtrip():
    cfg = get_config("qwen1.5-4b").reduced()
    out = train(cfg, steps=3, batch=2, seq_len=32, log_every=1)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, out["params"], out["opt_state"], step=3,
                        meta={"arch": cfg.name})
        params2, opt2, step = load_checkpoint(d, out["params"],
                                              out["opt_state"])
        assert step == 3
        a = jax.tree_util.tree_leaves(out["params"])
        b = jax.tree_util.tree_leaves(params2)
        for x, y in zip(a, b):
            assert jnp.allclose(x, y), "checkpoint must restore exactly"


def test_engine_generate_and_eos():
    cfg = get_config("tinyllama-1.1b").reduced()
    eng = Engine(cfg, temperature=0.0)   # greedy
    g = eng.generate("hello", max_new_tokens=6)
    assert 1 <= g.new_tokens <= 6
    g2 = eng.generate("hello", max_new_tokens=6)
    assert g.token_ids == g2.token_ids   # greedy is deterministic


def test_engine_sliding_window_arch():
    import dataclasses
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              sliding_window=16)
    eng = Engine(cfg, temperature=0.0)
    g = eng.generate("a" * 100, max_new_tokens=5)   # prompt > window
    assert g.new_tokens >= 1


def test_scheduler_continuous_batching():
    cfg = get_config("tinyllama-1.1b").reduced()
    eng = Engine(cfg)
    sched = BatchScheduler(eng, n_slots=2)
    rids = [sched.submit(f"prompt {i}", max_new=4) for i in range(5)]
    results = sched.run()
    assert set(results) == set(rids)


def test_synthetic_data_deterministic():
    d = SyntheticLM(vocab_size=100, seq_len=16, batch=2, seed=7)
    b1, b2 = d.batch_at(3), d.batch_at(3)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert b1["tokens"].max() < 100


def test_agent_trace_corpus():
    c = AgentTraceCorpus(["hello world " * 50], vocab_size=1000, seq_len=32,
                         batch=2)
    b = c.batch_at(0)
    assert b["tokens"].shape == (2, 32)


def test_frontend_data_pipeline():
    cfg = get_config("internvl2-1b").reduced()
    d = SyntheticLM(cfg.vocab_size, 32, 2, 0,
                    frontend_positions=cfg.frontend_positions,
                    d_model=cfg.d_model)
    b = d.batch_at(0)
    assert b["frontend_embeds"].shape == (2, cfg.frontend_positions,
                                          cfg.d_model)
    out = train(cfg, steps=2, batch=2, seq_len=32, log_every=1, data=d)
    assert out["history"][-1]["loss"] > 0
