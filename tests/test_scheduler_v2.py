"""Scheduler v2: batched + chunked prefill with priority preemption.

Acceptance criteria of the admission overhaul:
  * bucketed BATCHED prefill (several requests, one jitted call per
    power-of-two bucket) keeps tokens bit-identical to serial
    generation;
  * chunked prefill (long prompts admitted one fixed-shape chunk per
    step, live slots decoding in between) keeps tokens bit-identical;
  * priority classes order admission, preemption evicts-and-requeues
    keeping generated tokens, and a preempted-then-resumed request
    produces tokens bit-identical to an uninterrupted run across
    gqa/mla/ssm cache families;
  * EngineStepped gains prefill/preemption gauges (wire-compatible) and
    RunSpec.priority plumbs through ServingBackend.make.
"""
import dataclasses

import pytest

from repro.apps.cache import spec_fingerprint
from repro.apps.session import RunSpec, Session
from repro.configs import get_config
from repro.core.events import EngineStepped, from_wire, to_wire
from repro.serving import (BatchScheduler, Engine, EngineClient, RunMonitor,
                           prefill_bucket)

PROMPTS = ["hello world", "a much longer prompt about agents and tools",
           "x", "another prompt", "fifth!", "sixth prompt here"]


def _cfg(arch, **over):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, **over) if over else cfg


# deepseek's reduced config is MLA+MoE; the MoE capacity dispatch is
# batch-composition-dependent (padding changes token drops), so the
# fixed-shape admission path is exercised on an MLA-dense variant
def _mla_dense():
    return _cfg("deepseek-v2-236b", arch_type="dense", moe=None)


ADMISSION_ARCHS = [("gqa", lambda: _cfg("tinyllama-1.1b")),
                   ("mla", _mla_dense)]


# ---------------------------------------------------------------------------
# bucketed batched prefill


@pytest.mark.parametrize("name,make_cfg", ADMISSION_ARCHS,
                         ids=[a[0] for a in ADMISSION_ARCHS])
def test_bucketed_batch_admission_parity(name, make_cfg):
    """Mixed-length burst admitted through bucketed batched prefill is
    bit-identical to serial generation, and TTFT stamps are recorded."""
    eng = Engine(make_cfg(), temperature=0.0)
    assert eng.supports_fixed_shape_prefill
    sched = BatchScheduler(eng, n_slots=3, max_len=64)
    maxn = [8, 5, 12, 7, 9, 6]
    rids = [sched.submit(p, max_new=m) for p, m in zip(PROMPTS, maxn)]
    results = sched.drain()
    for rid, m in zip(rids, maxn):
        req = sched.requests[rid]
        ref = eng.generate_ids(req.prompt_ids, m, rid=rid,
                               cache_len=sched.max_len)
        assert results[rid].token_ids == ref.token_ids, \
            f"rid {rid}: bucketed admission diverged from serial"
        assert req.t_first_token >= req.t_submit > 0


def test_bucketed_prefill_one_trace_per_bucket():
    """Prompts of different lengths inside one bucket share ONE jitted
    prefill trace — the per-length-recompile elimination."""
    eng = Engine(_cfg("tinyllama-1.1b"), temperature=0.0)
    size = getattr(eng._prefill_fixed, "_cache_size", None)
    if size is None:
        pytest.skip("jit cache introspection unavailable")
    sched = BatchScheduler(eng, n_slots=2, max_len=64)
    for n in (3, 5, 6, 8):      # all bucket 8 (floor)
        sched.submit(prompt_ids=list(range(1, n + 1)), max_new=2)
    sched.drain()
    assert eng._prefill_fixed._cache_size() == 1
    sched.submit(prompt_ids=list(range(1, 14)), max_new=2)   # bucket 16
    sched.drain()
    assert eng._prefill_fixed._cache_size() == 2


def test_prefill_bucket_helper():
    assert [prefill_bucket(n) for n in (1, 8, 9, 16, 17, 33)] == \
        [8, 8, 16, 16, 32, 64]


# ---------------------------------------------------------------------------
# chunked prefill


@pytest.mark.parametrize("name,make_cfg", ADMISSION_ARCHS,
                         ids=[a[0] for a in ADMISSION_ARCHS])
def test_chunked_prefill_parity(name, make_cfg):
    """Prompts split across prefill chunks (including a padded final
    partial chunk) generate bit-identically to serial — the serial
    recipe chunks too, so this also proves chunk-loop == whole-bucket
    numerics."""
    eng = Engine(make_cfg(), temperature=0.0, prefill_chunk=8)
    sched = BatchScheduler(eng, n_slots=2, max_len=64)
    maxn = [6, 5, 7, 6]
    prompts = [PROMPTS[1], PROMPTS[3], PROMPTS[1] + " extended further",
               PROMPTS[0]]      # lengths straddle the chunk budget
    rids = [sched.submit(p, max_new=m) for p, m in zip(prompts, maxn)]
    results = sched.drain()
    for rid, m in zip(rids, maxn):
        req = sched.requests[rid]
        ref = eng.generate_ids(req.prompt_ids, m, rid=rid,
                               cache_len=sched.max_len)
        assert results[rid].token_ids == ref.token_ids, \
            f"rid {rid}: chunked admission diverged from serial"


def test_chunked_admission_interleaves_decode():
    """A long prompt's chunked admission must not stall live slots: some
    step both prefills a chunk AND decodes a live slot."""
    eng = Engine(_cfg("tinyllama-1.1b"), temperature=0.0, prefill_chunk=4)
    events = []
    sched = BatchScheduler(eng, n_slots=2, max_len=64,
                           on_event=events.append)
    short = sched.submit("hi", max_new=16)
    sched.step()                      # short is live and decoding
    long_rid = sched.submit(PROMPTS[1], max_new=4)    # ~44 tokens, 11 chunks
    sched.drain()
    overlapped = [e for e in events if e.prefilled > 0 and e.live > 0]
    assert overlapped, "chunk admission must interleave with live decode"
    chunk_steps = [e for e in events if 0 < e.prefilled <= 4]
    assert len(chunk_steps) >= 3, "long prompt must span several steps"
    assert sched.requests[short].done and sched.requests[long_rid].done


# ---------------------------------------------------------------------------
# priority + preemption


def test_priority_orders_admission():
    """Within a full scheduler, a higher-priority submission is admitted
    before an earlier lower-priority one (no preemption involved: the
    running request has equal priority to the high submission)."""
    eng = Engine(_cfg("tinyllama-1.1b"), temperature=0.0)
    sched = BatchScheduler(eng, n_slots=1, max_len=64)
    running = sched.submit("occupying the only slot", max_new=6, priority=3)
    sched.step()
    lo = sched.submit("low priority waiter", max_new=2, priority=0)
    hi = sched.submit("high priority waiter", max_new=2, priority=3)
    sched.drain()
    reqs = sched.requests
    assert reqs[hi].t_first_token < reqs[lo].t_first_token
    assert reqs[running].preemptions == 0


PREEMPT_ARCHS = [
    ("gqa", lambda: _cfg("tinyllama-1.1b")),
    ("mla", lambda: _cfg("deepseek-v2-236b")),   # real MLA(+MoE) cache
    ("ssm", lambda: _cfg("mamba2-370m")),
]


@pytest.mark.parametrize("name,make_cfg", PREEMPT_ARCHS,
                         ids=[a[0] for a in PREEMPT_ARCHS])
def test_preemption_resume_bit_identical(name, make_cfg):
    """A preempted-then-resumed request keeps its generated prefix and
    finishes with tokens bit-identical to an uninterrupted run, across
    cache families (replay resume)."""
    eng = Engine(make_cfg(), temperature=0.0)
    monitor = RunMonitor()
    sched = BatchScheduler(eng, n_slots=1, max_len=64, on_event=monitor)
    low = sched.submit("a long low priority request about workflows",
                       max_new=10, priority=0)
    for _ in range(4):
        sched.step()
    kept = list(sched.requests[low].out_ids)
    assert kept, "low-priority request must have generated tokens"
    hi = sched.submit("urgent", max_new=3, priority=5)
    results = sched.drain()
    low_req, hi_req = sched.requests[low], sched.requests[hi]
    assert low_req.preemptions == 1
    assert monitor.engine_preemptions == 1
    assert results[low].token_ids[:len(kept)] == kept, \
        "eviction must keep already-generated tokens"
    ref_low = eng.generate_ids(low_req.prompt_ids, 10, rid=low,
                               cache_len=sched.max_len)
    ref_hi = eng.generate_ids(hi_req.prompt_ids, 3, rid=hi,
                              cache_len=sched.max_len)
    assert results[low].token_ids == ref_low.token_ids, \
        "preempted+resumed run diverged from uninterrupted"
    assert results[hi].token_ids == ref_hi.token_ids
    # the high-priority request got its first token before the
    # preempted one produced any post-eviction token
    assert hi_req.t_first_token > low_req.t_first_token


def test_equal_priority_never_preempts():
    eng = Engine(_cfg("tinyllama-1.1b"), temperature=0.0)
    monitor = RunMonitor()
    sched = BatchScheduler(eng, n_slots=1, max_len=64, on_event=monitor)
    a = sched.submit("first request", max_new=6, priority=2)
    sched.step()
    sched.submit("second request, same class", max_new=2, priority=2)
    sched.drain()
    assert monitor.engine_preemptions == 0
    assert sched.requests[a].preemptions == 0


# ---------------------------------------------------------------------------
# gauges + plumbing


def test_engine_stepped_gauges_wire_roundtrip():
    ev = EngineStepped(t=3.0, live=2, queued=5, generated=2,
                       prefilled=17, preempted=1)
    assert from_wire(to_wire(ev)) == ev
    # pre-v2 wire payloads (no gauge fields) still deserialize
    legacy = {"type": "EngineStepped", "t": 1.0, "live": 1, "queued": 0,
              "generated": 1}
    ev2 = from_wire(legacy)
    assert ev2.prefilled == 0 and ev2.preempted == 0


def test_monitor_prefill_gauge_counts_prompt_tokens():
    eng = Engine(_cfg("tinyllama-1.1b"), temperature=0.0)
    monitor = RunMonitor()
    sched = BatchScheduler(eng, n_slots=2, max_len=64, on_event=monitor)
    rids = [sched.submit(p, max_new=3) for p in PROMPTS[:3]]
    sched.drain()
    total = sum(len(sched.requests[r].prompt_ids) for r in rids)
    assert monitor.engine_prefill_tokens == total
    assert monitor.snapshot()["engine_prefill_tokens"] == total


def test_engine_client_passes_priority():
    eng = Engine(_cfg("tinyllama-1.1b"), temperature=0.0)
    sched = BatchScheduler(eng, n_slots=1, max_len=64)
    seen = []
    orig = sched.submit

    def probe(*a, **kw):
        seen.append(kw.get("priority"))
        return orig(*a, **kw)

    sched.submit = probe
    EngineClient(sched).generate("hello", 2, priority=4)
    assert seen == [4]


def test_runspec_priority_reaches_backend_make():
    from repro.core.llm import OracleLLMBackend
    from repro.serving import ServingBackend, register_llm_backend

    @register_llm_backend("prio-probe")
    class _Probe(ServingBackend):
        name = "prio-probe"
        seen = []

        def make(self, world, policy, trace, priority=0):
            type(self).seen.append(priority)
            return OracleLLMBackend(world, policy, trace)

    r = Session().execute(RunSpec("web_search", "quantum", "agentx",
                                  llm="prio-probe", priority=3))
    assert _Probe.seen == [3]
    assert r.trace.agent_invocations >= 1


def test_spec_fingerprint_ignores_priority():
    """Priority steers latency, never tokens — runs differing only in
    priority share one cache entry."""
    base = RunSpec("web_search", "quantum", "agentx")
    hot = dataclasses.replace(base, priority=7)
    assert spec_fingerprint(base) == spec_fingerprint(hot)


def test_take_slot_inverts_write_slot():
    import jax
    import jax.numpy as jnp
    from repro.models.model import init_cache
    from repro.serving import take_slot, write_slot
    cfg = _cfg("zamba2-7b")        # hybrid: every cache family at once
    big = init_cache(cfg, 3, 32)
    row = jax.tree_util.tree_map(lambda x: jnp.ones_like(x),
                                 take_slot(big, 0))
    out = write_slot(big, row, 2)
    back = take_slot(out, 2)
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), back, row))
