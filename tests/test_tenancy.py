"""Multi-tenant serving (``repro.tenancy``): fair share, budgets, spans.

Acceptance criteria pinned here:

  * **fingerprint hygiene** — ``RunSpec.tenant`` enters the run-cache
    fingerprint (no cross-tenant cache hits) but neither the world seed
    nor the plan key (tenants share worlds and compiled graphs);
  * **fair share** — deficit-round-robin admission tracks weights, a
    single tenant degenerates to the plain FIFO semaphore bit-identically,
    and the real-mode scheduler interleaves tenants instead of FIFO;
  * **budgets** — soft exhaustion degrades (``RunDegraded`` precedes
    ``RunStarted`` on the stream, run not cached), hard exhaustion
    rejects (``BudgetExceeded``, nothing billed);
  * **span export** — lossless folding, identical trees for in-process
    and wire-replayed streams, correct nesting across patterns;
  * **parity** — with tenancy off (or a default tenant and no budgets)
    every run is bit-identical to a tenancy-free session.
"""
import asyncio
import dataclasses
import json

import pytest

from repro.apps.cache import RunCache, spec_fingerprint
from repro.apps.session import RunSpec, Session, stable_world_seed
from repro.core.events import (BudgetExceeded, LLMCompleted, RunCompleted,
                               RunDegraded, RunStarted, StageCompleted,
                               events_from_wire, events_to_wire)
from repro.core.metrics import LLMEvent
from repro.plans.compile import plan_key
from repro.tenancy import (DEFAULT_TENANT, BudgetMeter, DeficitRoundRobin,
                           DegradePolicy, FairShareGate, Tenancy, Tenant,
                           TenantQueue, TenantRegistry, fold_spans,
                           to_otlp)
from repro.traffic import TrafficDriver, Workload, tenant_mix
from repro.traffic.driver import VirtualTimeline
from repro.traffic.workload import DEFAULT_MIX

WEB = ("web_search", "quantum", "agentx")
REACT = ("web_search", "edge", "react")
MAGENTIC = ("research_report", "flow", "magentic")


def spec(app=WEB[0], inst=WEB[1], pattern=WEB[2], **kw):
    return RunSpec(app, inst, pattern, **kw)


# ---------------------------------------------------------------------------
# fingerprint hygiene


def test_tenant_in_run_cache_fingerprint():
    assert (spec_fingerprint(spec(tenant="acme"))
            != spec_fingerprint(spec()))
    assert (spec_fingerprint(spec(tenant="acme"))
            != spec_fingerprint(spec(tenant="zeta")))


def test_default_tenant_fingerprint_unchanged():
    """The default tenant is OMITTED from the fingerprint payload, so
    pre-tenancy fingerprints (and on-disk caches keyed by them) stay
    byte-identical."""
    assert (spec_fingerprint(spec())
            == spec_fingerprint(dataclasses.replace(spec(tenant="x"),
                                                    tenant="")))


def test_tenant_excluded_from_world_seed_and_plan_key():
    assert stable_world_seed(spec(tenant="acme")) == stable_world_seed(spec())
    assert plan_key(spec(tenant="acme")) == plan_key(spec())


def test_no_cross_tenant_cache_hits():
    """Same spec, two tenants, one shared RunCache: both executions are
    billed — the second tenant is never served the first's result."""
    tenancy = Tenancy.with_tenants(Tenant("a"), Tenant("b"))
    sess = Session(cache=RunCache(), tenancy=tenancy)
    sess.execute(spec(tenant="a", seed=3))
    sess.execute(spec(tenant="b", seed=3))
    tok_a, _ = tenancy.meter.used("a")
    tok_b, _ = tenancy.meter.used("b")
    assert tok_a > 0 and tok_b > 0

    # ... while a repeat from the SAME tenant is a cache hit: returned
    # unbilled (the tenant already paid at first execution)
    sess.execute(spec(tenant="a", seed=3))
    assert tenancy.meter.used("a") == (tok_a, _)


# ---------------------------------------------------------------------------
# registry


def test_registry_defaults_and_validation():
    reg = TenantRegistry(Tenant("gold", weight=4.0))
    assert reg.weight("gold") == 4.0
    assert reg.weight("unknown") == 1.0           # permissive resolve
    assert reg.resolve(DEFAULT_TENANT).token_budget == float("inf")
    with pytest.raises(ValueError):
        Tenant("bad", weight=0.0)
    with pytest.raises(ValueError):
        Tenant("bad", weight=-1.0)


# ---------------------------------------------------------------------------
# deficit round robin


def test_drr_equal_weights_alternate():
    drr = DeficitRoundRobin()
    picks = [drr.next_tenant(["a", "b"]) for _ in range(6)]
    assert picks == ["a", "b", "a", "b", "a", "b"]


def test_drr_weighted_shares():
    drr = DeficitRoundRobin({"a": 2.0, "b": 1.0})
    picks = [drr.next_tenant(["a", "b"]) for _ in range(300)]
    assert abs(picks.count("a") / 300 - 2 / 3) < 0.02
    # and deterministically so
    drr2 = DeficitRoundRobin({"a": 2.0, "b": 1.0})
    assert [drr2.next_tenant(["a", "b"]) for _ in range(300)] == picks


def test_drr_idle_tenant_does_not_hoard():
    """A tenant idle for many rounds re-enters with RESET credit — it
    gets its fair share going forward, not a burst repaying the idle
    time."""
    drr = DeficitRoundRobin()
    for _ in range(50):                  # b idle: a absorbs everything
        assert drr.next_tenant(["a"]) == "a"
    picks = [drr.next_tenant(["a", "b"]) for _ in range(20)]
    assert picks.count("b") <= 11        # ~half, never a catch-up burst


def test_drr_preview_does_not_charge():
    drr = DeficitRoundRobin()
    assert drr.preview(["a", "b"]) == drr.next_tenant(["a", "b"]) == "a"
    assert drr.admitted == {"a": 1}


# ---------------------------------------------------------------------------
# FairShareGate on the virtual timeline


def _drive_gate(jobs, capacity=1, weights=None, fifo=False):
    """Run ``jobs`` = [(tenant, duration), ...] (all arriving at t=0, in
    order) through a capacity gate; returns the admission order as
    [(virtual_t, tenant), ...]."""
    order = []

    async def main():
        tl = VirtualTimeline()
        gate = (tl.semaphore(capacity) if fifo
                else FairShareGate(tl, capacity, weights))

        async def worker(tenant, dur):
            try:
                await gate.acquire(tenant)
                order.append((tl.now(), tenant))
                await tl.sleep(dur)
                gate.release()
            finally:
                tl.unregister()

        for _ in jobs:
            tl.register()
        await asyncio.gather(*[asyncio.ensure_future(worker(t, d))
                               for t, d in jobs])

    asyncio.run(main())
    return order


def test_gate_interleaves_tenants_not_fifo():
    """4 queued runs from a bursting tenant vs 2 from a steady one,
    capacity 1: FIFO starves the steady tenant to the tail; DRR
    alternates."""
    jobs = [("a", 1.0)] * 4 + [("b", 1.0)] * 2
    assert [t for _, t in _drive_gate(jobs, fifo=True)] \
        == ["a", "a", "a", "a", "b", "b"]
    assert [t for _, t in _drive_gate(jobs)] \
        == ["a", "b", "a", "b", "a", "a"]


def test_gate_weighted_admission():
    jobs = [("a", 1.0)] * 6 + [("b", 1.0)] * 3
    order = [t for _, t in _drive_gate(jobs, weights={"a": 2.0, "b": 1.0})]
    assert order == ["a", "a", "b", "a", "a", "b", "a", "a", "b"]


def test_gate_single_tenant_is_fifo_bit_identical():
    jobs = [("", d) for d in (2.0, 1.0, 3.0, 1.5, 0.5)]
    assert _drive_gate(jobs, capacity=2) == _drive_gate(jobs, capacity=2,
                                                        fifo=True)


def test_driver_single_tenant_gate_parity():
    """A whole workload through TrafficDriver: the tenant-aware gate
    with one (default) tenant reproduces the FIFO semaphore's timeline
    exactly."""
    wl = Workload(rate=3.0, n_requests=12, seed=1)
    plain = TrafficDriver(Session(), max_concurrency=2).run(wl)
    gated = TrafficDriver(Session(), max_concurrency=2,
                          tenants=TenantRegistry()).run(wl)
    assert ([(r.start, r.end, r.queue_wait) for r in plain.records]
            == [(r.start, r.end, r.queue_wait) for r in gated.records])


# ---------------------------------------------------------------------------
# TenantQueue (real-mode admission)


def test_tenant_queue_priority_within_tenant_drr_across():
    tq = TenantQueue()
    tq.push("a", (0, 0), "a-low")
    tq.push("a", (-5, 1), "a-high")
    tq.push("b", (0, 2), "b-only")
    first = tq.pop()
    assert first == ("a", "a-high")      # priority class within tenant
    assert tq.pop() == ("b", "b-only")   # DRR alternates tenants
    assert tq.pop() == ("a", "a-low")
    assert tq.pop() is None and len(tq) == 0


def test_tenant_queue_same_tenant_pop_respects_drr():
    tq = TenantQueue()
    tq.push("a", (0, 0), "a0")
    tq.push("a", (0, 1), "a1")
    tq.push("b", (0, 2), "b0")
    assert tq.pop() == ("a", "a0")
    # growing a's prefill group would cut in front of b — refused
    assert tq.pop_same_tenant("a", lambda item: True) is None
    assert tq.pop() == ("b", "b0")
    # now it's a's turn again
    assert tq.pop_same_tenant("a", lambda item: True) == "a1"


def test_scheduler_fair_share_interleaves_tenants():
    """BatchScheduler with ``fair_share``: one slot, tenant a's four
    requests queued ahead of tenant b's two — b's first token lands
    before a's third request (DRR), and generation is token-identical
    to the FIFO scheduler."""
    from repro.configs import get_config
    from repro.serving import BatchScheduler, Engine

    eng = Engine(get_config("tinyllama-1.1b").reduced(), temperature=0.0)
    subs = [("a", "alpha one"), ("a", "alpha two"), ("a", "alpha three"),
            ("a", "alpha four"), ("b", "beta one"), ("b", "beta two")]

    fair = BatchScheduler(eng, n_slots=1, max_len=48, fair_share=True)
    rids = [fair.submit(p, max_new=4, tenant=t) for t, p in subs]
    fair_out = fair.drain()
    admit = sorted(rids, key=lambda r: fair.requests[r].t_first_token)
    tenants_in_order = [fair.requests[r].tenant for r in admit]
    assert tenants_in_order == ["a", "b", "a", "b", "a", "a"]

    fifo = BatchScheduler(eng, n_slots=1, max_len=48)
    rids2 = [fifo.submit(p, max_new=4, tenant=t) for t, p in subs]
    fifo_out = fifo.drain()
    for r1, r2 in zip(rids, rids2):
        assert fair_out[r1].token_ids == fifo_out[r2].token_ids


# ---------------------------------------------------------------------------
# budgets


def test_budget_meter_state_machine():
    reg = TenantRegistry(Tenant("t", token_budget=100.0))
    meter = BudgetMeter(reg, soft_fraction=0.8)
    assert meter.state("t") == "ok"
    meter.charge("t", 79.0, 0.0)
    assert meter.state("t") == "ok"
    meter.charge("t", 1.0, 0.0)
    assert meter.state("t") == "soft"
    meter.charge("t", 20.0, 0.0)
    assert meter.state("t") == "hard"
    assert meter.exhausted_axis("t") == ("tokens", 100.0, 100.0)
    assert meter.state("other") == "ok"  # unlimited by default


def test_hard_exhaustion_rejects_unbilled():
    tenancy = Tenancy.with_tenants(Tenant("poor", token_budget=1.0))
    sess = Session(cache=RunCache(), tenancy=tenancy)
    first = sess.execute(spec(tenant="poor", seed=0))
    tokens, cost = tenancy.meter.used("poor")
    assert tokens > 1.0                  # cap trips AFTER the first run

    rejected = sess.execute(spec(tenant="poor", seed=1))
    assert not rejected.success
    assert rejected.failure_reason.startswith("BudgetExceeded")
    assert rejected.total_latency == 0.0
    assert rejected.extras.get("rejected") is True
    evs = rejected.extras["events"]
    assert len(evs) == 1 and isinstance(evs[0], BudgetExceeded)
    assert evs[0].kind == "tokens" and evs[0].tenant == "poor"
    # nothing billed, telemetry recorded
    assert tenancy.meter.used("poor") == (tokens, cost)
    assert tenancy.meter.snapshot()["poor"]["rejected_runs"] == 1
    assert first.success in (True, False)  # first run executed for real


def test_soft_exhaustion_degrades_faas_to_local():
    # soft_fraction 0.1: one run puts the tenant in the soft band while
    # leaving plenty of hard headroom
    tenancy = Tenancy.with_tenants(Tenant("t", token_budget=10_000_000.0),
                                   soft_fraction=0.1)
    tenancy.meter.charge("t", 5_000_000.0, 0.0)   # into the soft band
    sess = Session(cache=RunCache(), tenancy=tenancy)
    res = sess.execute(spec(deployment="faas", tenant="t", seed=2))
    evs = res.extras["events"]
    assert isinstance(evs[0], RunDegraded)
    assert isinstance(evs[1], RunStarted)         # admission precedes run
    assert evs[0].from_deployment == "faas"
    assert evs[0].to_deployment == "local"
    assert res.deployment == "local"              # actually ran degraded
    assert res.faas_cost == 0.0                   # Eq. 2 bill shed
    assert tenancy.meter.snapshot()["t"]["degraded_runs"] == 1
    # a degraded result must not be cached (the RunDegraded on its
    # stream reflects meter state, not the spec)
    again = sess.execute(spec(deployment="faas", tenant="t", seed=2))
    assert tenancy.meter.snapshot()["t"]["degraded_runs"] == 2
    assert again is not res


def test_degrade_policy_mappings():
    pol = DegradePolicy()
    s = spec(pattern="react", deployment="faas")
    new, info = pol.degrade(s)
    assert new.deployment == "local" and new.pattern == "react"
    assert info == {"from_pattern": "react", "to_pattern": "react",
                    "from_deployment": "faas", "to_deployment": "local"}
    # nothing to cheapen
    assert pol.degrade(spec(pattern="react"))[1] is None
    # agentx -> compiled is only claimed when the plan graph is cached;
    # the spec's pattern field stays untouched either way (the plan key
    # is pattern-scoped; the session replays cached graphs on its own)
    assert pol.degrade(spec())[1] is None

    class FakeCache:
        def get(self, key):
            return object()

    new, info = pol.degrade(spec(), plan_cache=FakeCache())
    assert new.pattern == "agentx"
    assert info["to_pattern"] == "agentx-compiled"


# ---------------------------------------------------------------------------
# span export


def _run_events(app, inst, pattern, **kw):
    res = Session().execute(RunSpec(app, inst, pattern, **kw))
    return list(res.extras["events"])


@pytest.mark.parametrize("app,inst,pattern", [WEB, REACT, MAGENTIC],
                         ids=["agentx", "react", "magentic"])
def test_fold_spans_lossless(app, inst, pattern):
    """Every event is represented: as a span or as a zero-width
    annotation.  RunCompleted/StageCompleted close existing spans rather
    than opening new ones, so they are excluded from the count."""
    events = _run_events(app, inst, pattern)
    roots = fold_spans(events)
    assert len(roots) == 1 and roots[0].kind == "run"
    spans = list(roots[0].walk())
    reps = len(spans) + sum(len(s.events) for s in spans)
    closers = sum(isinstance(e, (RunCompleted, StageCompleted))
                  for e in events)
    assert reps == len(events) - closers


@pytest.mark.parametrize("app,inst,pattern", [WEB, REACT, MAGENTIC],
                         ids=["agentx", "react", "magentic"])
def test_fold_spans_wire_replay_identical(app, inst, pattern):
    """Spans are a derived view of the stream: folding the in-process
    events and folding the wire round-tripped events give identical
    trees — the export works from any transport boundary."""
    events = _run_events(app, inst, pattern)
    assert fold_spans(events) \
        == fold_spans(events_from_wire(events_to_wire(events)))


def test_span_nesting_and_attribution():
    events = _run_events(*WEB, tenant="acme")
    root = fold_spans(events)[0]
    spans = list(root.walk())
    # agentx is staged: llm/tool spans nest under stage spans
    stages = [s for s in spans if s.kind == "stage"]
    assert stages and all(s.parent_id == root.span_id for s in stages)
    leaves = [s for s in spans if s.kind in ("llm", "tool")]
    stage_ids = {s.span_id for s in stages}
    assert leaves and all(s.parent_id in stage_ids | {root.span_id}
                          for s in leaves)
    # tenant stamped everywhere, costs roll up to the run's Eq. 1 total
    assert all(s.attributes["tenant"] == "acme" for s in spans)
    llm_cost = sum(s.attributes["cost_usd"] for s in spans
                   if s.kind == "llm")
    assert root.attributes["cost_usd"] == pytest.approx(llm_cost)

    # react has no stages: leaves attach straight to the run span
    react_root = fold_spans(_run_events(*REACT))[0]
    assert all(s.parent_id == react_root.span_id
               for s in react_root.children)


def test_degraded_and_rejected_streams_fold():
    pre = RunDegraded(t=0.0, tenant="t", reason="soft budget exhaustion",
                      from_pattern="agentx", to_pattern="agentx",
                      from_deployment="faas", to_deployment="local")
    events = [pre] + _run_events(*WEB, tenant="t")
    root = fold_spans(events)[0]
    kinds = [c.kind for c in root.children]
    assert kinds[0] == "admission"       # preamble attached under the run

    rej = fold_spans([BudgetExceeded(t=0.0, tenant="t", kind="tokens",
                                     used=2.0, budget=1.0)])
    assert len(rej) == 1 and rej[0].kind == "admission"
    assert rej[0].start == rej[0].end    # zero-width root


def test_otlp_export_shape():
    events = _run_events(*WEB, tenant="acme")
    roots = fold_spans(events)
    payload = to_otlp(roots, service="svc")
    assert json.loads(json.dumps(payload)) == payload   # JSON-safe
    spans = payload["resourceSpans"][0]["scopeSpans"][0]["spans"]
    assert len(spans) == len(list(roots[0].walk()))
    by_id = {s["spanId"]: s for s in spans}
    for s in spans:
        if "parentSpanId" in s:
            assert s["parentSpanId"] in by_id
        assert int(s["endTimeUnixNano"]) >= int(s["startTimeUnixNano"])


def test_run_monitor_per_tenant_gauges():
    from repro.serving.engine import RunMonitor
    mon = RunMonitor()
    mon(RunStarted(t=0.0, pattern="agentx", task="x", tenant="acme"))
    mon(LLMCompleted(t=1.0, event=LLMEvent("planner", 100, 50, 1.0, 1.0)))
    mon(RunCompleted(t=2.0, completed=True, data=None))
    mon(RunDegraded(t=0.0, tenant="acme", reason="r", from_pattern="p",
                    to_pattern="p", from_deployment="faas",
                    to_deployment="local"))
    mon(BudgetExceeded(t=0.0, tenant="acme", kind="tokens", used=2.0,
                       budget=1.0))
    g = mon.snapshot()["tenants"]["acme"]
    assert g["runs"] == 1 and g["completed"] == 1
    assert g["llm_calls"] == 1 and g["tokens"] == 150
    assert g["degraded"] == 1 and g["rejected"] == 1


# ---------------------------------------------------------------------------
# workload + SLO plumbing


def test_tenant_mix_shapes_offered_load():
    mix = tenant_mix({"a": 1.0, "noisy": 5.0})
    assert len(mix) == 2 * len(DEFAULT_MIX)
    noisy = [s for s in mix if s.tenant == "noisy"]
    base_by_suffix = {s.name: s for s in DEFAULT_MIX}
    for s in noisy:
        assert s.name.startswith("noisy/")
        base = base_by_suffix[s.name.split("/", 1)[1]]
        assert s.weight == base.weight * 5.0
        assert s.spec(7).tenant == "noisy"


def test_aggregate_report_tenant_section():
    from repro.traffic import aggregate_report
    wl = Workload(scenarios=tenant_mix({"a": 1.0, "b": 1.0}), rate=3.0,
                  n_requests=8, seed=0)
    reg = TenantRegistry(Tenant("a"), Tenant("b"))
    agg = aggregate_report(
        TrafficDriver(Session(tenancy=Tenancy(reg)), max_concurrency=2,
                      tenants=reg).run(wl))
    assert set(agg["tenants"]) <= {"a", "b"}
    for t in agg["tenants"].values():
        assert {"tokens", "token_throughput", "cost_usd", "degraded_runs",
                "rejected_runs"} <= set(t["tenant"])
    # single default tenant: no tenants section at all (parity)
    plain = aggregate_report(
        TrafficDriver(Session()).run(Workload(rate=3.0, n_requests=4)))
    assert "tenants" not in plain


# ---------------------------------------------------------------------------
# tenancy-off parity


def test_tenancy_off_bit_identical():
    s = spec(seed=5)
    base = Session().execute(s)
    inert = Session(tenancy=Tenancy()).execute(s)
    assert base.extras["events"] == inert.extras["events"]
    assert (base.artifact, base.success, base.total_latency) \
        == (inert.artifact, inert.success, inert.total_latency)


def test_tenant_stamp_changes_only_runstarted():
    plain = Session().execute(spec(seed=6)).extras["events"]
    stamped = Session().execute(spec(seed=6,
                                     tenant="acme")).extras["events"]
    assert len(plain) == len(stamped)
    for a, b in zip(plain, stamped):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        if isinstance(a, RunStarted):
            assert da.pop("tenant") == "" and db.pop("tenant") == "acme"
        assert da == db
