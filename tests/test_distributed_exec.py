"""EXECUTED distributed training/serving on a debug mesh (8 forced host
devices, subprocess-isolated): proves the sharding rules are not just
compilable but numerically runnable — loss decreases under pjit with the
production param/activation specs.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import param_shardings, make_activation_policy
from repro.configs.base import InputShape
from repro.models.params import init_params
from repro.models.sharding_ctx import activation_policy
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_loop import make_train_step
from repro.training.data import SyntheticLM

arch = __import__("sys").argv[1]
cfg = get_config(arch).reduced()
mesh = make_debug_mesh(2, 2)   # 2x2 ("data","model")
B, S = 4, 64
shape = InputShape("debug", S, B, "train")

params = init_params(cfg, jax.random.key(0))
opt = init_opt_state(params)
p_sh = param_shardings(params, mesh)
o_sh = param_shardings(opt, mesh)
params = jax.device_put(params, p_sh)
opt = jax.device_put(opt, o_sh)
pol = make_activation_policy(cfg, shape, mesh)
step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=1,
                                              total_steps=10)),
               in_shardings=(p_sh, o_sh, None), out_shardings=(p_sh, o_sh, None))
data = SyntheticLM(cfg.vocab_size, S, B, 0,
                   cfg.frontend_positions if cfg.frontend else 0, cfg.d_model)
losses = []
with mesh:
    with activation_policy(pol):
        for i in range(6):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
# a param leaf is actually sharded across >1 device
leaf = jax.tree_util.tree_leaves(params)[2]
n_shards = len({d for s in leaf.addressable_shards for d in [s.device]})
print("RESULT::" + json.dumps({"losses": losses, "n_shards": n_shards}))
"""


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "phi3.5-moe-42b-a6.6b",
                                  "mamba2-370m", "zamba2-7b"])
def test_sharded_training_executes_and_learns(arch):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT, arch],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT::")][0]
    res = json.loads(line[len("RESULT::"):])
    losses = res["losses"]
    assert losses[-1] < losses[0], losses          # it learns
    assert all(l == l for l in losses)             # no NaNs
    assert res["n_shards"] > 1                     # actually distributed


SHARDMAP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import init_params
from repro.models import model as mm
from repro.models.model import forward
from repro.launch.mesh import make_debug_mesh

cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, n_experts=4, top_k=2, capacity_factor=8.0))
params = init_params(cfg, jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
ref, _ = forward(params, cfg, toks, remat=False)
mesh = make_debug_mesh(2, 2)
mm.MOE_SHARDMAP_MESH = mesh
with mesh:
    out, _ = jax.jit(lambda p, t: forward(p, cfg, t, remat=False))(params, toks)
err = float(jnp.max(jnp.abs(out - ref)))
print("RESULT::" + json.dumps({"err": err}))
"""


def test_shardmap_moe_matches_gather_dispatch():
    """shard_map expert-parallel MoE == gather-dispatch MoE numerically."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", SHARDMAP_SCRIPT],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT::")][0]
    assert json.loads(line[len("RESULT::"):])["err"] < 5e-3
